"""Shared benchmark plumbing: trace cache, timing, CSV row emission.

Every benchmark emits rows ``name,us_per_call,derived`` where
``us_per_call`` is wall-microseconds per simulated request (or per step)
and ``derived`` is the benchmark's key metric (miss ratio, improvement,
count, ...).  Set REPRO_BENCH_FULL=1 for the larger trace suite.
"""

from __future__ import annotations

import os
import time
from typing import Dict, Tuple

import numpy as np

from repro.core import stats, traces

FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"
# REPRO_BENCH_CI=1: the deterministic reduced tier the bench-regression CI
# job runs (fewer traces, truncated streams).  baseline.json is generated
# under this flag, so comparisons are apples-to-apples.
CI = os.environ.get("REPRO_BENCH_CI", "0") == "1" and not FULL
CI_TRACE_LIMIT = 150_000

# paper cache sizes (fractions of trace footprint)
SIZE_FRACS = (0.005, 0.01, 0.05, 0.1)

_TRACE_CACHE: Dict[Tuple, np.ndarray] = {}


def suite():
    if FULL:
        return traces.SUITE
    return traces.SUITE[:2] if CI else traces.SUITE[:4]


def data_trace(spec) -> np.ndarray:
    key = ("data", spec.name)
    if key not in _TRACE_CACHE:
        tr = spec.data()
        _TRACE_CACHE[key] = tr[:CI_TRACE_LIMIT] if CI else tr
    return _TRACE_CACHE[key]


def meta_trace(spec) -> np.ndarray:
    key = ("meta", spec.name)
    if key not in _TRACE_CACHE:
        _TRACE_CACHE[key] = traces.derive_metadata(data_trace(spec))
    return _TRACE_CACHE[key]


def timed_sim(policy: str, trace, cap: int, **kw):
    t0 = time.perf_counter()
    r = stats.simulate(policy, trace, cap, **kw)
    dt = time.perf_counter() - t0
    return r, 1e6 * dt / max(1, len(trace))


def row(name: str, us: float, derived) -> str:
    if isinstance(derived, float):
        derived = f"{derived:.6f}"
    return f"{name},{us:.3f},{derived}"


def write_dirty(trace, frac: float = 0.3, seed: int = 0):
    """Deterministic write-request marker (dirty_fn for policy.run)."""
    rng = np.random.default_rng(seed)
    marks = rng.random(len(trace)) < frac
    return lambda i, key: bool(marks[i])
