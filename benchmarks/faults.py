"""Fault-layer overhead benchmark.

The fault-injection plumbing (``repro.faults``) wraps the block pool's
host-IO swap path; like the obs layer it must cost ~nothing when armed
but idle.  ``perf_fault_overhead`` drives the same churny lookup stream
through an uninstrumented ``BlockPool`` and one carrying a ``NullPlan``
(the full ``HostIO`` retry/breaker/journal machinery in place, no fault
ever fires) and gates the ratio at ``perf/faults/ratio`` <= 1.05x in
baseline.json, so any future check that sneaks onto the per-swap path
fails CI.

Measurement note: the raw instrumented/uninstrumented wall-time ratio is
too noisy to gate tightly (the jnp block copies that dominate a swap
jitter by more than the plumbing costs), so the gated row is composed
from two stable measurements: the ``HostIO.run`` wrapper overhead,
microbenchmarked against a bare call on a no-op IO fn (pure Python,
low-variance), scaled by the measured IO ops per lookup and divided by
the measured per-lookup swap-path cost.  The raw wall times are still
emitted as ungated reference rows.
"""

from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks import common


def _mk_pool(faults=None):
    import jax.numpy as jnp

    from repro.configs import get_config, reduced
    from repro.kvcache.pool import BlockPool

    cfg = reduced(get_config("granite-3-8b"))
    pool = BlockPool(cfg, 32, 8, faults=faults)
    zeros = jnp.zeros((cfg.n_layers, pool.bs, cfg.n_kv_heads, cfg.hd))
    return pool, zeros


def _drive(pool, zeros, keys) -> None:
    # keyspace >> HBM blocks: every stretch of the stream churns the
    # pool through evict -> swap-out -> swap-in, the instrumented path
    for k in keys:
        slot, needs_fill = pool.lookup(int(k), pin=False)
        if needs_fill:
            pool.write_block(slot, zeros, zeros, key=int(k))


def _wrapper_overhead_us(n: int = 20_000) -> float:
    """Added cost of one ``HostIO.run``-wrapped IO op vs the bare call
    (no-op IO fn, NullPlan armed), best-of-5 interleaved."""
    from repro.faults import HostIO, NullPlan

    def fn():
        return None

    best = {"wrapped": float("inf"), "bare": float("inf")}
    for _ in range(5):
        io = HostIO(plan=NullPlan())
        t0 = time.perf_counter()
        for i in range(n):
            io.run("swap_out", i, fn)
        best["wrapped"] = min(best["wrapped"], time.perf_counter() - t0)
        t0 = time.perf_counter()
        for i in range(n):
            fn()
        best["bare"] = min(best["bare"], time.perf_counter() - t0)
    return 1e6 * (best["wrapped"] - best["bare"]) / n


def perf_fault_overhead() -> List[str]:
    """Swap-path cost of the armed-but-idle fault layer (NullPlan) vs
    the uninstrumented pool; gated composite ratio plus raw wall times."""
    from repro.faults import NullPlan

    rng = np.random.default_rng(11)
    warm = rng.integers(0, 120, 1_500)
    timed = rng.integers(0, 120, 4_000)

    def run_once(faults):
        pool, zeros = _mk_pool(faults)
        _drive(pool, zeros, warm)
        t0 = time.perf_counter()
        _drive(pool, zeros, timed)
        return time.perf_counter() - t0, pool

    # interleaved best-of-3 raw wall times (reference rows, ungated)
    best = {"instrumented": float("inf"), "uninstrumented": float("inf")}
    io_ops = 0
    for _ in range(3):
        dt, _pool = run_once(None)
        best["uninstrumented"] = min(best["uninstrumented"], dt)
        dt, pool = run_once(NullPlan())
        best["instrumented"] = min(best["instrumented"], dt)
        io_ops = pool._io.plan.op_seq  # total wrapped IO ops, all phases
    us_i = 1e6 * best["instrumented"] / len(timed)
    us_u = 1e6 * best["uninstrumented"] / len(timed)
    ops_per_lookup = io_ops / (len(warm) + len(timed))

    wrap_us = _wrapper_overhead_us()
    ratio = (us_u + ops_per_lookup * wrap_us) / max(1e-12, us_u)

    rows = [common.row("perf/faults/uninstrumented", us_u, len(timed)),
            common.row("perf/faults/instrumented", us_i, len(timed)),
            common.row("perf/faults/wrapper_us", wrap_us, ops_per_lookup)]
    # the gate: ratio rides the us column (us_factor rules are one-sided)
    rows.append(common.row("perf/faults/ratio", ratio, us_i))
    return rows


def perf_journal_append() -> List[str]:
    """Write-ahead-journal append cost on the policy hot path.

    Same composite-gate technique as ``perf_fault_overhead``: the raw
    per-append cost (in-memory and on-disk variants) is microbenchmarked
    directly (low variance), scaled by the measured journal records per
    pool lookup, and divided by the measured per-lookup cost — the gated
    ``perf/journal/ratio`` row must stay <= 1.05x.  Raw appends are
    emitted as ungated reference rows.
    """
    import os
    import tempfile

    from repro.core.prodcache import ProdClock2QPlus
    from repro.faults import ShardJournal
    from repro.obs import NullSink

    n = 20_000

    def append_us(directory) -> float:
        best = float("inf")
        for rep in range(5):
            sub = None if directory is None else \
                os.path.join(directory, f"r{rep}")
            pol = ProdClock2QPlus(48, max_capacity=64, obs=NullSink())
            jr = ShardJournal(sub).attach(pol)
            t0 = time.perf_counter()
            for i in range(n):
                jr.on_io_done(i)
            best = min(best, time.perf_counter() - t0)
            jr.close()
        return 1e6 * best / n

    mem_us = append_us(None)
    with tempfile.TemporaryDirectory() as d:
        disk_us = append_us(d)

    # journal records per pool lookup (the churny perf workload), and
    # the per-lookup swap-path cost it dilutes into
    rng = np.random.default_rng(11)
    warm = rng.integers(0, 120, 1_500)
    timed = rng.integers(0, 120, 4_000)
    pool, zeros = _mk_pool()
    jr = ShardJournal(None).attach(pool.policy)
    _drive(pool, zeros, warm)
    mark = jr.lsn
    t0 = time.perf_counter()
    _drive(pool, zeros, timed)
    lookup_us = 1e6 * (time.perf_counter() - t0) / len(timed)
    appends_per_lookup = (jr.lsn - mark) / len(timed)

    ratio = (lookup_us + appends_per_lookup * mem_us) \
        / max(1e-12, lookup_us)
    return [common.row("perf/journal/append_mem", mem_us, n),
            common.row("perf/journal/append_disk", disk_us, n),
            common.row("perf/journal/ratio", ratio, appends_per_lookup)]


def perf_failover_rto() -> List[str]:
    """Failover recovery: standby promotion vs ghost-journal cold rewarm
    on w01-skewed at 48k — wall RTO in the us column, post-failover
    miss-ratio gap vs the uninjured run in the derived column.  The
    promote row's gap is gated at exactly 0.0 (bit-exact state) in
    baseline.json; the rewarm row is the ungated reference."""
    import dataclasses as _dc

    from repro.core import traces
    from repro.faults import GhostJournal, ShardReplicator, failover
    from repro.obs import NullSink
    from repro.shardcache import ShardedClock2QPlus

    spec = next(s for s in traces.SUITE if s.name == "w01-skewed")
    trace = _dc.replace(spec, n=48_000).data()
    chunk = 2048

    def run(mode=None):
        svc = ShardedClock2QPlus(2048, n_shards=4, max_capacity=4096,
                                 obs=NullSink())
        rep = gj = None
        if mode == "promote":
            rep = ShardReplicator(svc, None, lag_threshold=1 << 30)
        elif mode == "rewarm":
            gj = GhostJournal()
        hits, rto, done = 0, 0.0, False
        for lo in range(0, len(trace), chunk):
            hits += int(svc.access_many(trace[lo:lo + chunk]).sum())
            if gj is not None:
                gj.capture(svc)
            if rep is not None:
                rep.poll()
            if mode is not None and not done \
                    and lo + chunk >= len(trace) // 2:
                t0 = time.perf_counter()
                if mode == "promote":
                    rep.promote(1)
                else:
                    failover(svc, 1, gj)
                rto = time.perf_counter() - t0
                done = True
        return hits / len(trace), rto

    base, _ = run()
    hr_p, rto_p = run("promote")
    hr_r, rto_r = run("rewarm")
    return [common.row("perf/failover/promote_rto", 1e6 * rto_p,
                       abs(base - hr_p)),
            common.row("perf/failover/rewarm_rto", 1e6 * rto_r,
                       abs(base - hr_r))]
