"""Scenario-zoo benchmarks: every policy x every registered scenario.

``fig_scenario_matrix`` is the coverage table the ROADMAP's "as many
scenarios as you can imagine" goal is measured by: one miss-ratio row per
(scenario, policy) pair, all workloads resolved by name from
``repro.core.traces.SCENARIOS``.  The reduced REPRO_BENCH_CI=1 tier
(shorter streams, headline policies) is what the bench-regression gate
pins in benchmarks/baseline.json.
"""

from __future__ import annotations

from typing import List

from benchmarks import common
from repro.core import traces

# deterministic generation seed for the matrix (baseline.json depends on it)
SEED = 11


def _policies() -> List[str]:
    from benchmarks.paper_figs import HEADLINE, ZOO
    return ZOO if common.FULL else HEADLINE


def _length() -> int:
    if common.FULL:
        return 400_000
    return 60_000 if common.CI else 150_000


def fig_scenario_matrix() -> List[str]:
    rows = []
    n = _length()
    for name in traces.scenario_names():
        tr = traces.make_trace(name, n=n, seed=SEED)
        cap = traces.suite_capacity(tr)
        for pol in _policies():
            r, us = common.timed_sim(pol, tr, cap)
            rows.append(common.row(
                f"fig_scenario_matrix/{name}/{pol}", us, r.miss_ratio))
    return rows
