"""Scenario-zoo benchmarks: every policy x every registered scenario.

``fig_scenario_matrix`` is the coverage table the ROADMAP's "as many
scenarios as you can imagine" goal is measured by: one miss-ratio row per
(scenario, policy) pair, all workloads resolved by name from
``repro.core.traces.SCENARIOS``.  The reduced REPRO_BENCH_CI=1 tier
(shorter streams, headline policies) is what the bench-regression gate
pins in benchmarks/baseline.json.
"""

from __future__ import annotations

from typing import List

from benchmarks import common
from repro.core import traces

# deterministic generation seed for the matrix (baseline.json depends on it)
SEED = 11


def _policies() -> List[str]:
    from benchmarks.paper_figs import HEADLINE, ZOO
    return ZOO if common.FULL else HEADLINE


def _length() -> int:
    if common.FULL:
        return 400_000
    return 60_000 if common.CI else 150_000


def fig_scenario_matrix() -> List[str]:
    rows = []
    n = _length()
    for name in traces.scenario_names():
        tr = traces.make_trace(name, n=n, seed=SEED)
        cap = traces.suite_capacity(tr)
        for pol in _policies():
            r, us = common.timed_sim(pol, tr, cap)
            rows.append(common.row(
                f"fig_scenario_matrix/{name}/{pol}", us, r.miss_ratio))
    return rows


# per-policy tuning grids for fig_policy_tuning: the knobs each engine
# actually reads (clock is knob-free — its sweep is capacities only and
# its tuner grid collapses to the live point)
POLICY_TUNING_GRIDS = {
    "s3fifo": dict(small_fracs=(0.05, 0.1, 0.25), ghost_fracs=(1.0,)),
    "clock": {},
}


def fig_policy_tuning() -> List[str]:
    """The PolicyEngine payoff: the batched MRC sweep and the OnlineTuner
    running against NON-Clock2Q+ lane policies, straight from the
    registry.  For each policy: (a) a capacities x knob-grid sweep on a
    zipf scenario, reporting the best achievable miss ratio; (b) an
    ``EngineCache`` live replay with the tuner observing, reporting the
    resulting miss ratio and how many retunes it applied."""
    import time

    import numpy as np

    from repro.core.engine.host import EngineCache
    from repro.tuning import OnlineTuner, make_grid, relabel, sweep_grid

    rows = []
    n = _length()
    tr = traces.make_trace("zipf", n=n, seed=SEED)
    cap = traces.suite_capacity(tr)
    dense, universe = relabel(tr)
    dense = np.asarray(dense)
    for pol, kw in POLICY_TUNING_GRIDS.items():
        caps = sorted({max(8, cap // 4), max(8, cap // 2), cap})
        grid = make_grid(caps, policy=pol, **kw)
        t0 = time.perf_counter()
        mrs = sweep_grid(dense, grid)
        us = 1e6 * (time.perf_counter() - t0) / (len(dense) * len(grid))
        rows.append(common.row(f"fig_policy_tuning/{pol}/mrc_best", us,
                               float(mrs.min())))
        cache = EngineCache(pol, cap, universe,
                            **({"small_frac": 0.05} if pol == "s3fifo"
                               else {}))
        tuner = OnlineTuner(cache, retune_every=max(2048, n // 8),
                            rate_shift=4, min_scaled_cap=16,
                            min_samples=256, min_gain=0.001,
                            confirm_rounds=1,
                            **({"small_fracs": kw["small_fracs"]}
                               if "small_fracs" in kw else {}))
        t0 = time.perf_counter()
        for lo in range(0, dense.size, 4096):
            chunk = dense[lo:lo + 4096]
            cache.access_many(chunk)
            tuner.observe_many(chunk)
        us = 1e6 * (time.perf_counter() - t0) / dense.size
        rows.append(common.row(f"fig_policy_tuning/{pol}/tuned_mr", us,
                               cache.miss_ratio))
        rows.append(common.row(
            f"fig_policy_tuning/{pol}/applied", 0.0,
            sum(1 for d in tuner.decisions if d.applied)))
    return rows
