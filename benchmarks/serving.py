"""Serving-scheduler benchmarks (model-free, pure virtual time).

``perf_sched_tick`` — scheduler decision overhead: wall-microseconds per
virtual tick driving the ``SimExecutor`` through a saturating mixed-class
workload (the us/tick cost a real engine pays on top of its JAX steps).

``fig_sched_slo`` — the headline claim of the scheduler PR: on the same
3x-overload arrival trace, deadline attainment of the high-priority
class under the admission-controlled scheduler vs the old synchronous
FIFO loop (head-of-line blocking).  Also emits a stable 64-bit fold of
the full decision stream, which is how CI asserts bit-reproducibility
of the simulated schedule per seed across machines.

Everything here is a pure function of seeds on the integer tick clock —
derived values are exactly reproducible, so baseline.json pins the
attainment gap (scheduler >= 0.99, sync < 0.80) and the schedule hash
at zero tolerance.
"""

from __future__ import annotations

import time
from typing import List, Tuple

from benchmarks import common
from repro.core import traces
from repro.faults.io import Clock
from repro.faults.plan import splitmix64
from repro.serving.admission import (
    ST_COMPLETED, AdmissionConfig, SchedRequest,
)
from repro.serving.scheduler import (
    SchedConfig, Scheduler, SimExecutor, simulate_sync,
)

SEED = 23
MAX_BATCH = 4
MAX_NEW = 8          # service: 1 prefill tick + 7 decode ticks
DEADLINE_SLACK = 40  # ticks of SLO slack for the interactive class

# the SLO-strict admission profile (docs/operations.md "Serving"): aging
# off, so sustained overload never promotes filler work into the
# interactive class — the profile an operator pins when the deadline
# attainment of class 0 is the contract
SLO_ADMISSION = AdmissionConfig(age_ticks=0, queue_bound=256)


def _slo_workload(n: int, load: float,
                  seed: int) -> Tuple[List[SchedRequest], List[int]]:
    """A mixed-class open-loop workload at ``load`` x the engine's
    service capacity (~MAX_BATCH/MAX_NEW sequences per tick).  Every 5th
    request is interactive with a deadline; the rest are deadline-free
    standard/batch filler that FIFO happily runs ahead of it."""
    capacity = MAX_BATCH / MAX_NEW
    gap = 1.0 / (load * capacity)
    reqs, arrivals = [], []
    for i in range(n):
        arr = int(i * gap)
        interactive = i % 5 == 0
        reqs.append(SchedRequest(
            req_id=i, prompt_len=16, max_new=MAX_NEW,
            priority=0 if interactive else 1 + (i % 2),
            deadline=(arr + DEADLINE_SLACK) if interactive else 0,
            tenant=f"t{i % 3}"))
        arrivals.append(arr)
    return reqs, arrivals


def _attainment(finish: dict, reqs: List[SchedRequest]) -> float:
    slo = [r for r in reqs if r.deadline]
    met = sum(1 for r in slo
              if finish.get(r.req_id, None) is not None
              and finish[r.req_id] <= r.deadline)
    return met / max(1, len(slo))


def _log_hash(log) -> int:
    h = 0
    for entry in log:
        for v in entry:
            x = v if isinstance(v, int) else \
                int.from_bytes(str(v).encode(), "little")
            h = splitmix64((h ^ x) & 0xFFFFFFFFFFFFFFFF)
    return h


def fig_sched_slo() -> List[str]:
    rows = []
    n = 150 if common.CI else 400
    for load in (1.0, 2.0, 3.0):
        reqs, arrivals = _slo_workload(n, load, SEED)
        clock = Clock()
        x = SimExecutor(n_blocks=1 << 14, block_size=16, clock=clock)
        sched = Scheduler(x, config=SchedConfig(token_budget=256,
                                                max_batch=MAX_BATCH,
                                                admission=SLO_ADMISSION),
                          clock=clock, seed=SEED)
        t0 = time.perf_counter()
        outs = sched.run(reqs, arrivals)
        dt = time.perf_counter() - t0
        fin = {o.req_id: o.finish for o in outs
               if o.status == ST_COMPLETED}
        tag = f"load{load:.0f}x"
        rows.append(common.row(
            f"fig_sched_slo/{tag}/scheduler",
            1e6 * dt / max(1, clock.now), _attainment(fin, reqs)))
        sync_fin = simulate_sync(
            _slo_workload(n, load, SEED)[0], arrivals,
            max_batch=MAX_BATCH)
        rows.append(common.row(
            f"fig_sched_slo/{tag}/sync", 0.0,
            _attainment(sync_fin, reqs)))
        if load == 3.0:
            # bit-reproducibility: the full decision stream folds to the
            # same 64-bit value on every machine (zero tolerance in CI);
            # a second in-process replay must agree before we pin it
            reqs2, arrivals2 = _slo_workload(n, load, SEED)
            clock2 = Clock()
            sched2 = Scheduler(
                SimExecutor(n_blocks=1 << 14, block_size=16, clock=clock2),
                config=SchedConfig(token_budget=256, max_batch=MAX_BATCH,
                                   admission=SLO_ADMISSION),
                clock=clock2, seed=SEED)
            sched2.run(reqs2, arrivals2)
            replayed = _log_hash(sched2.schedule_log) \
                == _log_hash(sched.schedule_log)
            rows.append(common.row(
                "fig_sched_slo/schedule_hash", 0.0,
                int(_log_hash(sched.schedule_log) % 1_000_000)
                if replayed else "NONDETERMINISTIC"))
    return rows


def perf_sched_tick() -> List[str]:
    """us per scheduler tick on a saturating arrival trace (decision
    cost only — the SimExecutor's prefill/decode are dict updates)."""
    rows = []
    n = 400 if common.CI else 2000
    arrivals = traces.make_trace("arrivals-poisson", n=n, seed=SEED,
                                 mean_gap=0.5).tolist()
    reqs = [SchedRequest(req_id=i, prompt_len=24, max_new=4,
                         priority=i % 3, tenant=f"t{i % 4}")
            for i in range(n)]
    clock = Clock()
    x = SimExecutor(n_blocks=1 << 12, block_size=16, clock=clock)
    sched = Scheduler(x, config=SchedConfig(token_budget=128,
                                            max_batch=8),
                      clock=clock, seed=SEED)
    t0 = time.perf_counter()
    outs = sched.run(reqs, arrivals)
    dt = time.perf_counter() - t0
    done = sum(1 for o in outs if o.status == ST_COMPLETED)
    rows.append(common.row("perf/sched/tick_us",
                           1e6 * dt / max(1, clock.now),
                           float(clock.now)))
    rows.append(common.row("perf/sched/completed_frac", 0.0,
                           done / n))
    return rows
