"""Performance benchmarks: cache-hit CPU overhead (paper §1 goal), lane
scalability of the vectorized engine, serving throughput, kernel-oracle
throughput on CPU."""

from __future__ import annotations

import time
from pathlib import Path
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import jax_engine as je
from repro.core import make_policy
from repro.core.prodcache import ProdClock2QPlus

REPO = Path(__file__).resolve().parents[1]


def perf_cpu_overhead() -> List[str]:
    """us per access at ~100% hit ratio (the paper's low-overhead goal) and
    under churn, python reference vs production array implementation."""
    rows = []
    hot = np.tile(np.arange(64), 4000)          # ~100% hits after warmup
    rng = np.random.default_rng(0)
    churn = rng.integers(0, 4096, 256_000)      # high miss ratio
    for impl, mk in (("ref", lambda: make_policy("clock2q+", 1024)),
                     ("prod", lambda: ProdClock2QPlus(1024))):
        for wname, w in (("hot", hot), ("churn", churn)):
            pol = mk()
            acc = pol.access
            t0 = time.perf_counter()
            for k in w:
                acc(int(k))
            us = 1e6 * (time.perf_counter() - t0) / len(w)
            rows.append(common.row(f"perf/cpu/{impl}/{wname}", us,
                                   len(w)))
    return rows


def perf_obs_overhead() -> List[str]:
    """Hit-path cost of the obs layer: fully instrumented
    ``ProdClock2QPlus`` vs the same cache under a ``NullSink``, replaying
    an all-hot trace (the line-rate path the paper optimizes).  The
    instrumented/null wall-time ratio is the gated row —
    ``perf/obs/ratio`` <= 1.05x in baseline.json — so any future
    instrumentation that sneaks work onto the hit path fails CI.

    Also produces the CI telemetry artifact: a 2-thread sharded replay
    with tuner + rebalance activity, its merged snapshot written as
    ``experiments/obs_snapshot.json`` (+ ``.prom``) and rendered through
    tools/obsreport.py to prove the report path works end to end."""
    import sys

    from repro.obs import NullSink
    from repro.obs import export as obs_export
    from repro.shardcache import ShardedClock2QPlus
    from repro.shardcache.replay import replay_threaded
    from repro.tuning import OnlineTuner

    rows = []
    rng = np.random.default_rng(3)
    warm = rng.integers(0, 2048, 16_000).tolist()  # populate (untimed)
    hot = np.tile(np.arange(256), 400).tolist()    # ~100% hits (timed)

    def run_once(pol) -> float:
        acc = pol.access
        for k in warm:
            acc(k)
        t0 = time.perf_counter()
        for k in hot:
            acc(k)
        return time.perf_counter() - t0

    # interleaved best-of-5: same machine noise hits both variants
    best = {"instrumented": float("inf"), "null": float("inf")}
    for _ in range(5):
        best["instrumented"] = min(
            best["instrumented"], run_once(ProdClock2QPlus(1024)))
        best["null"] = min(
            best["null"],
            run_once(ProdClock2QPlus(1024, obs=NullSink(src="cache"))))
    us_i = 1e6 * best["instrumented"] / len(hot)
    us_n = 1e6 * best["null"] / len(hot)
    rows.append(common.row("perf/obs/instrumented", us_i, len(hot)))
    rows.append(common.row("perf/obs/null", us_n, len(hot)))
    # the gate: ratio rides the us column (us_factor rules are one-sided)
    rows.append(common.row("perf/obs/ratio", us_i / max(1e-12, us_n),
                           us_i))

    # -- CI telemetry artifact ------------------------------------------------
    cache = ShardedClock2QPlus(512, n_shards=4, max_capacity=1024)
    tuner = OnlineTuner(cache, retune_every=16_384,
                        window_fracs=(0.1, 0.5, 1.0), min_gain=-1.0,
                        confirm_rounds=1, obs=cache.obs)
    art = (rng.zipf(1.2, 32_768) % 4096).astype(np.int64)
    replay_threaded(cache, art, n_threads=2, batch_size=512,
                    obs=cache.obs)
    tuner.observe_many(art)
    # a deterministic rebalance + retune so the artifact always carries
    # the full event vocabulary, whatever the tuner decided organically
    caps = [s.capacity for s in cache.shards]
    cache.set_shard_capacities([caps[0] + 16, caps[1] - 16] + caps[2:])
    while not cache.rebalance_step(128):
        pass
    cache.retune(window_frac=0.3)
    snap = cache.obs_snapshot()
    out_json = REPO / "experiments" / "obs_snapshot.json"
    out_json.parent.mkdir(parents=True, exist_ok=True)
    out_json.write_text(snap.to_json(indent=1))
    (REPO / "experiments" / "obs_snapshot.prom").write_text(
        obs_export.to_prometheus(snap))
    sys.path.insert(0, str(REPO / "tools"))
    import obsreport
    report = obsreport.render(
        obs_export.Snapshot.from_json(out_json.read_text()))
    assert "cache_hits_total" in report
    rows.append(common.row("perf/obs/snapshot_series", 0.0,
                           len(snap.counters) + len(snap.gauges)
                           + len(snap.hists)))
    rows.append(common.row("perf/obs/snapshot_events", 0.0,
                           len(snap.events)))
    return rows


def perf_jax_engine() -> List[str]:
    """Vectorized-simulation throughput and lane scaling (the TPU
    adaptation of the paper's multi-core scalability)."""
    rows = []
    rng = np.random.default_rng(1)
    T = 50_000
    for lanes in (1, 4, 8):
        traces_np = rng.integers(0, 2048, (lanes, T)).astype(np.int32)
        states = jax.vmap(lambda _: je.init_state("clock2q+", 256, 2048))(
            jnp.arange(lanes))
        tr = jnp.asarray(traces_np)
        _, hits = je.replay_batch("clock2q+", states, tr)  # compile
        jax.block_until_ready(hits)
        t0 = time.perf_counter()
        _, hits = je.replay_batch("clock2q+", states, tr)
        jax.block_until_ready(hits)
        dt = time.perf_counter() - t0
        us = 1e6 * dt / (lanes * T)
        rows.append(common.row(f"perf/jax_engine/lanes{lanes}", us,
                               lanes * T / dt))
    return rows


def perf_serving() -> List[str]:
    """Paged-serving decode throughput on the reduced model (CPU)."""
    from repro.configs import get_config, reduced
    from repro.models.model import build
    from repro.serving.engine import Request, ServingEngine
    rows = []
    cfg = reduced(get_config("granite-3-8b"))
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    prefix = list(rng.integers(0, cfg.vocab, 16))
    reqs = [Request(i, prefix + list(rng.integers(0, cfg.vocab, 8)),
                    max_new=8) for i in range(8)]
    eng = ServingEngine(api, params, block_size=8, hbm_blocks=48,
                        max_batch=4)
    t0 = time.perf_counter()
    outs = eng.run(reqs)
    dt = time.perf_counter() - t0
    n_tok = sum(len(c.tokens) for c in outs)
    stats, flows = eng.stats
    rows.append(common.row("perf/serving/tokens_per_s",
                           1e6 * dt / max(1, n_tok), n_tok / dt))
    rows.append(common.row("perf/serving/prefix_hit_ratio", 0.0,
                           stats.hit_ratio))
    return rows


def perf_train_step() -> List[str]:
    """Reduced-model train-step walltime (CPU) — framework overhead check."""
    from repro.configs import get_config, reduced
    from repro.launch.specs import make_batch
    from repro.models.config import ShapeCell
    from repro.models.model import build
    from repro.training import optim, step as step_lib
    rows = []
    cfg = reduced(get_config("olmo-1b"))
    api = build(cfg)
    oc = optim.AdamWConfig()
    state = step_lib.init_train_state(api, jax.random.PRNGKey(0), oc)
    step = jax.jit(step_lib.make_train_step(
        api, step_lib.RunConfig(adamw=oc)))
    batch = make_batch(cfg, ShapeCell("t", 64, 8, "train"), seed=1)
    state, m = step(state, batch)            # compile
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    for _ in range(3):
        state, m = step(state, batch)
    jax.block_until_ready(m["loss"])
    dt = (time.perf_counter() - t0) / 3
    tokens = 64 * 8
    rows.append(common.row("perf/train_step/reduced_olmo", 1e6 * dt,
                           tokens / dt))
    return rows
