"""Performance benchmarks: cache-hit CPU overhead (paper §1 goal), lane
scalability of the vectorized engine, serving throughput, kernel-oracle
throughput on CPU."""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import jax_engine as je
from repro.core import make_policy
from repro.core.prodcache import ProdClock2QPlus


def perf_cpu_overhead() -> List[str]:
    """us per access at ~100% hit ratio (the paper's low-overhead goal) and
    under churn, python reference vs production array implementation."""
    rows = []
    hot = np.tile(np.arange(64), 4000)          # ~100% hits after warmup
    rng = np.random.default_rng(0)
    churn = rng.integers(0, 4096, 256_000)      # high miss ratio
    for impl, mk in (("ref", lambda: make_policy("clock2q+", 1024)),
                     ("prod", lambda: ProdClock2QPlus(1024))):
        for wname, w in (("hot", hot), ("churn", churn)):
            pol = mk()
            acc = pol.access
            t0 = time.perf_counter()
            for k in w:
                acc(int(k))
            us = 1e6 * (time.perf_counter() - t0) / len(w)
            rows.append(common.row(f"perf/cpu/{impl}/{wname}", us,
                                   len(w)))
    return rows


def perf_jax_engine() -> List[str]:
    """Vectorized-simulation throughput and lane scaling (the TPU
    adaptation of the paper's multi-core scalability)."""
    rows = []
    rng = np.random.default_rng(1)
    T = 50_000
    for lanes in (1, 4, 8):
        traces_np = rng.integers(0, 2048, (lanes, T)).astype(np.int32)
        states = jax.vmap(lambda _: je.init_state("clock2q+", 256, 2048))(
            jnp.arange(lanes))
        tr = jnp.asarray(traces_np)
        _, hits = je.replay_batch("clock2q+", states, tr)  # compile
        jax.block_until_ready(hits)
        t0 = time.perf_counter()
        _, hits = je.replay_batch("clock2q+", states, tr)
        jax.block_until_ready(hits)
        dt = time.perf_counter() - t0
        us = 1e6 * dt / (lanes * T)
        rows.append(common.row(f"perf/jax_engine/lanes{lanes}", us,
                               lanes * T / dt))
    return rows


def perf_serving() -> List[str]:
    """Paged-serving decode throughput on the reduced model (CPU)."""
    from repro.configs import get_config, reduced
    from repro.models.model import build
    from repro.serving.engine import Request, ServingEngine
    rows = []
    cfg = reduced(get_config("granite-3-8b"))
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    prefix = list(rng.integers(0, cfg.vocab, 16))
    reqs = [Request(i, prefix + list(rng.integers(0, cfg.vocab, 8)),
                    max_new=8) for i in range(8)]
    eng = ServingEngine(api, params, block_size=8, hbm_blocks=48,
                        max_batch=4)
    t0 = time.perf_counter()
    outs = eng.run(reqs)
    dt = time.perf_counter() - t0
    n_tok = sum(len(c.tokens) for c in outs)
    stats, flows = eng.stats
    rows.append(common.row("perf/serving/tokens_per_s",
                           1e6 * dt / max(1, n_tok), n_tok / dt))
    rows.append(common.row("perf/serving/prefix_hit_ratio", 0.0,
                           stats.hit_ratio))
    return rows


def perf_train_step() -> List[str]:
    """Reduced-model train-step walltime (CPU) — framework overhead check."""
    from repro.configs import get_config, reduced
    from repro.launch.specs import make_batch
    from repro.models.config import ShapeCell
    from repro.models.model import build
    from repro.training import optim, step as step_lib
    rows = []
    cfg = reduced(get_config("olmo-1b"))
    api = build(cfg)
    oc = optim.AdamWConfig()
    state = step_lib.init_train_state(api, jax.random.PRNGKey(0), oc)
    step = jax.jit(step_lib.make_train_step(
        api, step_lib.RunConfig(adamw=oc)))
    batch = make_batch(cfg, ShapeCell("t", 64, 8, "train"), seed=1)
    state, m = step(state, batch)            # compile
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    for _ in range(3):
        state, m = step(state, batch)
    jax.block_until_ready(m["loss"])
    dt = (time.perf_counter() - t0) / 3
    tokens = 64 * 8
    rows.append(common.row("perf/train_step/reduced_olmo", 1e6 * dt,
                           tokens / dt))
    return rows
