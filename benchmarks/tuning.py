"""Tuning-subsystem benchmarks: batched-sweep throughput vs the serial
replay path it replaced, and OnlineTuner convergence on SUITE traces."""

from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks import common
from repro.core import traces
from repro.core.prodcache import ProdClock2QPlus
from repro.tuning import OnlineTuner, sweep_grid
from repro.tuning import profiler
from repro.tuning.sweep import make_grid, serial_sweep_hits, sweep_hits

GRID_WINDOW_FRACS = (0.1, 0.3, 0.5, 1.0)


def _grid_trace() -> np.ndarray:
    tr = common.meta_trace(traces.SUITE[0])
    return tr if common.FULL else tr[:60_000]


def perf_sweep_grid() -> List[str]:
    """The tentpole measurement: a full >=8x4 MRC grid (capacities x
    correlation windows) in ONE jitted call vs one replay per config."""
    rows = []
    tr = _grid_trace()
    fp = traces.footprint(tr)
    caps = [max(8, int(fp * f))
            for f in (0.005, 0.01, 0.02, 0.05, 0.1, 0.2, 0.35, 0.5)]
    grid = make_grid(caps, GRID_WINDOW_FRACS)
    t0 = time.perf_counter()
    hb = sweep_hits(tr, grid)
    t_b = time.perf_counter() - t0
    t0 = time.perf_counter()
    sweep_hits(tr, grid)           # jit-cached: the tuner's steady state
    t_warm = time.perf_counter() - t0
    t0 = time.perf_counter()
    hs = serial_sweep_hits(tr, grid)
    t_s = time.perf_counter() - t0
    n = len(tr) * len(grid)
    rows.append(common.row("perf/sweep_grid/batched", 1e6 * t_b / n,
                           len(grid)))
    rows.append(common.row("perf/sweep_grid/batched_warm", 1e6 * t_warm / n,
                           len(grid)))
    rows.append(common.row("perf/sweep_grid/serial", 1e6 * t_s / n,
                           len(grid)))
    rows.append(common.row("perf/sweep_grid/speedup", 0.0, t_s / max(t_b, 1e-9)))
    rows.append(common.row("perf/sweep_grid/speedup_warm", 0.0,
                           t_s / max(t_warm, 1e-9)))
    rows.append(common.row("perf/sweep_grid/max_abs_hit_diff", 0.0,
                           int(np.abs(hb - hs).max())))
    return rows


def fig_sampled_mrc() -> List[str]:
    """Profiler fidelity: sampled-MRC estimation error vs the exact MRC
    (max abs error over the capacity curve, per trace)."""
    rows = []
    for spec in common.suite()[:3]:
        tr = common.meta_trace(spec)
        if not common.FULL:
            tr = tr[:120_000]
        fp = traces.footprint(tr)
        caps = [max(8, int(fp * f)) for f in (0.01, 0.02, 0.05, 0.1)]
        grid = make_grid(caps)
        exact = sweep_grid(tr, grid)
        t0 = time.perf_counter()
        est = profiler.estimate_sweep(tr, grid, rate_shift=5)
        us = 1e6 * (time.perf_counter() - t0) / len(tr)
        err = float(np.nanmax(np.abs(est - exact)))
        rows.append(common.row(
            f"fig_sampled_mrc/{spec.name}/max_abs_err", us, err))
    return rows


def fig_tuner_converge() -> List[str]:
    """OnlineTuner convergence: start a live cache at a deliberately bad
    correlation window, replay a SUITE trace through it with the tuner
    observing, then score the tuner's final configuration on the full
    trace vs the best offline fig13-style sweep value (gap in pp)."""
    rows = []
    wfs = (0.1, 0.3, 0.5, 1.0)
    for spec in common.suite()[:3]:
        tr = common.meta_trace(spec)
        if not common.FULL:
            tr = tr[:120_000]
        cap = traces.suite_capacity(tr)
        offline = sweep_grid(tr, make_grid([cap], wfs))
        best = float(offline.min())
        cache = ProdClock2QPlus(cap, window_frac=8.0)  # deliberately bad
        tuner = OnlineTuner(cache, window_fracs=wfs, retune_every=30_000,
                            rate_shift=5, min_gain=0.001)
        t0 = time.perf_counter()
        for k in tr:
            cache.access(int(k))
            tuner.observe(int(k))
        us = 1e6 * (time.perf_counter() - t0) / len(tr)
        final_wf = cache.tuning["window_frac"]
        final = float(sweep_grid(tr, make_grid([cap], [final_wf]))[0])
        rows.append(common.row(
            f"fig_tuner/{spec.name}/gap_pp", us, 100.0 * (final - best)))
        rows.append(common.row(
            f"fig_tuner/{spec.name}/final_window", 0.0, final_wf))
    return rows
