"""Paper-table/figure reproductions (one function per artifact).

Each returns a list of CSV rows; benchmarks.run drives them all.
"""

from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks import common
from repro.core import stats, traces
from repro.core.btree import btree_metadata_trace

# the zoo evaluated in Fig. 8 (paper evaluates 10 SOTA algorithms);
# clock2q+a is our beyond-paper adaptive variant (EXPERIMENTS.md §Perf)
ZOO = ["fifo", "lru", "clock", "slru", "lfu", "sieve", "lirs", "arc",
       "wtinylfu", "2q", "clock2q", "s3fifo", "clock2q+", "clock2q+a"]
HEADLINE = ["clock", "arc", "s3fifo", "clock2q+"]


def fig7_fidelity() -> List[str]:
    """Metadata-trace fidelity: btree-replay vs divide-by-fanout."""
    rows = []
    U = 1 << 16
    data = traces.storage_data_trace(80_000, universe=U, seed=5)
    m_div = traces.derive_metadata(data, 200)
    t0 = time.perf_counter()
    m_bt = btree_metadata_trace(data, 200, universe=U)
    us = 1e6 * (time.perf_counter() - t0) / len(data)
    fp = traces.footprint(m_div)
    for algo in ("clock2q+", "s3fifo"):
        for frac in (0.02, 0.05, 0.1):
            cap = max(10, int(frac * fp))
            a = stats.simulate(algo, m_div, cap).miss_ratio
            b = stats.simulate(algo, m_bt, cap).miss_ratio
            rows.append(common.row(
                f"fig7/{algo}/frac{frac}/abs_mr_diff", us, abs(a - b)))
    return rows


def _improvements(trace, fracs, algos) -> dict:
    fp = traces.footprint(trace)
    out = {}
    for frac in fracs:
        cap = max(10, int(frac * fp))
        mrs = {}
        for algo in algos + ["clock"]:
            r, us = common.timed_sim(algo, trace, cap)
            mrs[algo] = (r.miss_ratio, us)
        base = mrs["clock"][0]
        for algo in algos:
            mr, us = mrs[algo]
            out[(algo, frac)] = ((base - mr) / max(base, 1e-12), us)
    return out


def fig8_improvements() -> List[str]:
    """Miss-ratio improvement over Clock (Eq. 1), metadata + data traces."""
    rows = []
    agg = {}
    for kind, get in (("meta", common.meta_trace), ("data",
                                                    common.data_trace)):
        fracs = (0.01, 0.1) if kind == "meta" else (0.01, 0.05)
        for spec in common.suite():
            imp = _improvements(get(spec), fracs, ZOO)
            for (algo, frac), (v, us) in imp.items():
                agg.setdefault((kind, algo), []).append(v)
                agg.setdefault((kind, algo, frac), []).append(v)
        for algo in ZOO:
            vals = agg[(kind, algo)]
            rows.append(common.row(
                f"fig8/{kind}/{algo}/mean_improvement", 0.0,
                float(np.mean(vals))))
            for frac in fracs:  # per-size means: the paper's regime split
                rows.append(common.row(
                    f"fig8/{kind}/{algo}/frac{frac}/mean_improvement", 0.0,
                    float(np.mean(agg[(kind, algo, frac)]))))
    # headline: Clock2Q+ vs S3-FIFO relative miss-ratio reduction (meta)
    rows.append(common.row(
        "fig8/meta/clock2q+_vs_s3fifo/max_rel_reduction", 0.0,
        _headline_gap()))
    return rows


def _headline_gap() -> float:
    best = 0.0
    for spec in common.suite():
        meta = common.meta_trace(spec)
        fp = traces.footprint(meta)
        for frac in (0.05, 0.1):
            cap = max(10, int(frac * fp))
            mrs = stats.miss_ratios(["clock2q+", "s3fifo"], meta, cap)
            if mrs["s3fifo"] > 0:
                best = max(best, (mrs["s3fifo"] - mrs["clock2q+"])
                           / mrs["s3fifo"])
    return best


def fig9_mrc() -> List[str]:
    """Miss-ratio curves (metadata + data) for the headline algorithms."""
    rows = []
    spec = common.suite()[0]
    for kind, tr in (("meta", common.meta_trace(spec)),
                     ("data", common.data_trace(spec))):
        fp = traces.footprint(tr)
        sizes = [max(8, int(fp * f))
                 for f in (0.01, 0.02, 0.05, 0.1, 0.25, 0.5, 1.0)]
        for algo in HEADLINE:
            curve = stats.mrc(algo, tr, sizes)
            auc = float(np.mean(list(curve.values())))
            rows.append(common.row(f"fig9/{kind}/{algo}/mean_mr_over_sizes",
                                   0.0, auc))
            for c, mr in curve.items():
                rows.append(common.row(f"fig9/{kind}/{algo}/size{c}", 0.0,
                                       mr))
    return rows


def table1_fig10_flows() -> List[str]:
    """Queue-flow counts + next-reuse-distance of moved blocks."""
    rows = []
    spec = common.suite()[1]
    meta = common.meta_trace(spec)
    fp = traces.footprint(meta)
    cap = max(10, int(0.05 * fp))
    for algo in ("clock2q+", "s3fifo"):
        res, counts, flows = stats.flow_nrd(algo, meta, cap)
        for kind in ("small_to_main", "small_to_ghost", "ghost_to_main"):
            rows.append(common.row(f"table1/{algo}/{kind}", 0.0,
                                   counts.get(kind, 0)))
            ds = [d for d in flows.get(kind, []) if d < (1 << 60)]
            med = float(np.median(ds)) if ds else -1.0
            rows.append(common.row(f"fig10/{algo}/{kind}/median_nrd", 0.0,
                                   med))
    return rows


def fig11_dirty() -> List[str]:
    """Simplified vs accurate dirty handling (30% writes)."""
    rows = []
    for spec in common.suite()[:2]:
        meta = common.meta_trace(spec)
        fp = traces.footprint(meta)
        dirty_fn = common.write_dirty(meta)
        for frac in (0.01, 0.05, 0.1):
            cap = max(10, int(frac * fp))
            mrs = {}
            for mode in ("simplified", "accurate"):
                r = stats.simulate("clock2q+", meta, cap, dirty_fn=dirty_fn,
                                   dirty_mode=mode, flush_after=2_000)
                mrs[mode] = r.miss_ratio
            imp = (mrs["accurate"] - mrs["simplified"]) \
                / max(mrs["accurate"], 1e-12)
            rows.append(common.row(
                f"fig11/{spec.name}/frac{frac}/simplified_vs_accurate",
                0.0, imp))
    return rows


def fig12_skiplimit() -> List[str]:
    """Bounding clock-hand reinsertions per eviction."""
    rows = []
    spec = common.suite()[0]
    meta = common.meta_trace(spec)
    fp = traces.footprint(meta)
    cap = max(10, int(0.05 * fp))
    base = None
    for limit in (None, 1000, 100, 10):
        pol_kw = {"skip_limit": limit}
        r, us = common.timed_sim("clock2q+", meta, cap, **pol_kw)
        name = "inf" if limit is None else str(limit)
        if base is None:
            base = r.miss_ratio
        rows.append(common.row(f"fig12/limit_{name}/mr_delta_vs_inf", us,
                               r.miss_ratio - base))
    # mean skipped blocks per eviction (Fig. 12a)
    from repro.core import make_policy
    pol = make_policy("clock2q+", cap)
    pol.run(meta)
    skipped = pol.main.skipped_per_eviction
    rows.append(common.row("fig12a/mean_skipped_per_eviction", 0.0,
                           float(np.mean(skipped)) if skipped else 0.0))
    return rows


FIG13_WINDOW_FRACS = (0.1, 0.3, 0.5)


def fig13_window() -> List[str]:
    """Correlation-window size sensitivity (10/30/50% of Small FIFO).

    Fast path: the whole (capacity x window) grid per trace runs as ONE
    jitted batched sweep (repro.tuning.sweep) instead of serial
    per-configuration replays; the replaced serial path is timed
    alongside so the speedup lands in the bench output
    (``fig13_speed/*`` rows, gated loosely by CI)."""
    from repro.tuning import sweep as tsweep
    rows = []
    for si, spec in enumerate(common.suite()[:2]):
        meta = common.meta_trace(spec)
        fp = traces.footprint(meta)
        caps = [max(10, int(frac * fp)) for frac in (0.01, 0.1)]
        bases = {cap: stats.simulate("clock", meta, cap).miss_ratio
                 for cap in caps}
        grid = tsweep.make_grid(caps, FIG13_WINDOW_FRACS)
        t0 = time.perf_counter()
        mrs = tsweep.sweep_grid(meta, grid)
        t_batched = time.perf_counter() - t0
        if si == 0:
            # before/after wall time, first spec only (the serial paths
            # are exactly what the batched call replaces): the engine's
            # per-config replays, plus the pure-Python simulations the
            # pre-batched fig13 ran, for reference
            t0 = time.perf_counter()
            serial_hits = tsweep.serial_sweep_hits(meta, grid)
            t_jax_serial = time.perf_counter() - t0
            assert (np.abs(1.0 - serial_hits / len(meta) - mrs)
                    < 1e-9).all(), "batched sweep diverged from serial replay"
            t0 = time.perf_counter()
            for cfg in grid:
                stats.simulate("clock2q+", meta, cfg.capacity,
                               window_frac=cfg.window_frac)
            t_py_serial = time.perf_counter() - t0
            n_req = len(meta) * len(grid)
            rows.append(common.row("fig13_speed/serial_jax_replays",
                                   1e6 * t_jax_serial / n_req, t_jax_serial))
            rows.append(common.row("fig13_speed/serial_python_sims",
                                   1e6 * t_py_serial / n_req, t_py_serial))
            rows.append(common.row("fig13_speed/batched_sweep",
                                   1e6 * t_batched / n_req, t_batched))
            rows.append(common.row(
                "fig13_speed/speedup_vs_serial_jax", 0.0,
                t_jax_serial / max(t_batched, 1e-9)))
        for i, (cfg, mr) in enumerate(zip(grid, mrs)):
            # make_grid is capacity-major: lanes [0, n_wf) belong to
            # caps[0] (frac 0.01), the rest to caps[1] (frac 0.1)
            frac = 0.01 if i < len(FIG13_WINDOW_FRACS) else 0.1
            base = bases[cfg.capacity]
            imp = (base - mr) / max(base, 1e-12)
            rows.append(common.row(
                f"fig13/{spec.name}/frac{frac}/window"
                f"{int(cfg.window_frac*100)}", 0.0, imp))
    return rows


def fig14_nonblock() -> List[str]:
    """Non-block (object/key-value) workloads."""
    rows = []
    for seed, alpha in ((1, 1.2), (2, 0.9), (3, 1.4)):
        tr = traces.object_trace(200_000, universe=1 << 16, alpha=alpha,
                                 seed=seed)
        fp = traces.footprint(tr)
        for frac in (0.05, 0.1):
            cap = max(10, int(frac * fp))
            base = stats.simulate("clock", tr, cap).miss_ratio
            for algo in ("s3fifo", "clock2q+", "arc"):
                r = stats.simulate(algo, tr, cap)
                imp = (base - r.miss_ratio) / max(base, 1e-12)
                rows.append(common.row(
                    f"fig14/obj-a{alpha}/frac{frac}/{algo}", 0.0, imp))
    return rows
