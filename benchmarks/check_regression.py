"""Benchmark-regression gate for CI.

Compares a benchmark CSV (``benchmarks.run`` output) against the
committed ``benchmarks/baseline.json`` under that file's explicit
tolerance rules, writes a ``BENCH_ci.json`` verdict report, and exits
non-zero on any regression.

    # gate (what the bench-regression CI job runs)
    REPRO_BENCH_CI=1 python -m benchmarks.run --only fig7,fig13,fig_scenario_matrix,fig_policy_tuning,perf_cpu,perf_obs,perf_sweep_grid
    python -m benchmarks.check_regression --out BENCH_ci.json

    # refresh the baseline after an intentional change (same bench run,
    # then rewrite baseline rows, keeping the tolerance rules)
    python -m benchmarks.check_regression --update

Tolerance rules (first matching ``prefix`` wins):
  * ``ignore``       — row must exist, values not gated (timing rows)
  * ``derived_abs``  — |derived - baseline| <= tol (miss ratios &c.)
  * ``us_factor``    — us_per_call <= max(us_floor, baseline * factor)
                       (wall-clock: generous, CI machines vary)
Rows missing from the run fail; rows new in the run are reported but
never fail (commit them to the baseline when intentional).
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
DEFAULT_CSV = REPO / "experiments" / "bench_results.csv"
DEFAULT_BASELINE = REPO / "benchmarks" / "baseline.json"


def parse_csv(path: Path) -> dict:
    rows = {}
    lines = path.read_text().strip().splitlines()
    for line in lines[1:]:  # skip header
        parts = line.split(",", 2)
        if len(parts) != 3:
            continue  # continuation line of a multi-line ERROR message
        name, us, derived = parts
        try:
            us = float(us)
        except ValueError:
            continue  # not a data row
        try:
            derived = float(derived)
        except ValueError:
            pass  # error strings stay strings (and fail value gates)
        rows[name] = {"us": us, "derived": derived}
    return rows


def rule_for(name: str, tolerances: list) -> dict:
    for rule in tolerances:
        if name.startswith(rule["prefix"]):
            return rule
    return {"prefix": "", "ignore": True}


def check_row(name: str, base: dict, run: dict, rule: dict) -> list:
    """Failure strings for one row (empty = pass)."""
    if rule.get("ignore"):
        return []
    fails = []
    if "derived_abs" in rule:
        b, r = base["derived"], run["derived"]
        if isinstance(b, float) and isinstance(r, float):
            if abs(r - b) > rule["derived_abs"]:
                fails.append(
                    f"{name}: derived {r:.6f} vs baseline {b:.6f} "
                    f"(tol {rule['derived_abs']})")
        elif b != r:
            fails.append(f"{name}: derived {r!r} vs baseline {b!r}")
    if "us_factor" in rule:
        cap = max(rule.get("us_floor", 0.0), base["us"] * rule["us_factor"])
        if run["us"] > cap:
            fails.append(
                f"{name}: us_per_call {run['us']:.3f} > {cap:.3f} "
                f"(baseline {base['us']:.3f} x {rule['us_factor']})")
    return fails


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--csv", type=Path, default=DEFAULT_CSV)
    ap.add_argument("--baseline", type=Path, default=DEFAULT_BASELINE)
    ap.add_argument("--out", type=Path, default=None,
                    help="write a BENCH_ci.json verdict report here")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline's rows from --csv "
                         "(tolerance rules are kept)")
    args = ap.parse_args()

    baseline = json.loads(args.baseline.read_text())
    rows = parse_csv(args.csv)

    if args.update:
        keep = [n for n in rows if not n.endswith("/ERROR")]
        baseline["rows"] = {n: rows[n] for n in sorted(keep)}
        args.baseline.write_text(json.dumps(baseline, indent=1) + "\n")
        print(f"baseline updated: {len(keep)} rows -> {args.baseline}")
        return 0

    failures, checked, verdicts = [], 0, {}
    for name, base in baseline["rows"].items():
        rule = rule_for(name, baseline["tolerances"])
        if name not in rows:
            failures.append(f"{name}: missing from benchmark run")
            verdicts[name] = "missing"
            continue
        fails = check_row(name, base, rows[name], rule)
        checked += 1
        verdicts[name] = "fail" if fails else (
            "ignored" if rule.get("ignore") else "pass")
        failures.extend(fails)
    new_rows = sorted(set(rows) - set(baseline["rows"]))

    report = {
        "pass": not failures,
        "checked": checked,
        "baseline_rows": len(baseline["rows"]),
        "failures": failures,
        "new_rows": new_rows,
        "verdicts": verdicts,
    }
    if args.out:
        args.out.write_text(json.dumps(report, indent=1) + "\n")
    for f in failures:
        print(f"REGRESSION {f}", file=sys.stderr)
    if new_rows:
        print(f"note: {len(new_rows)} rows not in baseline "
              f"(e.g. {new_rows[:3]})")
    print(f"bench-regression: {checked}/{len(baseline['rows'])} rows "
          f"checked, {len(failures)} failure(s)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
