"""Sharded-cache benchmarks: multi-thread replay throughput (the paper's
multi-CPU scalability experiment, §5) and sharding fidelity (miss-ratio
delta vs the unsharded cache at equal total capacity)."""

from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks import common
from repro.core import jax_engine as je, traces
from repro.core.traces import suite_capacity
from repro.shardcache import (
    ShardedClock2QPlus, scalability_sweep, unsharded_miss_ratio,
)

SHARD_COUNTS = (2, 4, 8)
THREADS = (1, 2, 4, 8)


def _bench_trace(spec, limit: int) -> np.ndarray:
    tr = common.meta_trace(spec)
    return tr if common.FULL else tr[:limit]


def perf_shard_scalability() -> List[str]:
    """Replay throughput of the 8-shard service at 1/2/4/8 worker threads
    (fresh cache per thread count; wall-clock includes lock contention)."""
    rows = []
    spec = traces.SUITE[0]
    tr = _bench_trace(spec, 200_000)
    cap = suite_capacity(tr)
    for r in scalability_sweep(tr, cap, n_shards=8, threads=THREADS):
        rows.append(common.row(
            f"perf/shard/{spec.name}/threads{r.n_threads}",
            r.us_per_access, r.throughput))
    return rows


def fig_shard_fidelity() -> List[str]:
    """Miss-ratio delta (percentage points) of the sharded service vs the
    unsharded ProdClock2QPlus at equal total capacity, across the SUITE."""
    rows = []
    for spec in common.suite():
        tr = _bench_trace(spec, 150_000)
        cap = suite_capacity(tr)
        t0 = time.perf_counter()
        base = unsharded_miss_ratio(tr, cap)
        us = 1e6 * (time.perf_counter() - t0) / len(tr)
        rows.append(common.row(f"fig_shard/{spec.name}/shards1", us, base))
        for n in SHARD_COUNTS:
            sh = ShardedClock2QPlus(cap, n_shards=n)
            t0 = time.perf_counter()
            hits = sh.access_many(tr)
            us = 1e6 * (time.perf_counter() - t0) / len(tr)
            delta_pp = 100.0 * abs((1.0 - hits.mean()) - base)
            rows.append(common.row(
                f"fig_shard/{spec.name}/shards{n}/delta_pp", us, delta_pp))
    return rows


def fig_shard_jax_fidelity() -> List[str]:
    """Same fidelity question answered by the vectorized engine: partition
    the trace by key hash, vmap the per-shard lanes, merge hit arrays."""
    rows = []
    for spec in common.suite()[:3]:
        tr = _bench_trace(spec, 150_000)
        cap = suite_capacity(tr)
        universe = int(tr.max()) + 1
        _, base = je.replay_np("clock2q+", tr, cap, universe=universe)
        for n in SHARD_COUNTS:
            _, mr = je.sharded_replay_np("clock2q+", tr, cap, n,
                                         universe=universe)
            rows.append(common.row(
                f"fig_shard_jax/{spec.name}/shards{n}/delta_pp", 0.0,
                100.0 * abs(mr - base)))
    return rows

