"""Benchmark driver: one reproduction per paper table/figure plus perf
benchmarks.  Prints ``name,us_per_call,derived`` CSV rows (stdout) and
writes them to experiments/bench_results.csv.

  PYTHONPATH=src python -m benchmarks.run            # default scale
  REPRO_BENCH_FULL=1 ... python -m benchmarks.run    # full trace suite
  python -m benchmarks.run --only fig8               # subset
"""

from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

from benchmarks import (faults, paper_figs, perf, scenarios, serving, shard,
                        tuning)

BENCHES = [
    ("fig7", paper_figs.fig7_fidelity),
    ("fig8", paper_figs.fig8_improvements),
    ("fig9", paper_figs.fig9_mrc),
    ("table1", paper_figs.table1_fig10_flows),
    ("fig11", paper_figs.fig11_dirty),
    ("fig12", paper_figs.fig12_skiplimit),
    ("fig13", paper_figs.fig13_window),
    ("fig14", paper_figs.fig14_nonblock),
    ("fig_scenario_matrix", scenarios.fig_scenario_matrix),
    ("fig_sched_slo", serving.fig_sched_slo),
    ("fig_policy_tuning", scenarios.fig_policy_tuning),
    ("fig_shard", shard.fig_shard_fidelity),
    ("fig_shard_jax", shard.fig_shard_jax_fidelity),
    ("fig_sampled_mrc", tuning.fig_sampled_mrc),
    ("fig_tuner", tuning.fig_tuner_converge),
    ("perf_cpu", perf.perf_cpu_overhead),
    ("perf_obs", perf.perf_obs_overhead),
    ("perf_faults", faults.perf_fault_overhead),
    ("perf_journal", faults.perf_journal_append),
    ("perf_failover", faults.perf_failover_rto),
    ("perf_sched_tick", serving.perf_sched_tick),
    ("perf_sweep_grid", tuning.perf_sweep_grid),
    ("perf_shard_scalability", shard.perf_shard_scalability),
    ("perf_engine", perf.perf_jax_engine),
    ("perf_serving", perf.perf_serving),
    ("perf_train", perf.perf_train_step),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated bench-name prefixes")
    args = ap.parse_args()
    only = args.only.split(",") if args.only else None

    out_path = Path(__file__).resolve().parents[1] / "experiments" \
        / "bench_results.csv"
    out_path.parent.mkdir(parents=True, exist_ok=True)
    all_rows = ["name,us_per_call,derived"]
    print(all_rows[0])
    for name, fn in BENCHES:
        if only and not any(name.startswith(o) for o in only):
            continue
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:  # noqa: BLE001
            rows = [f"{name}/ERROR,0,{type(e).__name__}:{e}"]
        for r in rows:
            print(r)
            all_rows.append(r)
        print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)
    out_path.write_text("\n".join(all_rows) + "\n")


if __name__ == "__main__":
    main()
