"""The PolicyEngine protocol surface: registry, multi-policy sweeps,
the EngineCache host facade, and OnlineTuner against non-Clock2Q+
policies."""

import numpy as np
import pytest

import repro.core.engine as engine
from repro.core.engine.host import EngineCache
from repro.tuning import OnlineTuner, make_grid, serial_sweep_hits, sweep_hits


def _trace(seed=0, T=2000, U=300):
    rng = np.random.default_rng(seed)
    out = np.empty(T, np.int64)
    out[0::2] = rng.integers(0, U, T // 2)
    out[1::2] = np.arange(T // 2) % (U // 2)
    return out


# -- registry ------------------------------------------------------------------

def test_registry_contents():
    names = engine.engine_names()
    for p in ("clock2q+", "clock2q", "s3fifo", "fifo", "clock", "lru"):
        assert p in names


def test_unknown_engine_raises():
    with pytest.raises(KeyError, match="no registered lane engine"):
        engine.get_engine("belady")


def test_engine_preset_applies_in_config():
    cfg = engine.get_engine("s3fifo").config(100)
    assert cfg.policy == "s3fifo"
    assert cfg.ghost_frac == 1.0  # preset: full-capacity ghost ring
    cfg2 = engine.get_engine("s3fifo").config(100, ghost_frac=0.25)
    assert cfg2.ghost_frac == 0.25  # explicit kwargs win


# -- multi-policy grids --------------------------------------------------------

def test_grid_init_rejects_mixed_policies():
    c1 = engine.get_engine("clock2q+").config(50)
    c2 = engine.get_engine("s3fifo").config(50)
    with pytest.raises(ValueError, match="ONE policy"):
        engine.grid_init([c1, c2], 128)


def test_sweep_hits_mixed_policy_grid_matches_serial():
    tr = _trace()
    configs = (make_grid([30, 90], window_fracs=(0.2, 1.0))
               + make_grid([30, 90], policy="s3fifo", ghost_fracs=(1.0,))
               + make_grid([30, 90], policy="clock")
               + make_grid([60], policy="s3fifo", ghost_fracs=(1.0,),
                           bits=1))
    batched = sweep_hits(tr, configs)
    serial = serial_sweep_hits(tr, configs)
    np.testing.assert_array_equal(batched, serial)


def test_make_grid_policy_and_bits():
    grid = make_grid([10, 20], policy="s3fifo", bits=1)
    assert all(c.policy == "s3fifo" and c.bits == 1 for c in grid)


# -- EngineCache ---------------------------------------------------------------

def test_engine_cache_matches_replay():
    tr = _trace(seed=3, T=1500, U=200)
    for policy in ("s3fifo", "clock", "clock2q+"):
        cache = EngineCache(policy, 40, 256)
        hits = cache.access_many(tr % 256)
        eng = engine.get_engine(policy)
        st = eng.init(40, 256)
        _, ref = eng.replay(st, np.asarray(tr % 256, np.int32))
        np.testing.assert_array_equal(hits, np.asarray(ref).astype(bool))
        assert cache.hits == int(hits.sum())
        assert cache.hits + cache.misses == tr.size
        assert 0.0 <= cache.miss_ratio <= 1.0


def test_engine_cache_single_access_and_bounds():
    cache = EngineCache("fifo", 4, 64)
    assert cache.access(7) is False
    assert cache.access(7) is True
    with pytest.raises(ValueError, match="relabel"):
        cache.access(64)


def test_engine_cache_tuning_surface():
    cache = EngineCache("s3fifo", 64, 256, small_frac=0.2)
    assert cache.engine_policy == "s3fifo"
    assert cache.capacity == 64
    assert cache.lane_skip_limit == 0
    t = cache.tuning
    assert t["small_frac"] == 0.2 and t["ghost_frac"] == 1.0
    assert "window_frac" not in t  # s3fifo has no correlation window


def test_engine_cache_window_retune_is_live():
    cache = EngineCache("clock2q+", 60, 256)
    cache.access_many(_trace(seed=5, T=500, U=200) % 256)
    before = {k: v for k, v in cache.state.items() if k != "window"}
    cache.retune(window_frac=1.0)
    assert cache.tuning["window_frac"] == 1.0
    # live update: only the window scalar changed, residency survived
    for k, v in before.items():
        np.testing.assert_array_equal(np.asarray(v),
                                      np.asarray(cache.state[k]))
    assert int(cache.state["window"]) == round(1.0 * int(cache.state["scap"]))


def test_engine_cache_fraction_retune_reinits():
    cache = EngineCache("clock2q+", 60, 256)
    cache.access_many(_trace(seed=6, T=500, U=200) % 256)
    cache.retune(small_frac=0.3)
    assert cache.tuning["small_frac"] == 0.3
    assert int(np.asarray(cache.state["seqctr"])) == 0  # cold state


# -- OnlineTuner over non-Clock2Q+ policies ------------------------------------

def test_tuner_candidate_grid_collapses_unread_knobs():
    cache = EngineCache("s3fifo", 64, 1024)
    tuner = OnlineTuner(cache, small_fracs=(0.1, 0.3),
                        retune_every=512, min_scaled_cap=8)
    assert tuner.policy == "s3fifo"
    grid = tuner.candidate_grid()
    # window dim collapsed (s3fifo reads no window), small dim kept
    assert {c.window_frac for c in grid} == {grid[0].window_frac}
    assert {c.small_frac for c in grid} == {0.1, 0.3}
    assert all(c.policy == "s3fifo" for c in grid)


def test_tuner_knob_free_policy_grid_is_live_point():
    cache = EngineCache("clock", 64, 1024)
    tuner = OnlineTuner(cache, retune_every=512)
    grid = tuner.candidate_grid()
    assert len(grid) == 1 and grid[0] == tuner._live_config()


@pytest.mark.parametrize("policy,kw", [
    ("s3fifo", dict(small_fracs=(0.1, 0.4))),
    ("clock", {}),
])
def test_tuner_runs_against_engine_cache(policy, kw):
    """End-to-end: observe a drifting stream through an EngineCache and
    let the tuner profile + (maybe) retune — no crash, decisions
    recorded, and any applied decision actually changed the knobs."""
    cache = EngineCache(policy, 64, 4096)
    tuner = OnlineTuner(cache, retune_every=1024, rate_shift=2,
                        min_scaled_cap=8, min_samples=64,
                        confirm_rounds=1, min_gain=0.0, **kw)
    rng = np.random.default_rng(11)
    for lo in range(0, 8192, 1024):
        keys = rng.zipf(1.3, 1024) % 4096
        cache.access_many(keys)
        tuner.observe_many(keys)
    assert len(tuner.decisions) >= 4
    last_applied = None
    for d in tuner.decisions:
        assert np.isfinite(d.est_miss_ratios).any()
        if d.applied:
            last_applied = d
    if last_applied is not None:
        for k, v in cache.tuning.items():
            assert v == getattr(last_applied.chosen, k)
