"""The import-layering contract (tools/check_layering.py) as a test:
``core.engine`` at the bottom, ``serving`` at the top, no module-level
import pointing upward."""

import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

import check_layering  # noqa: E402


def test_no_upward_module_level_imports():
    violations = check_layering.check(REPO / "src")
    assert violations == [], "\n".join(violations)


def test_layer_of_longest_prefix_wins():
    assert check_layering.layer_of("repro.core.engine.layout") == 0
    assert check_layering.layer_of("repro.obs.metrics") == 0
    assert check_layering.layer_of("repro.core.jax_engine") == 1
    assert check_layering.layer_of("repro.tuning.sweep") == 3
    assert check_layering.layer_of("repro.serving.engine") == 4
    assert check_layering.layer_of("repro.models.model") is None


def test_obs_is_sealed():
    # obs is instrumented by every layer, so it must not import any
    # layered package itself — not even sideways at layer 0
    assert check_layering._sealed_prefix("repro.obs.events") == "repro.obs"
    assert check_layering._sealed_prefix("repro.core.engine") is None
    import ast
    import re
    for path in sorted((REPO / "src" / "repro" / "obs").glob("*.py")):
        tree = ast.parse(path.read_text())
        for _, imported in check_layering.module_level_imports(tree):
            assert not re.match(r"repro\.(?!obs)", imported + "."), \
                f"{path} imports {imported}"
