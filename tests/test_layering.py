"""The import-layering contract (tools/check_layering.py) as a test:
``core.engine`` at the bottom, ``serving`` at the top, no module-level
import pointing upward."""

import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

import check_layering  # noqa: E402


def test_no_upward_module_level_imports():
    violations = check_layering.check(REPO / "src")
    assert violations == [], "\n".join(violations)


def test_layer_of_longest_prefix_wins():
    assert check_layering.layer_of("repro.core.engine.layout") == 0
    assert check_layering.layer_of("repro.obs.metrics") == 0
    assert check_layering.layer_of("repro.core.jax_engine") == 1
    assert check_layering.layer_of("repro.tuning.sweep") == 3
    assert check_layering.layer_of("repro.serving.engine") == 4
    assert check_layering.layer_of("repro.models.model") is None


def test_faults_layer_and_restriction():
    # faults sits beside traceio at layer 2, so the pool/serving layers
    # above may thread it in...
    assert check_layering.layer_of("repro.faults.plan") == 2
    assert check_layering._restricted_prefix("repro.faults.io") == \
        "repro.faults"
    assert check_layering._restricted_prefix("repro.traceio") is None
    # ...but faults itself is RESTRICTED to core + obs: a faults ->
    # traceio import would be layer-legal (sideways) yet must still be
    # flagged
    allowed = check_layering.RESTRICTED["repro.faults"]
    assert check_layering._in_allowed("repro.core.prodcache", allowed)
    assert check_layering._in_allowed("repro.obs.events", allowed)
    assert not check_layering._in_allowed("repro.traceio.stream", allowed)
    assert not check_layering._in_allowed("repro.kvcache.pool", allowed)


def test_restricted_violation_is_reported(tmp_path):
    # synthesize a faults module with a sideways traceio import and run
    # the real checker over it: the RESTRICTED rule must fire even
    # though plain layer ordering (2 -> 2) would allow the edge
    pkg = tmp_path / "repro" / "faults"
    pkg.mkdir(parents=True)
    (tmp_path / "repro" / "__init__.py").write_text("")
    (pkg / "__init__.py").write_text("")
    (pkg / "bad.py").write_text("import repro.traceio\n")
    violations = check_layering.check(tmp_path)
    assert len(violations) == 1 and "restricted" in violations[0]
    # the same import from an unrestricted layer-2 package is fine
    (pkg / "bad.py").write_text("import repro.core.prodcache\n")
    assert check_layering.check(tmp_path) == []


def test_obs_is_sealed():
    # obs is instrumented by every layer, so it must not import any
    # layered package itself — not even sideways at layer 0
    assert check_layering._sealed_prefix("repro.obs.events") == "repro.obs"
    assert check_layering._sealed_prefix("repro.core.engine") is None
    import ast
    import re
    for path in sorted((REPO / "src" / "repro" / "obs").glob("*.py")):
        tree = ast.parse(path.read_text())
        for _, imported in check_layering.module_level_imports(tree):
            assert not re.match(r"repro\.(?!obs)", imported + "."), \
                f"{path} imports {imported}"
