"""Property-based tests (hypothesis) for the cache substrate's invariants."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dev dependency")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import make_policy, policy_names
from repro.core.prodcache import ProdClock2QPlus

POLICIES = [p for p in policy_names() if p != "belady"]

trace_strategy = st.lists(st.integers(min_value=0, max_value=120),
                          min_size=1, max_size=400)
cap_strategy = st.integers(min_value=2, max_value=50)


@settings(max_examples=25, deadline=None)
@given(trace=trace_strategy, cap=cap_strategy)
def test_all_policies_core_invariants(trace, cap):
    for name in POLICIES:
        pol = make_policy(name, cap)
        resident = set()
        for k in trace:
            hit = pol.access(k)
            # a hit requires residency; a miss means it was absent
            assert hit == (k in resident)
            # rebuild residency from the policy's own view
            resident = {x for x in resident if x in pol}
            if k in pol:
                resident.add(k)
            assert len(pol) <= cap


@settings(max_examples=15, deadline=None)
@given(trace=st.lists(st.integers(min_value=0, max_value=90),
                      min_size=10, max_size=300),
       cap=st.integers(min_value=4, max_value=40),
       seed=st.integers(min_value=0, max_value=5))
def test_prodcache_matches_reference(trace, cap, seed):
    prod = ProdClock2QPlus(cap)
    ref = make_policy("clock2q+", cap, dirty_mode="simplified")
    for i, k in enumerate(trace):
        ref.clock_time = i
        assert prod.access(k).hit == ref.access(k)


@settings(max_examples=10, deadline=None)
@given(trace=st.lists(st.integers(min_value=0, max_value=60),
                      min_size=10, max_size=200),
       cap=st.integers(min_value=4, max_value=30))
def test_prodcache_payload_handles_unique(trace, cap):
    """Every resident key owns exactly one payload block; no block is
    owned twice (allocator correctness under churn)."""
    prod = ProdClock2QPlus(cap)
    for k in trace:
        prod.access(k)
        live = prod.block[prod.key != -1]
        assert len(set(live.tolist())) == len(live)
        free = set(prod.free_blocks)
        assert free.isdisjoint(set(live.tolist()))


@settings(max_examples=8, deadline=None)
@given(data=st.data())
def test_prodcache_live_resize_preserves_hits(data):
    """Resizing mid-stream must never corrupt lookups: any key the cache
    claims resident must be found again immediately."""
    cap = data.draw(st.integers(min_value=8, max_value=24))
    prod = ProdClock2QPlus(cap, max_capacity=96)
    rng = np.random.default_rng(0)
    for phase, new_cap in ((0, 80), (1, 12)):
        prod.begin_resize(new_cap)
        for k in rng.integers(0, 100, 300):
            r = prod.access(int(k))
            prod.resize_step(4)
            assert prod.access(int(k)).hit  # immediate re-lookup must hit
    while not prod.resize_step(512):
        pass
    assert len(prod) <= prod.small_cap + prod.main_cap


@settings(max_examples=10, deadline=None)
@given(trace=st.lists(st.integers(min_value=0, max_value=50),
                      min_size=5, max_size=150))
def test_oversized_window_never_promotes_from_small(trace):
    """window > S: no resident block can age past it (a resident block's
    age can reach exactly S, so window=S does NOT suffice — window=2S
    does), giving Clock2Q behaviour (§3.2; jax_engine maps clock2q to
    clock2q+ with window_frac=10)."""
    pw = make_policy("clock2q+", 30, window_frac=2.0)
    for k in trace:
        pw.access(k)
    assert pw.flows["small_to_main"] == 0
