"""Per-architecture smoke tests (reduced configs, CPU): one forward/train
step with shape + finiteness checks, and prefill+decode consistency
against the full forward."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.launch.specs import make_batch
from repro.models.config import SHAPES, ShapeCell, cell_applicable
from repro.models.model import build

pytestmark = pytest.mark.slow  # JAX-compile-heavy (see pytest.ini)

CELL = ShapeCell("smoke", 32, 2, "train")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = reduced(get_config(arch))
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, CELL, seed=1)
    loss, metrics = jax.jit(api.loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    # jit: eager grad dispatch through the scan-heavy archs costs 15s+
    grads = jax.jit(jax.grad(lambda p: api.loss(p, batch)[0]))(params)
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", ["granite-3-8b", "olmoe-1b-7b",
                                  "falcon-mamba-7b", "zamba2-2.7b",
                                  "whisper-tiny", "llava-next-mistral-7b"])
def test_prefill_decode_consistency(arch):
    cfg = reduced(get_config(arch))
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, capacity_factor=64.0)  # dropless
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = make_batch(cfg, ShapeCell("p", S, B, "prefill"), seed=3)
    extra = jnp.ones((B, 1), jnp.int32) * 7
    full = dict(batch, tokens=jnp.concatenate([batch["tokens"], extra], 1))
    if cfg.family == "encdec":
        from repro.models import encdec
        ref, _ = encdec.forward_train(cfg, params, full, remat=False)
    elif cfg.family == "hybrid":
        from repro.models import hybrid
        ref, _ = hybrid.forward_full(cfg, params, full, remat=False)
    elif cfg.family == "ssm":
        from repro.models.model import _ssm_forward_train
        ref, _ = _ssm_forward_train(cfg, params, full, remat=False)
    else:
        from repro.models import transformer as T
        ref, _ = T.forward_train(cfg, params, full, remat=False)
    lp, cache = api.prefill(params, batch, max_len=S + 4)
    ld, _ = api.decode(params, extra, cache)
    scale = float(jnp.max(jnp.abs(ref[:, -1]))) + 1e-9
    assert float(jnp.max(jnp.abs(ref[:, -1] - ld[:, 0]))) / scale < 2e-4
    assert float(jnp.max(jnp.abs(ref[:, S - 1] - lp[:, -1]))) / scale < 2e-4


def test_vlm_patch_positions_are_masked():
    cfg = reduced(get_config("llava-next-mistral-7b"))
    batch = make_batch(cfg, CELL, seed=0)
    P = batch["patch_embeds"].shape[1]
    assert (np.asarray(batch["labels"])[:, :P] == -1).all()


def test_param_counts_match_analytic():
    """init() leaf totals must agree with the analytic n_params() used for
    MODEL_FLOPS in the roofline."""
    for arch in ("granite-3-8b", "olmoe-1b-7b", "falcon-mamba-7b"):
        cfg = reduced(get_config(arch))
        api = build(cfg)
        shapes = jax.eval_shape(api.init, jax.random.PRNGKey(0))
        total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
        analytic = cfg.n_params()
        # analytic formula ignores norms/biases/router-bias etc: within 5%
        assert abs(total - analytic) / total < 0.05, (arch, total, analytic)


def test_long500k_applicability_rules():
    skips = {a: cell_applicable(get_config(a), SHAPES[3]) for a in ARCH_IDS}
    runs = [a for a, s in skips.items() if s is None]
    assert sorted(runs) == ["falcon-mamba-7b", "zamba2-2.7b"]
