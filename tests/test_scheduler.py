"""Deterministic simulation harness for the continuous-batching serving
scheduler (repro.serving.scheduler).

Everything in the fast tier here runs the model-free ``SimExecutor`` on
the virtual tick clock — no JAX, no wall clock, no unseeded RNG — so
each property is checked against the *exact* decision stream
(``schedule_log``), not a statistical summary:

  * bit-identical schedules per seed, across the registered arrival
    scenarios (poisson / burst / adversarial);
  * no starvation of the batch class under sustained overload (aging);
  * shed-before-deadline-miss: a completed request never misses its
    SLO, and a deadline shed happens at or before the deadline;
  * greedy-token equality across batch compositions (the sim analogue
    of scheduler-vs-``run_sync`` on the real engine, locked slow below);
  * bounded admission with displacement, the block watermark, degraded-
    mode backpressure, and multi-tenant fair share;
  * a hypothesis sweep of the structural invariants (terminal
    trichotomy, queue bound, deadline safety) over random workloads.

The slow tier drives the real ``ServingEngine`` through the same
scheduler: token equality against the old synchronous loop, and a
FaultPlan chaos run (IO_ERROR storm + SHARD_LOSS mid-batch) checking
degraded shedding, recovery, and fault-oblivious completed tokens.
"""

import numpy as np
import pytest

from repro.core import traces
from repro.faults.io import Clock
from repro.serving.admission import (
    R_DEADLINE, R_DEGRADED, R_DISPLACED, R_OVERSIZE, R_QUEUE_FULL,
    ST_COMPLETED, ST_REJECTED, ST_SHED, AdmissionConfig, AdmissionQueue,
    SchedRequest,
)
from repro.serving.scheduler import (
    SchedConfig, Scheduler, SimExecutor, simulate_sync,
)

ARRIVAL_SCENARIOS = ("arrivals-poisson", "arrivals-burst",
                     "arrivals-adversarial")


def _mk_requests(n, seed=0, *, prompt=24, max_new=5, n_classes=3,
                 tenants=("a", "b"), deadline_slack=0):
    """A deterministic request mix: class/tenant round-robin keyed off
    the index (pure function of its arguments)."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        reqs.append(SchedRequest(
            req_id=i, prompt_len=prompt + int(rng.integers(0, 8)),
            max_new=max_new, priority=i % n_classes,
            tenant=tenants[i % len(tenants)],
            deadline=0))
    return reqs


def _run(reqs, arrivals, *, seed=0, cfg=None, x=None):
    cfg = cfg or SchedConfig(token_budget=256, max_batch=4)
    clock = Clock()
    x = x or SimExecutor(n_blocks=4096, block_size=16, clock=clock)
    s = Scheduler(x, config=cfg, clock=clock, seed=seed)
    outs = s.run(reqs, arrivals)
    return s, outs


# =============================================================================
# bit-reproducibility over the arrival-scenario registry
# =============================================================================

@pytest.mark.parametrize("scenario", ARRIVAL_SCENARIOS)
def test_bit_identical_schedule_per_seed(scenario):
    arrivals = traces.make_trace(scenario, n=120, seed=9).tolist()
    logs, outs = [], []
    for _ in range(2):  # two full independent replays
        s, o = _run(_mk_requests(120, seed=4), arrivals, seed=17)
        logs.append(list(s.schedule_log))
        outs.append([(x.req_id, x.status, x.finish, x.reason,
                      tuple(x.tokens)) for x in o])
    assert logs[0] == logs[1]
    assert outs[0] == outs[1]
    # the log is the full decision stream: every request admits or
    # rejects exactly once, and terminals cover the whole input
    assert len(outs[0]) == 120
    kinds = {e[0] for e in logs[0]}
    assert "admit" in kinds and "batch" in kinds


def test_seed_changes_tiebreaks_not_outcomes():
    # same workload, different scheduler seed: the tie-break hash moves,
    # but the set of terminal statuses stays a function of the workload
    arrivals = traces.make_trace("arrivals-burst", n=80, seed=2).tolist()
    _, o1 = _run(_mk_requests(80, seed=1), arrivals, seed=0)
    _, o2 = _run(_mk_requests(80, seed=1), arrivals, seed=999)
    assert {x.req_id for x in o1 if x.status == ST_COMPLETED} \
        == {x.req_id for x in o2 if x.status == ST_COMPLETED}


# =============================================================================
# no starvation under sustained overload (anti-starvation aging)
# =============================================================================

def test_batch_class_not_starved_under_overload():
    # 2 requests/tick of interactive work against ~1.33 seqs/tick of
    # capacity: without aging the batch-class stragglers drain dead last;
    # with aging they promote and interleave
    hot = [SchedRequest(req_id=i, prompt_len=8, max_new=4, priority=0)
           for i in range(60)]
    cold = [SchedRequest(req_id=1000 + i, prompt_len=8, max_new=4,
                         priority=2) for i in range(4)]
    # cold arrives at tick 4, once the interactive backlog has built up
    arrivals = [i // 2 for i in range(60)] + [4, 4, 4, 4]
    adm = AdmissionConfig(queue_bound=256, age_ticks=8)
    cfg = SchedConfig(token_budget=256, max_batch=4, admission=adm)
    s, outs = _run(hot + cold, arrivals, cfg=cfg)
    assert all(o.status == ST_COMPLETED for o in outs)

    def start_ticks(sched, pred):
        return [e[1] for e in sched.schedule_log
                if e[0] == "start" and pred(e[2])]

    # every aged batch request is dispatched before the interactive
    # stream drains — it was not parked behind 60 class-0 requests
    assert max(start_ticks(s, lambda r: r >= 1000)) \
        < max(start_ticks(s, lambda r: r < 1000))

    # control: aging off -> batch work starts only once every
    # interactive request has been dispatched (starved to the end)
    adm0 = AdmissionConfig(queue_bound=256, age_ticks=0)
    s0, outs0 = _run(hot + cold, arrivals,
                     cfg=SchedConfig(token_budget=256, max_batch=4,
                                     admission=adm0))
    assert min(start_ticks(s0, lambda r: r >= 1000)) \
        >= max(start_ticks(s0, lambda r: r < 1000))


# =============================================================================
# shed-before-deadline-miss
# =============================================================================

def test_shed_before_deadline_miss():
    # more deadline work than capacity: some requests must be shed, and
    # the scheduler sheds them BEFORE their deadline instead of letting
    # them run and miss
    reqs = [SchedRequest(req_id=i, prompt_len=16, max_new=6, priority=0,
                         deadline=12) for i in range(24)]
    s, outs = _run(reqs, [0] * 24,
                   cfg=SchedConfig(token_budget=64, max_batch=3))
    by_status = {}
    for o in outs:
        by_status.setdefault(o.status, []).append(o)
    assert by_status.get(ST_SHED), "overload must shed"
    for o in by_status.get(ST_COMPLETED, ()):
        assert o.finish <= 12, "a completed request never misses its SLO"
        assert len(o.tokens) == 6
    for o in by_status[ST_SHED]:
        assert o.reason == R_DEADLINE
        assert o.finish <= 12, "shed happens before the miss, not after"


def test_feasible_deadlines_all_met():
    # plenty of capacity and feasible SLOs: nothing sheds, all deadlines met
    reqs = [SchedRequest(req_id=i, prompt_len=8, max_new=4, priority=0,
                         deadline=8 + i * 4) for i in range(6)]
    s, outs = _run(reqs, [i * 4 for i in range(6)],
                   cfg=SchedConfig(token_budget=256, max_batch=4))
    assert all(o.status == ST_COMPLETED for o in outs)
    assert all(o.finish <= r.deadline for o, r in
               zip(sorted(outs, key=lambda o: o.req_id), reqs))


# =============================================================================
# greedy-token equality across batch compositions
# =============================================================================

def test_tokens_independent_of_batch_composition():
    # the same request set through wildly different schedules (batch
    # size, budget, priorities shuffled by seed) produces identical
    # completed tokens — greedy decode depends only on the sequence
    arrivals = traces.make_trace("arrivals-poisson", n=40, seed=5).tolist()
    reference = None
    for max_batch, budget in ((1, 32), (4, 128), (8, 512)):
        s, outs = _run(_mk_requests(40, seed=7), arrivals,
                       cfg=SchedConfig(token_budget=budget,
                                       max_batch=max_batch))
        toks = {o.req_id: o.tokens for o in outs
                if o.status == ST_COMPLETED}
        assert toks, "workload must complete something"
        if reference is None:
            reference = toks
        else:
            for rid in toks.keys() & reference.keys():
                assert toks[rid] == reference[rid]


def test_scheduler_matches_sync_throughput_when_unconstrained():
    # no deadlines, one class, budget never binding: the scheduler
    # degenerates to the old FIFO loop's makespan on the same trace
    reqs = [SchedRequest(req_id=i, prompt_len=8, max_new=4, priority=0)
            for i in range(20)]
    arrivals = [i // 4 for i in range(20)]
    s, outs = _run(reqs, arrivals,
                   cfg=SchedConfig(token_budget=1 << 20, max_batch=4))
    sync_fin = simulate_sync(
        [SchedRequest(req_id=i, prompt_len=8, max_new=4, priority=0)
         for i in range(20)], arrivals, max_batch=4)
    assert max(o.finish for o in outs) == max(sync_fin.values())


# =============================================================================
# bounded admission: queue bound, displacement, oversize
# =============================================================================

def test_queue_bound_displacement_and_rejects():
    adm = AdmissionConfig(queue_bound=4, age_ticks=0)
    x = SimExecutor(n_blocks=4096, block_size=16)
    s = Scheduler(x, config=SchedConfig(max_batch=1, admission=adm), seed=3)
    # fill the queue with batch-class work
    for i in range(4):
        assert s.submit(SchedRequest(req_id=i, prompt_len=8, priority=2))
    assert len(s.queue) == 4
    # equal class on a full queue: rejected, never displaces
    assert not s.submit(SchedRequest(req_id=10, prompt_len=8, priority=2))
    assert s.outcomes[10].status == ST_REJECTED
    assert s.outcomes[10].reason == R_QUEUE_FULL
    # strictly-better class displaces the worst batch entry
    assert s.submit(SchedRequest(req_id=11, prompt_len=8, priority=0))
    assert len(s.queue) == 4
    displaced = [o for o in s.outcomes.values()
                 if o.status == ST_SHED and o.reason == R_DISPLACED]
    assert len(displaced) == 1 and displaced[0].priority == 2


def test_oversize_rejected_up_front():
    x = SimExecutor(n_blocks=8, block_size=16)  # 128-token pool
    s = Scheduler(x, seed=0)
    assert not s.submit(SchedRequest(req_id=0, prompt_len=500, max_new=8))
    assert s.outcomes[0].status == ST_REJECTED
    assert s.outcomes[0].reason == R_OVERSIZE
    # a feasible request on the same scheduler still completes
    assert s.submit(SchedRequest(req_id=1, prompt_len=16, max_new=2))
    outs = s.run([], [])
    assert s.outcomes[1].status == ST_COMPLETED


def test_block_watermark_never_overcommits():
    # tiny pool, fat sequences: prefills must throttle so pinned blocks
    # never exceed capacity, yet everything eventually completes
    x = SimExecutor(n_blocks=8, block_size=16)
    peak = 0
    orig = x.prefill

    def spying_prefill(r):
        nonlocal peak
        tok = orig(r)
        peak = max(peak, x.used)
        return tok
    x.prefill = spying_prefill
    reqs = [SchedRequest(req_id=i, prompt_len=30, max_new=2, priority=0)
            for i in range(10)]
    s, outs = _run(reqs, [0] * 10, x=x,
                   cfg=SchedConfig(token_budget=1 << 20, max_batch=8))
    assert all(o.status == ST_COMPLETED for o in outs)
    assert peak <= x.n_blocks


# =============================================================================
# degraded-mode backpressure (sim chaos)
# =============================================================================

def test_degraded_mode_sheds_lowest_and_recovers():
    clock = Clock()
    x = SimExecutor(n_blocks=4096, block_size=16, clock=clock,
                    degraded_ticks=range(2, 8))
    s = Scheduler(x, config=SchedConfig(token_budget=256, max_batch=2),
                  clock=clock, seed=1)
    reqs = (
        [SchedRequest(req_id=i, prompt_len=8, max_new=3, priority=0)
         for i in range(4)]
        + [SchedRequest(req_id=10 + i, prompt_len=8, max_new=3, priority=1)
           for i in range(4)]
        + [SchedRequest(req_id=20 + i, prompt_len=8, max_new=3, priority=2)
           for i in range(4)])
    outs = s.run(reqs, [0, 0, 3, 3, 0, 0, 3, 3, 0, 0, 3, 3])
    by_id = {o.req_id: o for o in outs}
    # batch-class work queued while degraded is shed with the degraded code
    degraded_sheds = [o for o in outs
                      if o.status == ST_SHED and o.reason == R_DEGRADED]
    assert degraded_sheds and all(o.priority == 2 for o in degraded_sheds)
    # standard-class work is paused (not shed) and completes after recovery
    mids = [by_id[10 + i] for i in range(4)]
    assert all(o.status == ST_COMPLETED for o in mids)
    assert all(o.finish >= 8 or o.finish <= 2 for o in mids)
    # interactive work keeps flowing throughout
    assert all(by_id[i].status == ST_COMPLETED for i in range(4))
    # recovery restores admission for new batch-class work
    x2 = SimExecutor(n_blocks=4096, block_size=16, clock=clock)
    s.x = x2
    late = SchedRequest(req_id=99, prompt_len=8, max_new=2, priority=2)
    assert s.submit(late)
    s.run([], [])
    assert s.outcomes[99].status == ST_COMPLETED


# =============================================================================
# multi-tenant fair share
# =============================================================================

def test_tenant_fair_share_band():
    # two tenants, equal weight, saturating equal demand at one priority:
    # completed tokens stay within a fairness band at every prefix
    reqs = []
    for i in range(60):
        reqs.append(SchedRequest(req_id=i, prompt_len=16, max_new=4,
                                 priority=1,
                                 tenant="a" if i % 2 == 0 else "b"))
    s, outs = _run(reqs, [0] * 60,
                   cfg=SchedConfig(token_budget=64, max_batch=2))
    starts = [e for e in s.schedule_log if e[0] == "start"]
    a = b = 0
    for e in starts:
        if e[2] % 2 == 0:
            a += 1
        else:
            b += 1
        assert abs(a - b) <= 2, "dispatch order must interleave tenants"


def test_tenant_weights_skew_share():
    adm = AdmissionConfig(queue_bound=256,
                          tenant_weights={"big": 3.0, "small": 1.0})
    reqs = [SchedRequest(req_id=i, prompt_len=16, max_new=4, priority=1,
                         tenant="big" if i % 2 == 0 else "small")
            for i in range(40)]
    cfg = SchedConfig(token_budget=32, max_batch=1, admission=adm)
    s, _ = _run(reqs, [0] * 40, cfg=cfg)
    first = [e[2] % 2 == 0 for e in s.schedule_log
             if e[0] == "start"][:16]
    big_share = sum(first) / len(first)
    assert big_share >= 0.6, f"weighted tenant got {big_share:.2f}"


# =============================================================================
# hypothesis: structural invariants over random workloads
# =============================================================================

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # optional dev dependency
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    req_strategy = st.lists(
        st.tuples(st.integers(1, 64),     # prompt_len
                  st.integers(1, 8),      # max_new
                  st.integers(0, 2),      # priority
                  st.integers(0, 40),     # deadline slack (0 = none)
                  st.sampled_from(("a", "b", "c")),   # tenant
                  st.integers(0, 20)),    # arrival tick
        min_size=1, max_size=60)

    @settings(max_examples=40, deadline=None)
    @given(spec=req_strategy, seed=st.integers(0, 3),
           queue_bound=st.integers(2, 16), max_batch=st.integers(1, 6))
    def test_invariants_random_workloads(spec, seed, queue_bound,
                                         max_batch):
        reqs, arrivals = [], []
        for i, (plen, mnew, pri, slack, tenant, arr) in enumerate(spec):
            reqs.append(SchedRequest(
                req_id=i, prompt_len=plen, max_new=mnew, priority=pri,
                deadline=(arr + slack) if slack else 0, tenant=tenant))
            arrivals.append(arr)
        adm = AdmissionConfig(queue_bound=queue_bound, age_ticks=16)
        cfg = SchedConfig(token_budget=128, max_batch=max_batch,
                          admission=adm)
        clock = Clock()
        x = SimExecutor(n_blocks=1 << 14, block_size=16, clock=clock)
        s = Scheduler(x, config=cfg, clock=clock, seed=seed)
        # drive submit/tick by hand so the queue bound is checked per tick
        order = sorted(range(len(reqs)), key=lambda i: (arrivals[i], i))
        pos = 0
        for _ in range(2000):
            while pos < len(order) and \
                    arrivals[order[pos]] <= clock.now:
                s.submit(reqs[order[pos]])
                pos += 1
            s.tick()
            assert len(s.queue) <= queue_bound
            assert len(s.active) <= max_batch
            if pos == len(order) and not s.queue and not s.active:
                break
        # terminal trichotomy: every request reaches exactly one end state
        assert len(s.outcomes) == len(reqs)
        assert len(s.order) == len(set(s.order)) == len(reqs)
        for r in reqs:
            o = s.outcomes[r.req_id]
            assert o.status in (ST_COMPLETED, ST_SHED, ST_REJECTED)
            if o.status == ST_COMPLETED:
                assert len(o.tokens) == r.max_new
                if r.deadline:
                    assert o.finish <= r.deadline
            else:
                assert o.reason != 0 and not o.tokens
        assert x.used == 0  # all blocks released


# =============================================================================
# admission-queue unit behaviour
# =============================================================================

def test_aging_promotes_ordering_only():
    adm = AdmissionConfig(age_ticks=4)
    q = AdmissionQueue(adm, seed=0)
    old = SchedRequest(req_id=0, prompt_len=1, priority=2, arrival=0)
    new = SchedRequest(req_id=1, prompt_len=1, priority=1, arrival=10)
    q.offer(old, 0)
    q.offer(new, 10)
    # at t=10 the old batch request has aged 2 classes: effective 0
    assert q.effective_class(old, 10) == 0
    assert q.peek_best(10) is old
    # ...but its declared class (metrics identity) is untouched
    assert old.priority == 2


def test_shed_expired_is_exact():
    q = AdmissionQueue(AdmissionConfig(), seed=0)
    # service_ticks(max_new=4) == 3: at t=0 a deadline of 3 is feasible,
    # 2 is not
    ok = SchedRequest(req_id=0, prompt_len=1, max_new=4, deadline=3)
    late = SchedRequest(req_id=1, prompt_len=1, max_new=4, deadline=2)
    q.offer(ok, 0)
    q.offer(late, 0)
    expired = q.shed_expired(0)
    assert [r.req_id for r in expired] == [1]
    assert len(q) == 1


# =============================================================================
# slow tier: the real engine through the same scheduler
# =============================================================================

@pytest.fixture(scope="module")
def small_model():
    import jax
    from repro.configs import get_config, reduced
    from repro.models.model import build
    cfg = reduced(get_config("granite-3-8b"))
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return api, params


@pytest.mark.slow
def test_engine_scheduler_tokens_match_sync(small_model):
    from repro.serving.engine import Request, ServingEngine
    api, params = small_model
    rng = np.random.default_rng(11)
    reqs = [Request(i, list(rng.integers(0, api.cfg.vocab, 20)), max_new=4,
                    priority=i % 2, tenant=f"t{i % 2}")
            for i in range(5)]
    eng = ServingEngine(api, params, block_size=8, hbm_blocks=32,
                        max_batch=2)
    sync = {c.req_id: c.tokens for c in eng.run_sync(
        [Request(r.req_id, list(r.prompt), max_new=r.max_new)
         for r in reqs])}
    outs = eng.run(reqs, arrivals=[0, 0, 1, 2, 3], seed=5)
    assert all(c.status == ST_COMPLETED for c in outs)
    for c in outs:
        assert c.tokens == sync[c.req_id], f"req {c.req_id}"
    # the scheduler's decision stream lands in the engine's obs sink
    snap = eng.obs_snapshot()
    assert sum(v for k, v in snap.counters.items()
               if k.startswith("sched_admitted_total")) == 5
    assert {e["kind"] for e in snap.events} >= {"admit", "batch"}
    # per-tenant kvcache attribution rode along with the lookups
    assert any(k.startswith("pool_tenant_lookups_total")
               for k in snap.counters)


@pytest.mark.slow
def test_engine_chaos_degraded_shed_and_recovery(small_model):
    from repro.faults import (
        IO_ERROR, SHARD_LOSS, FaultPlan, FaultSpec, RetryPolicy,
    )
    from repro.serving.engine import Request, ServingEngine
    api, params = small_model
    rng = np.random.default_rng(13)
    prompts = [list(rng.integers(0, api.cfg.vocab, 24)) for _ in range(8)]

    def mk_reqs():
        return [Request(i, list(p), max_new=3, priority=(2 if i >= 6 else 0))
                for i, p in enumerate(prompts)]

    # fault-free reference
    # 12 blocks/shard: worst-case key-hash skew (2 active seqs x 4
    # blocks all in one shard) still leaves evictable slots — per-shard
    # pinned exhaustion would spin the allocator, which is exactly the
    # oversize hazard the scheduler can only police globally
    eng0 = ServingEngine(api, params, block_size=8, hbm_blocks=24,
                         max_batch=2, n_shards=2)
    ref = {c.req_id: c.tokens for c in eng0.run_sync(mk_reqs())}

    # chaos: an IO_ERROR storm trips the breaker mid-run (the pool swaps
    # under hbm pressure), a SHARD_LOSS lands mid-batch, retries off
    plan = FaultPlan(7, [
        FaultSpec(SHARD_LOSS, at=(6,), shard=0),
        FaultSpec(IO_ERROR, prob=1.0),
    ])
    eng = ServingEngine(api, params, block_size=8, hbm_blocks=24,
                        max_batch=2, n_shards=2, faults=plan,
                        io_retry=RetryPolicy(max_retries=0))
    outs = eng.run(mk_reqs(), arrivals=list(range(8)), seed=2)
    by_id = {c.req_id: c for c in outs}
    assert len(outs) == 8
    # completed tokens are fault-oblivious (read-through refills from
    # prefill; greedy decode is unaffected)
    completed = [c for c in outs if c.status == ST_COMPLETED]
    assert completed
    for c in completed:
        assert c.tokens == ref[c.req_id], f"req {c.req_id}"
    # the breaker opened at some point: the incident trail has the
    # degraded transition, and if batch-class work was queued while
    # degraded it was shed with the degraded reason
    snap = eng.obs_snapshot()
    kinds = {e["kind"] for e in snap.events}
    assert "degraded" in kinds
    sched = eng._last_scheduler
    for o in sched.outcomes.values():
        if o.status == ST_SHED:
            assert o.reason in (R_DEGRADED, R_DEADLINE, R_DISPLACED)
    # recovery restores admission: a fresh batch-class request completes
    # once the breaker probes back to healthy
    if not eng.degraded:
        late = eng.run([Request(100, prompts[0], max_new=2, priority=2)])
        assert late[0].status == ST_COMPLETED
        assert late[0].tokens == ref[0][:2]
