"""Trace generation / derivation tests (paper §2.3, §5.2)."""

import numpy as np

from repro.core import stats, traces
from repro.core.btree import LeafBTree, btree_metadata_trace


def test_generators_deterministic():
    a = traces.storage_data_trace(20_000, seed=3)
    b = traces.storage_data_trace(20_000, seed=3)
    assert (a == b).all()
    assert (a != traces.storage_data_trace(20_000, seed=4)[:len(a)]).any()


def test_derivation_is_division():
    t = np.asarray([0, 5, 199, 200, 401, 999])
    m = traces.derive_metadata(t, fanout=200)
    assert list(m) == [0, 0, 0, 1, 2, 4]


def test_metadata_has_correlated_references():
    """Sequential data runs must produce short-interval re-references in
    the derived metadata trace (the paper's core observation)."""
    data = traces.storage_data_trace(50_000, seed=1, frac_seq_in_file=0.9,
                                     mean_run=64, frac_rmw=0.0)
    meta = traces.derive_metadata(data)
    # fraction of immediate repeats (distance 1) in metadata vs data
    rep_meta = float(np.mean(meta[1:] == meta[:-1]))
    rep_data = float(np.mean(data[1:] == data[:-1]))
    assert rep_meta > 0.5 and rep_data < 0.1


def test_btree_split_behaviour():
    t = LeafBTree(fanout=4)
    ids = [t.lookup_or_insert(k) for k in range(20)]
    assert t.n_leaves >= 4
    # keys must remain findable in sorted leaf ranges
    for k in range(20):
        assert t.lookup_or_insert(k) == ids[k] or True  # id stable per key
    assert t.lookup_or_insert(7) == t.lookup_or_insert(7)


def test_btree_vs_division_fidelity():
    """Fig. 7: miss ratios on btree-replayed vs divide-by-fanout metadata
    traces agree closely (tree pre-populated with the volume's LBN space,
    as in the paper's TLX experiment)."""
    U = 1 << 16
    data = traces.storage_data_trace(60_000, universe=U, seed=5)
    m_div = traces.derive_metadata(data, fanout=200)
    m_bt = btree_metadata_trace(data, fanout=200, universe=U)
    fp = traces.footprint(m_div)
    cap = max(10, int(0.05 * fp))
    for algo in ("clock2q+", "s3fifo"):
        mr_div = stats.simulate(algo, m_div, cap).miss_ratio
        mr_bt = stats.simulate(algo, m_bt, cap).miss_ratio
        assert abs(mr_div - mr_bt) < 0.005, (algo, mr_div, mr_bt)


def test_upper_tier_filter_removes_locality():
    t = traces.zipf_trace(30_000, 1 << 14, alpha=1.2, seed=2)
    filtered = traces.upper_tier_filter(t, 2_000)
    assert len(filtered) < len(t) * 0.8
    # the filtered trace has (near-)unique consecutive requests
    assert float(np.mean(filtered[1:] == filtered[:-1])) < 0.01


def test_object_trace_and_bursts():
    o = traces.object_trace(10_000, seed=1)
    assert o.min() >= 0
    b = traces.correlated_burst_trace(2_000, seed=1)
    rep = float(np.mean([x in set(b[max(0, i - 8):i])
                         for i, x in enumerate(b[:2000].tolist())]))
    assert rep > 0.2  # bursty by construction
