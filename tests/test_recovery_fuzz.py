"""Crash-point recovery fuzzing for the write-ahead delta journal
(repro.faults.journal) and hot-standby replication (repro.faults.replica).

The acceptance pillars from the issue:

  * kill the journal writer at EVERY record boundary and at random byte
    offsets inside records; recovery must restore state bit-exact up to
    the last durable LSN, detect + truncate the torn tail, and never
    silently apply a torn record;
  * a CRASH FaultSpec on the journal's append stream (``ticks`` = bytes
    that reached disk) reproduces the same mid-record kills in-process;
  * journal apply is idempotent and replay is deterministic: any prefix
    applied twice, or a replica resuming mid-stream, yields the same
    shard state byte-for-byte (hypothesis when available, seeded
    fallback otherwise — same sampler either way);
  * post-failover miss-ratio parity: replica promotion strictly beats
    PR 8's ghost-journal cold rewarm on the SUITE traces at 48k.
"""

import glob
import hashlib
import os
import shutil

import numpy as np
import pytest

from repro.core.prodcache import ProdClock2QPlus
from repro.faults import (
    CRASH, OP_JOURNAL_APPEND, FaultPlan, FaultSpec, GhostJournal,
    JournalCrash, ShardJournal, ShardReplica, ShardReplicator, failover,
    pack, recover, state_dict,
)
from repro.faults.journal import RECORD_SIZE, _SEG_HDR_SIZE
from repro.obs import EV_JOURNAL_TRUNCATED, EV_PROMOTE, NullSink, ObsSink
from repro.shardcache import ShardedClock2QPlus

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

_MASK = (1 << 64) - 1


def _digest(pol) -> str:
    return hashlib.sha1(pack(state_dict(pol))).hexdigest()


def _mk_policy():
    return ProdClock2QPlus(48, max_capacity=64, obs=NullSink())


def _drive(pol, n=200, seed=0x243F6A8885A308D3, each=None):
    """Deterministic mixed-op command stream covering every journaled
    op kind (access with dirty/pin, io_done, unpin, clean, set_dirty,
    retune, begin_resize, resize_step).  ``each()`` runs after every
    single policy call — exactly one journal record per call."""
    step = each if each is not None else (lambda: None)
    x = seed & _MASK
    for i in range(n):
        x = (x * 6364136223846793005 + 1442695040888963407) & _MASK
        k = (x >> 33) % 160
        r = pol.access(k, dirty=(i % 11 == 0), pin=(i % 17 == 0))
        step()
        if not r.hit:
            pol.io_done(k)
            step()
        if i % 17 == 0:
            pol.unpin(k)
            step()
        if i % 23 == 0:
            pol.clean(k)
            step()
        if i % 29 == 0:
            pol.set_dirty(k)
            step()
        if i % 97 == 0:
            pol.retune(window_frac=0.05 + (i % 3) * 0.05)
            step()
        if i % 61 == 0:
            pol.begin_resize(32 + (i % 33))
            step()
            while True:
                done = pol.resize_step(16)
                step()
                if done:
                    break


# =============================================================================
# Crash-point fuzz: every record boundary + random intra-record offsets
# =============================================================================

def _journaled_run(directory, segment_records=64):
    """Drive a journaled policy, recording the state digest at every
    LSN.  Returns (per-LSN digests, final LSN)."""
    pol = _mk_policy()
    jr = ShardJournal(directory, segment_records=segment_records)
    jr.attach(pol)
    hashes = {jr.lsn: _digest(pol)}
    _drive(pol, each=lambda: hashes.__setitem__(jr.lsn, _digest(pol)))
    jr.close()
    return hashes, jr.lsn


def _seg_start(path):
    stem = os.path.basename(path)[len("seg-"):-len(".c2qj")]
    return int(stem.split("-")[1])


def _crash_copy(src, dst, upto, extra=0):
    """Copy a journal directory as a crash at LSN boundary ``upto``
    would have left it: records 1..upto fully durable, plus ``extra``
    bytes of record upto+1 (a torn tail when 0 < extra < RECORD_SIZE)."""
    shutil.copytree(src, dst)
    for path in sorted(glob.glob(os.path.join(dst, "seg-*.c2qj")),
                       key=_seg_start):
        s = _seg_start(path)
        n = (os.path.getsize(path) - _SEG_HDR_SIZE) // RECORD_SIZE
        if n and s + n - 1 <= upto:
            continue  # every record of this segment is durable
        if s > upto + 1:
            os.unlink(path)  # the writer never got this far
        elif s == upto + 1:
            # crash right after rotation: header (+ torn bytes) only
            os.truncate(path, _SEG_HDR_SIZE + extra)
        else:
            os.truncate(path, _SEG_HDR_SIZE
                        + (upto - s + 1) * RECORD_SIZE + extra)


def test_crash_at_every_record_boundary(tmp_path):
    src = tmp_path / "journal"
    hashes, total = _journaled_run(str(src))
    assert total > 300  # the driver exercised a real op mix
    for k in range(total + 1):
        dst = tmp_path / f"b{k}"
        _crash_copy(str(src), str(dst), k)
        res = recover(str(dst))
        assert res.lsn == k and res.truncated_bytes == 0
        assert _digest(res.policy) == hashes[k], \
            f"state diverges after clean recovery at LSN {k}"
        shutil.rmtree(dst)


def test_crash_at_random_intra_record_offsets(tmp_path):
    src = tmp_path / "journal"
    hashes, total = _journaled_run(str(src))
    rng = np.random.default_rng(7)
    for i in range(200):
        k = int(rng.integers(0, total))       # last durable record
        extra = int(rng.integers(1, RECORD_SIZE))  # torn bytes of k+1
        dst = tmp_path / f"r{i}"
        _crash_copy(str(src), str(dst), k, extra=extra)
        obs = ObsSink(src="recover")
        res = recover(str(dst), obs=obs)
        # the torn record is detected, truncated, and NEVER applied
        assert res.lsn == k, f"offset {extra} into record {k + 1}"
        assert res.truncated_bytes == extra
        assert _digest(res.policy) == hashes[k]
        cuts = [e for e in obs.ring.records()
                if e["kind"] == "journal_truncated"]
        assert cuts and cuts[-1]["a"] == k and cuts[-1]["b"] == extra
        # the file really was truncated: a second recovery is clean
        res2 = recover(str(dst))
        assert res2.lsn == k and res2.truncated_bytes == 0
        shutil.rmtree(dst)


def test_crash_fault_spec_kills_writer_mid_record(tmp_path):
    """The in-process variant: a CRASH FaultSpec on the journal append
    stream flushes a record prefix and raises JournalCrash."""
    pol = _mk_policy()
    plan = FaultPlan(7, [FaultSpec(CRASH, ops=(OP_JOURNAL_APPEND,),
                                   at=(137,), ticks=17)])
    jr = ShardJournal(str(tmp_path), segment_records=64, plan=plan)
    jr.attach(pol)
    hashes = {jr.lsn: _digest(pol)}
    with pytest.raises(JournalCrash):
        _drive(pol, each=lambda: hashes.__setitem__(jr.lsn, _digest(pol)))
    with pytest.raises(ValueError):
        jr.append(1)  # a crashed journal accepts nothing further
    res = recover(str(tmp_path))
    # op_seq 137 is the 138th append: 137 records durable, 17 torn bytes
    assert res.lsn == 137 and res.truncated_bytes == 17
    assert _digest(res.policy) == hashes[137]


def test_crash_fault_full_record_is_durable(tmp_path):
    """ticks >= RECORD_SIZE clamps to the whole record: it reached disk,
    so recovery must apply it even though the writer died."""
    pol = _mk_policy()
    plan = FaultPlan(7, [FaultSpec(CRASH, ops=(OP_JOURNAL_APPEND,),
                                   at=(99,), ticks=10_000)])
    jr = ShardJournal(str(tmp_path), segment_records=64, plan=plan)
    jr.attach(pol)
    with pytest.raises(JournalCrash):
        _drive(pol)
    res = recover(str(tmp_path))
    assert res.lsn == 100 and res.truncated_bytes == 0


# =============================================================================
# Apply idempotency + replay determinism (hypothesis w/ seeded fallback)
# =============================================================================

def check_idempotent_replay(seed: int) -> None:
    """One sampled point: journal a run, then prove (a) applying any
    prefix twice is a no-op, (b) a replica resuming mid-stream converges
    to the same bytes as a one-shot catch-up, (c) two independent
    replicas agree with the live shard bit-for-bit."""
    rng = np.random.default_rng(seed)
    pol = _mk_policy()
    jr = ShardJournal(None, segment_records=int(rng.integers(16, 128)))
    jr.attach(pol)
    _drive(pol, n=120, seed=int(rng.integers(1, 1 << 62)))
    want = pack(state_dict(pol))
    recs = jr.records_since(0)
    assert recs and recs[-1].lsn == jr.lsn

    one_shot = ShardReplica(jr)
    assert one_shot.catch_up() == len(recs)
    assert pack(state_dict(one_shot.mirror)) == want

    # prefix applied twice: the second pass is skipped record-for-record
    cut = int(rng.integers(1, len(recs)))
    twice = ShardReplica(jr)
    assert twice.catch_up(upto=recs[cut - 1].lsn) == cut
    mid = pack(state_dict(twice.mirror))
    for r in recs[:cut]:
        assert not twice.apply(r)  # idempotent: already applied
    assert pack(state_dict(twice.mirror)) == mid
    # ...and resuming mid-segment reaches the exact final state
    assert twice.catch_up() == len(recs) - cut
    assert pack(state_dict(twice.mirror)) == want


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_journal_apply_idempotent_fuzz(seed):
        check_idempotent_replay(seed)
else:
    @pytest.mark.parametrize("seed", range(6))
    def test_journal_apply_idempotent_fuzz(seed):
        check_idempotent_replay(seed)


def test_records_since_survives_tail_eviction():
    """A replica that fell behind the bounded in-memory tail must be
    served by re-decoding segments, not a truncated slice."""
    pol = _mk_policy()
    jr = ShardJournal(None, segment_records=32, tail_cap=8)
    jr.attach(pol)
    _drive(pol, n=100)
    recs = jr.records_since(0)
    assert [r.lsn for r in recs] == list(range(1, jr.lsn + 1))
    rep = ShardReplica(jr)
    rep.catch_up()
    pol2 = _mk_policy()
    assert pack(state_dict(rep.mirror)) == pack(state_dict(pol))


def test_compaction_folds_sealed_segments(tmp_path):
    pol = _mk_policy()
    jr = ShardJournal(str(tmp_path), segment_records=32)
    jr.attach(pol)
    _drive(pol, n=150)
    want = _digest(pol)
    n_segs = len(glob.glob(str(tmp_path / "seg-*.c2qj")))
    assert n_segs > 2  # rotation actually happened
    folded = jr.compact()
    assert folded > 0 and jr.base_lsn == jr.lsn - jr._seg_count
    # sealed segments gone, exactly one base + the open segment remain
    assert len(glob.glob(str(tmp_path / "seg-*.c2qj"))) == 1
    assert len(glob.glob(str(tmp_path / "base-*.c2qsnap"))) == 1
    jr.close()
    res = recover(str(tmp_path))
    assert res.lsn == jr.lsn and _digest(res.policy) == want


# =============================================================================
# Hot-standby promotion: exact state, epochs, events
# =============================================================================

def test_promote_restores_exact_shard_state():
    svc = ShardedClock2QPlus(256, n_shards=4, max_capacity=512,
                             obs=NullSink())
    obs = ObsSink(src="replicator")
    rep = ShardReplicator(svc, None, lag_threshold=1 << 30, obs=obs)
    rng = np.random.default_rng(5)
    for k in rng.integers(0, 600, 5000):
        r = svc.access(int(k))
        if not r.hit:
            svc.io_done(int(k))
    rep.poll()
    # leave some lag on purpose: promote must drain it from the tail
    for k in rng.integers(0, 600, 500):
        r = svc.access(int(k))
        if not r.hit:
            svc.io_done(int(k))
    lag = rep.lag(1)
    assert lag > 0
    want = pack(state_dict(svc.shards[1]))
    old_epoch = rep.journals[1].epoch
    res = rep.promote(1)
    assert res.lag_at_loss == lag and res.replayed == lag
    assert pack(state_dict(svc.shards[1])) == want  # bit-exact failover
    # the shard's new incarnation journals under the next epoch
    assert rep.journals[1].epoch == old_epoch + 1
    assert rep.lag(1) == 0
    ev = [e for e in obs.ring.records() if e["kind"] == "promote"]
    assert ev and ev[-1]["shard"] == 1 and ev[-1]["b"] == lag
    # the replication-lag gauge family is exported per shard
    snap = obs.snapshot()
    assert any(k.startswith("cache_replica_lag_lsn")
               for k in snap.gauges)
    # and the promoted shard keeps serving + journaling
    for k in rng.integers(0, 600, 500):
        r = svc.access(int(k))
        if not r.hit:
            svc.io_done(int(k))
    rep.poll()
    assert rep.lag(1) == 0


def test_lag_threshold_gates_promotion():
    svc = ShardedClock2QPlus(64, n_shards=2, max_capacity=128,
                             obs=NullSink())
    rep = ShardReplicator(svc, None, lag_threshold=8)
    for k in range(32):
        r = svc.access(k)
        if not r.hit:
            svc.io_done(k)
    assert not rep.should_promote(0)  # way behind: rewarm instead
    rep.poll()
    assert rep.should_promote(0)
    # reattach after a rewarm fallback resumes journaling at epoch+1
    rep.reattach(0)
    assert rep.journals[0].epoch == 1 and rep.lag(0) == 0


# =============================================================================
# Pool wiring: promote-on-loss replaces the cold rewarm
# =============================================================================

def test_pool_promotes_standby_on_shard_loss():
    from repro.configs import get_config, reduced
    from repro.kvcache.pool import BlockPool
    from repro.faults import SHARD_LOSS

    cfg = reduced(get_config("granite-3-8b"))
    plan = FaultPlan(13, [FaultSpec(SHARD_LOSS, ops=("swap_out",),
                                    at=(6,), shard=1)])
    pool = BlockPool(cfg, 32, 8, n_shards=4, faults=plan, replicate=True,
                     lag_threshold=1 << 30, replica_poll=64)
    import jax.numpy as jnp
    zeros = jnp.zeros((cfg.n_layers, pool.bs, cfg.n_kv_heads, cfg.hd))
    rng = np.random.default_rng(2)
    for k in rng.integers(0, 120, 1200):
        slot, needs_fill = pool.lookup(int(k), pin=False)
        if needs_fill:
            pool.write_block(slot, zeros, zeros, key=int(k))
    assert plan.injected > 0
    ev = [e for e in pool.obs.ring.records() if e["kind"] == "promote"]
    assert ev and ev[-1]["shard"] == 1  # standby promoted, not rewarmed
    assert not any(e["kind"] == "shard_rewarm"
                   for e in pool.obs.ring.records())
    # staleness is bounded by the poll interval; a poll drains it fully
    assert pool.replication_lag(1) <= pool.replica_poll
    pool._replicator.poll()
    assert pool.replication_lag(1) == 0


# =============================================================================
# Acceptance: post-failover miss parity, promote vs PR 8 cold rewarm
# =============================================================================

def _suite_trace(name, n):
    import dataclasses
    from repro.core.traces import SUITE
    spec = next(s for s in SUITE if s.name == name)
    return dataclasses.replace(spec, n=n).data()


def _run_sharded(trace, lose_at=None, mode=None, chunk=2048):
    """The PR 8 harness, with a third mode: 'promote' replicates via the
    delta journal and promotes the standby at the loss point; 'rewarm'
    is the ghost-journal cold path; None is the uninjured baseline."""
    svc = ShardedClock2QPlus(2048, n_shards=4, max_capacity=4096,
                             obs=NullSink())
    rep = gj = None
    if mode == "promote":
        rep = ShardReplicator(svc, None, lag_threshold=1 << 30)
    elif mode == "rewarm":
        gj = GhostJournal()
    hits = 0
    done_loss = False
    for lo in range(0, len(trace), chunk):
        batch = trace[lo:lo + chunk]
        hits += int(svc.access_many(batch).sum())
        if gj is not None:
            gj.capture(svc)
        if rep is not None:
            rep.poll()
        if lose_at is not None and not done_loss and lo + chunk >= lose_at:
            if mode == "promote":
                rep.promote(1)
            else:
                failover(svc, 1, gj)
            done_loss = True
    return hits / len(trace)


@pytest.mark.slow
def test_promote_beats_cold_rewarm_miss_parity():
    """Replica promotion restores the EXACT replacement state, so the
    post-failover miss ratio matches the uninterrupted run to the bit
    (gap 0) — at least as close as the ghost rewarm on every trace, and
    strictly closer in aggregate."""
    gaps_promote, gaps_rewarm = [], []
    for name in ("w01-skewed", "w02-balanced", "w03-seqheavy"):
        trace = _suite_trace(name, 48_000)
        base = _run_sharded(trace)
        mid = len(trace) // 2
        gp = abs(base - _run_sharded(trace, mid, "promote"))
        gr = abs(base - _run_sharded(trace, mid, "rewarm"))
        assert gp == 0.0, f"{name}: promotion is not bit-exact (gap {gp})"
        assert gp <= gr, f"{name}: promote gap {gp} worse than rewarm {gr}"
        gaps_promote.append(gp)
        gaps_rewarm.append(gr)
    assert sum(gaps_promote) < sum(gaps_rewarm), \
        "promotion must strictly beat the cold rewarm in aggregate"
