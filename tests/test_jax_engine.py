"""Exact hit/miss parity: vectorized JAX engines vs the pure-Python zoo."""

import numpy as np
import pytest

from repro.core import jax_engine as je
from repro.core import make_policy

CASES = [("fifo", 37, {}), ("clock", 37, {}), ("lru", 31, {}),
         ("s3fifo", 50, {}), ("s3fifo", 50, {"bits": 1}),
         ("clock2q", 41, {}), ("clock2q+", 50, {})]


def _mixed_trace(seed, T=3000, U=350):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, U, T // 2)
    b = np.arange(T // 2) % (U + 70)
    out = np.empty(T, np.int32)
    out[0::2] = a
    out[1::2] = b
    return out


@pytest.mark.parametrize("name,cap,kw", CASES)
@pytest.mark.parametrize("seed", [0, 1])
def test_jax_matches_python(name, cap, kw, seed):
    trace = _mixed_trace(seed)
    h, _ = je.replay_np(name, trace, cap, universe=450, **kw)
    ref = make_policy(name, cap, **kw)
    hr = sum(ref.access(int(k)) for k in trace)
    assert h == hr


def test_vmap_lanes_match_sequential():
    import jax.numpy as jnp
    import jax
    traces = np.stack([_mixed_trace(s, T=600, U=150) for s in range(4)])
    states = jax.vmap(
        lambda _: je.init_state("clock2q+", 30, 250))(jnp.arange(4))
    _, hits = je.replay_batch("clock2q+", states,
                              jnp.asarray(traces, jnp.int32))
    for lane in range(4):
        h, _ = je.replay_np("clock2q+", traces[lane], 30, universe=250)
        assert int(hits[lane].sum()) == h
