"""Sharded concurrent cache service tests: partition fidelity vs the
unsharded cache (acceptance: within 2pp on SUITE traces), batched access
semantics, thread safety, cross-shard rebalancing on the live-resize
protocol, aggregated stats, the JAX sharded-simulation mode, and the
BlockPool sharded backend."""

import threading

import numpy as np
import pytest

from repro.core import jax_engine as je, traces
from repro.core.prodcache import EMPTY, ProdClock2QPlus
from repro.shardcache import (
    ShardedClock2QPlus, replay_threaded, scalability_sweep, shard_of,
    shard_of_np, unsharded_miss_ratio,
)
from repro.shardcache.sharded import apportion

PARITY_SPECS = traces.SUITE[:3]  # >= 3 SUITE traces (acceptance criterion)


def _meta_prefix(spec, n=120_000):
    return traces.derive_metadata(spec.data())[:n]


_cap_for = traces.suite_capacity  # shared with benchmarks/shard.py


# -- partitioning ---------------------------------------------------------------

def test_shard_hash_consistent_and_balanced():
    keys = np.arange(100_000, dtype=np.int64)
    sids = shard_of_np(keys, 8)
    assert sids.min() >= 0 and sids.max() < 8
    # scalar and vectorized hashes agree
    for k in (0, 1, 17, 999_999, 2**40 + 3):
        assert shard_of(k, 8) == shard_of_np(np.asarray([k]), 8)[0]
    # roughly balanced: no shard holds more than 2x its fair share
    counts = np.bincount(sids, minlength=8)
    assert counts.max() < 2 * len(keys) / 8


@pytest.mark.parametrize("spec", PARITY_SPECS, ids=lambda s: s.name)
@pytest.mark.parametrize("n_shards", [4, 8])
def test_sharded_miss_ratio_parity_with_unsharded(spec, n_shards):
    """Acceptance: sharding at equal total capacity moves the miss ratio
    by < 2 percentage points on SUITE traces."""
    tr = _meta_prefix(spec)
    cap = _cap_for(tr)
    base = unsharded_miss_ratio(tr, cap)
    sh = ShardedClock2QPlus(cap, n_shards=n_shards)
    hits = sh.access_many(tr)
    mr = 1.0 - hits.mean()
    assert abs(mr - base) < 0.02, (spec.name, n_shards, mr, base)


def test_sharded_jax_engine_parity():
    """The vmap sharded simulation tracks the unsharded lane within 2pp."""
    tr = traces.zipf_trace(40_000, 4096, alpha=1.1, seed=3)
    _, base = je.replay_np("clock2q+", tr, 256, universe=4096)
    for n in (4, 8):
        _, mr = je.sharded_replay_np("clock2q+", tr, 256, n, universe=4096)
        assert abs(mr - base) < 0.02, (n, mr, base)


def test_sharded_jax_hits_align_with_request_order():
    """Merged hit array: a key's first access is always a miss, and a
    repeat access with no intervening evictions (tiny working set) hits."""
    tr = np.asarray([5, 9, 5, 9, 5, 9, 100, 5, 100], dtype=np.int64)
    hits = je.sharded_replay("clock2q+", tr, 64, 4, universe=128)
    assert not hits[0] and not hits[1] and not hits[6]  # cold misses
    assert hits[2:6].all() and hits[7] and hits[8]


# -- access semantics ------------------------------------------------------------

def test_access_many_matches_per_shard_sequential_replay():
    """Batched dispatch preserves per-shard order: each shard sees exactly
    the subsequence of keys that hash to it, in input order."""
    rng = np.random.default_rng(7)
    keys = rng.integers(0, 500, 20_000).astype(np.int64)
    n = 4
    sh = ShardedClock2QPlus(64, n_shards=n)
    got = sh.access_many(keys)
    sids = shard_of_np(keys, n)
    want = np.zeros(len(keys), dtype=bool)
    for s in range(n):
        idx = np.nonzero(sids == s)[0]
        ref = ProdClock2QPlus(sh.shards[s].capacity,
                              max_capacity=sh.shard_max)
        for i in idx.tolist():
            want[i] = ref.access(int(keys[i])).hit
    assert (got == want).all()


def test_access_globalizes_block_handles():
    sh = ShardedClock2QPlus(64, n_shards=4, track_io=True)
    seen = {}
    rng = np.random.default_rng(1)
    for k in rng.integers(0, 300, 5000):
        r = sh.access(int(k))
        assert 0 <= r.block < sh.n_slots
        sid = sh.shard_of(int(k))
        assert r.block // sh.stride == sid  # handle encodes the shard
        if r.evicted_block != EMPTY:
            assert r.evicted_block // sh.stride == sid
        seen[int(k)] = r.block
        sh.io_done(int(k))
    # resident keys report the same slot via slot_of
    for k, blk in seen.items():
        if sh.contains(k):
            assert sh.slot_of(k) == blk


def test_aggregated_stats_and_flows():
    sh = ShardedClock2QPlus(64, n_shards=4)
    rng = np.random.default_rng(2)
    keys = rng.integers(0, 400, 10_000)
    hits = sh.access_many(keys)
    assert sh.hits + sh.misses == len(keys)
    assert sh.hits == int(hits.sum())
    assert sum(sh.flows.values()) == sum(
        sum(s.flows.values()) for s in sh.shards)
    assert len(sh) == sum(len(s) for s in sh.shards) <= 64
    per = sh.shard_stats()
    assert sum(p["hits"] for p in per) == sh.hits


def test_dirty_pin_io_route_to_owning_shard():
    sh = ShardedClock2QPlus(32, n_shards=4, track_io=True)
    sh.access(42, dirty=True, pin=True)
    assert 42 in sh
    assert 42 in sh.dirty_keys()
    owner = sh.shards[sh.shard_of(42)]
    assert owner.dirty_keys() == [42]
    sh.io_done(42)
    sh.clean(42)
    assert sh.dirty_keys() == []
    sh.unpin(42)
    sh.set_dirty(42)
    assert 42 in sh.dirty_keys()


def test_access_many_completes_io_on_track_io_cache():
    """Batched replay on a track_io cache must not leave its own misses
    wedged DOING-IO (they would be unevictable forever)."""
    sh = ShardedClock2QPlus(32, n_shards=4, track_io=True)
    rng = np.random.default_rng(4)
    # churn far past capacity: hangs at the first all-DOING-IO shard if
    # the batch path leaks fill obligations
    hits = sh.access_many(rng.integers(0, 500, 20_000))
    assert sh.hits + sh.misses == 20_000
    for s in sh.shards:
        assert not s.io[s.key != EMPTY].any()
    # an access()-admitted in-flight entry is NOT completed by a batch
    r = sh.access(123456)
    assert r.io_pending
    sh.access_many(np.asarray([123456], dtype=np.int64))
    owner = sh.shards[sh.shard_of(123456)]
    assert bool(owner.io[owner._hash_lookup(123456)])


# -- threading -------------------------------------------------------------------

def test_threaded_replay_conserves_requests_and_fidelity():
    tr = _meta_prefix(PARITY_SPECS[0], 40_000)
    cap = _cap_for(tr)
    serial = replay_threaded(ShardedClock2QPlus(cap, n_shards=8), tr, 1)
    for t in (2, 4):
        cache = ShardedClock2QPlus(cap, n_shards=8)
        rep = replay_threaded(cache, tr, t)
        assert rep.n_requests == len(tr)
        assert rep.hits == cache.hits  # worker counts match cache stats
        assert abs(rep.miss_ratio - serial.miss_ratio) < 0.05
    reports = scalability_sweep(tr[:10_000], cap, n_shards=8, threads=(1, 2))
    assert [r.n_threads for r in reports] == [1, 2]
    assert all(r.throughput > 0 for r in reports)


def test_concurrent_access_no_corruption():
    """Hammer one cache from 4 threads; shard invariants must hold: every
    request is counted, and each shard's payload handles stay unique."""
    sh = ShardedClock2QPlus(48, n_shards=4)
    rng = np.random.default_rng(3)
    chunks = [rng.integers(0, 600, 8_000).astype(np.int64) for _ in range(4)]

    def worker(c):
        sh.access_many(c, dirty=False)

    threads = [threading.Thread(target=worker, args=(c,)) for c in chunks]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert sh.hits + sh.misses == sum(len(c) for c in chunks)
    for s in sh.shards:
        live = s.block[s.key != EMPTY].tolist()
        assert len(set(live)) == len(live)
        assert set(s.free_blocks).isdisjoint(live)


# -- rebalancing -----------------------------------------------------------------

def test_apportion_sums_and_bounds():
    assert sum(apportion([1, 1, 1, 1], 64, 2, 100)) == 64
    caps = apportion([100, 1, 1, 1], 40, 2, 16)
    assert sum(caps) == 40 and caps[0] == 16 and all(c >= 2 for c in caps)
    with pytest.raises(ValueError):
        apportion([1, 1], 100, 2, 10)


def test_rebalance_moves_capacity_to_hot_shard():
    n = 4
    sh = ShardedClock2QPlus(64, n_shards=n)
    hot_sid = 2
    hot = [k for k in range(20_000) if shard_of(k, n) == hot_sid][:800]
    for k in hot:
        sh.access(k)  # heavy miss traffic on one shard
    caps = sh.rebalance()
    assert sum(caps) == 64
    assert caps[hot_sid] == max(caps) > 64 // n
    assert sh.shard_capacities == caps
    # service stays correct through the migration
    for k in hot[:100]:
        r = sh.access(k)
        assert 0 <= r.block < sh.n_slots
        assert sh.contains(k)


def test_rebalance_incremental_steps_interleaved_with_traffic():
    sh = ShardedClock2QPlus(64, n_shards=4)
    rng = np.random.default_rng(5)
    for k in rng.integers(0, 1000, 4000):
        sh.access(int(k))
    sh.rebalance(complete=False)
    done = False
    for k in rng.integers(0, 1000, 3000):
        resident_before = sh.contains(int(k))
        r = sh.access(int(k))
        assert r.hit == resident_before  # lookups stay exact mid-migration
        done = sh.rebalance_step(4)
    while not done:
        done = sh.rebalance_step(256)
    for s in sh.shards:
        assert len(s) <= s.small_cap + s.main_cap


def test_repeated_rebalance_without_completion_is_safe():
    """Retargeting a shard mid-migration must not lose resident entries."""
    sh = ShardedClock2QPlus(64, n_shards=4, rebalance_headroom=3.0)
    rng = np.random.default_rng(6)
    keys = rng.integers(0, 300, 2000)
    for k in keys:
        sh.access(int(k))
    for caps in ([10, 10, 34, 10], [28, 12, 12, 12], [16, 16, 16, 16]):
        sh.set_shard_capacities(caps, complete=False)
        resident = [int(k) for k in set(keys.tolist()) if sh.contains(int(k))]
        for k in resident:
            assert sh.access(k).hit  # lookups stay correct mid-migration
    while not sh.rebalance_step(256):
        pass
    assert sh.shard_capacities == [16, 16, 16, 16]


def test_retarget_with_pinned_entry_does_not_deadlock():
    """A pinned entry can block a shrink's out-of-bounds drain forever;
    retargeting that shard again must NOT spin-wait on the drain (which
    would deadlock unpin() on the shard lock) — only the hash migration
    is completed, the drain carries over to the new targets."""
    sh = ShardedClock2QPlus(64, n_shards=4, rebalance_headroom=3.0)
    hot_sid = 1
    keys = [k for k in range(20_000) if shard_of(k, 4) == hot_sid][:200]
    for k in keys:
        sh.access(k)
    # pin the small-FIFO occupant of slot 1: beyond the boundary once the
    # shrink to capacity 4 drops small_cap from 2 to 1
    shard = sh.shards[hot_sid]
    pinned = next(k for k in keys if shard._hash_lookup(k) == 1)
    sh.access(pinned, pin=True)
    # complete=True must RETURN with the pinned entry undrainable (the
    # unpin may be waiting on this very thread), leaving the shard pending
    sh.set_shard_capacities([44, 4, 8, 8], complete=True)    # deep shrink
    assert not sh.rebalance_step(512)  # pinned entry keeps the drain open
    # retarget the still-draining shard: must return, not hang
    sh.set_shard_capacities([16, 16, 16, 16], complete=False)
    assert sh.contains(pinned)
    sh.unpin(pinned)
    while not sh.rebalance_step(256):
        pass
    for s in sh.shards:
        assert len(s) <= s.small_cap + s.main_cap


def test_complete_retarget_finishes_rehash_with_tiny_steps():
    """Reviewer repro: a grow-heavy retarget with tiny steps has zero
    drain work from the start, which must NOT trip the no-progress break
    while hash migration (never blockable) is still pending."""
    sh = ShardedClock2QPlus(64, n_shards=4, rebalance_headroom=3.0)
    rng = np.random.default_rng(8)
    for k in rng.integers(0, 6000, 6000):
        sh.access(int(k))
    sh.set_shard_capacities([40, 8, 8, 8], steps_per_call=2, complete=True)
    assert sh.rebalance_step(1)  # nothing pending
    assert all(s.old_buckets is None for s in sh.shards)
    assert sh.shard_capacities == [40, 8, 8, 8]


def test_concurrent_rebalance_conserves_total_capacity():
    """Interleaved retargeting from two threads must never leave shard
    targets overcommitting the stated total budget."""
    sh = ShardedClock2QPlus(64, n_shards=4, rebalance_headroom=3.0)
    rng = np.random.default_rng(9)
    for k in rng.integers(0, 3000, 4000):
        sh.access(int(k))
    caps_sets = ([30, 12, 12, 10], [10, 12, 12, 30])

    def retarget(caps):
        for _ in range(20):
            sh.set_shard_capacities(caps, complete=True)

    threads = [threading.Thread(target=retarget, args=(c,))
               for c in caps_sets]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert sum(sh.shard_capacities) == 64
    assert sh.shard_capacities in [list(c) for c in caps_sets]


def test_total_resize_preserves_shard_proportions():
    sh = ShardedClock2QPlus(64, n_shards=4)
    sh.set_shard_capacities([32, 16, 8, 8])
    sh.begin_resize(32)
    while not sh.resize_step(256):
        pass
    assert sum(sh.shard_capacities) == 32
    caps = sh.shard_capacities
    assert caps[0] == max(caps)  # proportions survive the total resize


# -- BlockPool integration -------------------------------------------------------

def test_blockpool_sharded_backend():
    from repro.configs import get_config, reduced
    from repro.kvcache.pool import BlockPool
    cfg = reduced(get_config("granite-3-8b"))
    pool = BlockPool(cfg, 32, 8, n_shards=4)
    assert pool.kpool.shape[1] == pool.policy.n_slots
    rng = np.random.default_rng(0)
    for k in rng.integers(0, 120, 3000):
        slot, needs_fill = pool.lookup(int(k), pin=False)
        assert 0 <= slot < pool.policy.n_slots
        if needs_fill:
            pool.policy.io_done(int(k))
            pool.policy.set_dirty(int(k))
        pool.run_flusher()
    assert pool.stats.hits > 0 and pool.stats.swap_out > 0
    pool.resize(24)
    assert sum(pool.policy.shard_capacities) == 24


def test_blockpool_resize_returns_with_pinned_blocks():
    """pool.resize during a shrink must return (not spin) while pinned /
    in-flight blocks sit beyond the boundary — the unpin/io_done that
    would release them may be waiting on this very thread."""
    from repro.configs import get_config, reduced
    from repro.kvcache.pool import BlockPool
    cfg = reduced(get_config("granite-3-8b"))
    for n_shards in (0, 4):  # both policy backends
        pool = BlockPool(cfg, 32, 8, n_shards=n_shards)
        rng = np.random.default_rng(1)
        pinned = []
        for k in rng.integers(0, 80, 400):
            k = int(k)
            pin = len(pinned) < 6 and k not in pinned
            slot, fill = pool.lookup(k, pin=pin)
            if fill:
                pool.policy.io_done(k)
            if pin:
                pinned.append(k)
        pool.resize(8)   # deep shrink with 6 pinned blocks: must return
        for k in pinned:
            pool.unpin(k)
            assert pool.policy.contains(k)  # pinned survived the shrink
        pool.resize(8)   # drains the rest now that pins are gone
        assert pool.policy.undrained_count() == 0
