"""Differential fuzz: the unified capacity-masked step vs the Python
reference zoo, for EVERY registered lane policy, at random
(capacity, window_frac, small_frac, ghost_frac, skip_limit, bits)
points — per-request hit equality, not just totals.

Uses hypothesis when installed (CI does); otherwise falls back to a
seeded-random sampler so the fuzz still RUNS (no importorskip) in bare
environments.  Both paths share one sampler: hypothesis just drives the
seed, which keeps shrinking meaningful and the two paths identical.

Physical queue sizes are bucketed to powers of two before init, so the
jitted replay compiles once per (policy, bucket) rather than once per
sampled point.
"""

import numpy as np
import pytest

import repro.core.engine as engine
from repro.core import make_policy

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

UNIVERSE = 512  # fixed dense-id space: shared across every sampled point
T = 1200

POLICIES = sorted(engine.engine_names())


def _sample_point(rng: np.random.Generator) -> dict:
    return dict(
        capacity=int(rng.integers(2, 97)),
        window_frac=float(np.round(rng.uniform(0.0, 1.5), 3)),
        small_frac=float(np.round(rng.uniform(0.05, 0.6), 3)),
        ghost_frac=float(np.round(rng.uniform(0.1, 1.5), 3)),
        skip_limit=int(rng.choice([0, 0, 1, 2, 3, 5])),
        bits=int(rng.choice([1, 2])),
    )


def _trace(rng: np.random.Generator, capacity: int) -> np.ndarray:
    """Half uniform-random, half scanning — misses, ghost revisits and
    clock pressure all occur; universe scales with capacity so hits do
    too."""
    u = int(min(UNIVERSE, max(4, capacity * rng.uniform(1.5, 4.0))))
    out = np.empty(T, np.int32)
    out[0::2] = rng.integers(0, u, T // 2)
    out[1::2] = np.arange(T // 2) % min(UNIVERSE, u + capacity)
    return out


def _zoo_kwargs(policy: str, p: dict) -> dict:
    """Engine config -> zoo constructor kwargs.  skip_limit translates
    between the conventions: engine 0 = unlimited = zoo None."""
    sk = None if p["skip_limit"] == 0 else p["skip_limit"]
    if policy == "clock2q+":
        return dict(small_frac=p["small_frac"], ghost_frac=p["ghost_frac"],
                    window_frac=p["window_frac"], skip_limit=sk)
    if policy == "clock2q":
        # the zoo's Clock2Q has no window knob (never refs in small);
        # the engine preset encodes that as window_frac=10.0
        return dict(small_frac=p["small_frac"], ghost_frac=p["ghost_frac"],
                    skip_limit=sk)
    if policy == "s3fifo":
        return dict(small_frac=p["small_frac"], ghost_frac=p["ghost_frac"],
                    bits=p["bits"], skip_limit=sk)
    return {}


def _engine_overrides(eng: "engine.PolicyEngine", policy: str,
                      p: dict) -> dict:
    kw = {k: p[k] for k in eng.knobs}
    if policy == "clock2q":
        kw.pop("window_frac", None)  # keep the preset (see _zoo_kwargs)
    return kw


def check_point(policy: str, seed: int) -> None:
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    p = _sample_point(rng)
    eng = engine.get_engine(policy)
    cfg = eng.config(p["capacity"], **_engine_overrides(eng, policy, p))
    trace = _trace(rng, p["capacity"])

    sizes = eng.sizes_fn(cfg)
    phys = tuple(1 << max(0, (s - 1).bit_length()) for s in sizes)
    state = eng.init_config(cfg, UNIVERSE, phys)
    _, hits = engine.replay(policy, state, jnp.asarray(trace))
    eng_hits = np.asarray(hits).astype(bool)

    ref = make_policy(policy, p["capacity"], **_zoo_kwargs(policy, p))
    ref_hits = np.fromiter((ref.access(int(k)) for k in trace), bool, T)

    where = np.nonzero(eng_hits != ref_hits)[0]
    assert where.size == 0, (
        f"{policy} diverges from the zoo at request {where[:5]} "
        f"(of {where.size}) for {cfg}")


if HAVE_HYPOTHESIS:
    @pytest.mark.parametrize("policy", POLICIES)
    @settings(max_examples=12, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_engine_matches_zoo_fuzz(policy, seed):
        check_point(policy, seed)
else:
    @pytest.mark.parametrize("policy", POLICIES)
    @pytest.mark.parametrize("seed", range(8))
    def test_engine_matches_zoo_fuzz(policy, seed):
        check_point(policy, seed + 1000 * POLICIES.index(policy))
