"""Unit tests for the reference policy zoo (the paper's algorithm +
baselines)."""

import numpy as np
import pytest

from repro.core import make_policy, policy_names, stats


def run_trace(pol, trace):
    return [pol.access(k) for k in trace]


ALL = [p for p in policy_names() if p != "belady"]


@pytest.mark.parametrize("name", ALL)
def test_capacity_never_exceeded(name):
    pol = make_policy(name, 10)
    rng = np.random.default_rng(0)
    for k in rng.integers(0, 100, 2000):
        pol.access(int(k))
        assert len(pol) <= 10


@pytest.mark.parametrize("name", ALL)
def test_repeat_single_key_hits(name):
    pol = make_policy(name, 4)
    assert pol.access(7) is False
    for _ in range(10):
        assert pol.access(7) is True


def test_lru_exactness():
    pol = make_policy("lru", 3)
    seq = [1, 2, 3, 1, 4, 2]
    # classic: after 1,2,3 -> access 1 (hit), 4 evicts 2, access 2 miss
    got = run_trace(pol, seq)
    assert got == [False, False, False, True, False, False]


def test_clock_second_chance():
    pol = make_policy("clock", 2)
    assert pol.access(1) is False
    assert pol.access(2) is False
    assert pol.access(1) is True   # ref bit set on 1
    assert pol.access(3) is False  # evicts 2 (1 gets second chance)
    assert pol.access(1) is True
    assert pol.access(2) is False


def test_belady_is_lower_bound():
    rng = np.random.default_rng(1)
    trace = list(rng.integers(0, 60, 3000))
    opt = stats.simulate("belady", trace, 20)
    for name in ("lru", "clock", "s3fifo", "clock2q+", "arc", "2q"):
        r = stats.simulate(name, trace, 20)
        assert r.misses >= opt.misses, name


def test_2q_small_fifo_hits_do_nothing():
    # a block hit while in A1in must still be evicted FIFO-order
    pol = make_policy("2q", 8, small_frac=0.5)  # small cap 4
    for k in (1, 2, 3, 4):
        pol.access(k)
    assert pol.access(1) is True        # hit in A1in: no promotion
    pol.access(5)                       # evicts 1 -> ghost
    assert 1 not in pol
    assert pol.access(1) is False       # ghost hit -> promoted to main
    assert 1 in pol


def test_s3fifo_bits_promotion_threshold():
    # 2-bit: one re-reference is NOT enough to enter the main queue
    for bits, hit_after in ((1, True), (2, False)):
        pol = make_policy("s3fifo", 20, bits=bits)  # small cap 2
        pol.access(100)
        pol.access(100)               # 1 re-reference
        pol.access(101)
        pol.access(102)               # 100 evicted from small
        resident = 100 in pol
        assert resident == hit_after, f"bits={bits}"


def test_clock2qplus_correlation_window_filters_bursts():
    """Correlated burst while inside the window must NOT set the ref bit;
    a later re-reference after aging past the window must."""
    pol = make_policy("clock2q+", 40)  # small=4, window=2
    pol.access(7)
    pol.access(7)   # age 0 < 2: no ref
    pol.access(7)
    pol.access(8)
    pol.access(9)   # 7 aged 2 now
    burst_key_in_small = 7 in pol
    assert burst_key_in_small
    # evict 7: insert 2 more new keys -> small (cap 4) displaces 7
    pol.access(10)
    pol.access(11)
    assert 7 not in pol, "burst-only block must be demoted to ghost"
    # now a block that is re-referenced AFTER the window
    pol2 = make_policy("clock2q+", 40)
    pol2.access(7)
    pol2.access(8)
    pol2.access(9)   # 7 now aged 2 == window
    pol2.access(7)   # sets ref
    pol2.access(10)
    pol2.access(11)  # 7 evicted from small -> promoted to MAIN
    assert 7 in pol2, "post-window re-reference must promote"


def test_clock2qplus_flow_counters():
    pol = make_policy("clock2q+", 30)
    rng = np.random.default_rng(3)
    for k in rng.integers(0, 100, 3000):
        pol.access(int(k))
    f = pol.flows
    assert f["small_to_ghost"] > 0
    assert f["ghost_to_main"] > 0


def test_dirty_simplified_never_evicts_dirty_from_small():
    pol = make_policy("clock2q+", 30, dirty_mode="simplified")
    pol.access(1, dirty=True)
    for k in range(2, 20):
        pol.access(k)
    assert 1 in pol, "dirty block must be skipped by small-FIFO eviction"


def test_dirty_accurate_promotes_refset_dirty():
    pol = make_policy("clock2q+", 40, dirty_mode="accurate")
    pol.access(1, dirty=True)
    pol.access(2)
    pol.access(3)     # age(1) = 2 = window
    pol.access(1)     # sets ref
    for k in range(4, 10):
        pol.access(k)
    assert 1 in pol


def test_skip_limit_forces_eviction():
    pol = make_policy("clock2q+", 40, skip_limit=1)
    rng = np.random.default_rng(4)
    for k in rng.integers(0, 60, 4000):
        pol.access(int(k))
    assert max(pol.main.skipped_per_eviction or [0]) <= 36


def test_ghost_ring_tombstone_semantics():
    from repro.core.policies.two_q import _GhostFIFO
    g = _GhostFIFO(3)
    g.push(1)
    g.push(2)
    g.remove(1)        # promoted: tombstone
    g.push(3)
    g.push(4)          # ring holds (2,3,4); 1's slot was reclaimed
    assert 1 not in g and 2 in g and 3 in g and 4 in g
    g.push(5)          # wraps: 2 falls off
    assert 2 not in g and 5 in g
