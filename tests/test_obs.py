"""Observability subsystem: instruments, snapshot algebra, export
formats, the ring trace, and the wiring through the cache stack.

The load-bearing guarantee is EXACT mergeability: per-shard registries
are lock-free because nothing aggregates on the access path, so the
merged snapshot must equal the sum of per-shard deltas bit-for-bit —
asserted here under a real 4-thread sharded replay.
"""

import json
import pathlib
import sys

import numpy as np
import pytest

from repro.obs import (
    EV_EVICT, EV_RETUNE, EV_SNAPSHOT, EVENT_NAMES, FLOW_KINDS, EventRing,
    NullRing, NullSink, ObsSink, Snapshot, delta, merge, snapshot,
    to_prometheus,
)
from repro.obs.metrics import (
    Counter, Gauge, Histogram, Registry, parse_sample_key, sample_key,
)

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))


def zipf_trace(n=4000, universe=256, seed=0):
    rng = np.random.default_rng(seed)
    return rng.zipf(1.2, size=n).astype(np.int64) % universe


# -- instruments ---------------------------------------------------------------

def test_counter_gauge_basics():
    c = Counter()
    c.value += 3
    c.inc(2)
    assert c.sample() == 5
    g = Gauge()
    g.set(2.5)
    g.inc()
    g.dec(0.5)
    assert g.sample() == 3.0


def test_histogram_log2_bucketing():
    h = Histogram(base=1.0, n_buckets=6)
    # bucket 0: v < 1; bucket i: 2**(i-1) <= v < 2**i; top = catch-all
    for v, want in [(0.5, 0), (1.0, 1), (1.9, 1), (2.0, 2), (3.9, 2),
                    (4.0, 3), (1e9, 5)]:
        before = h.counts.copy()
        h.observe(v)
        (changed,) = np.nonzero(h.counts - before)
        assert changed[0] == want, (v, want, changed)
    assert h.count == 7
    assert h.bounds()[-1] == float("inf")
    assert h.bounds()[:3] == [1.0, 2.0, 4.0]
    assert np.isnan(Histogram().quantile(0.5))


def test_histogram_quantile_monotone():
    h = Histogram(base=1e-3, n_buckets=16)
    for v in [0.001, 0.002, 0.004, 0.008, 0.1, 0.1, 0.1, 2.0]:
        h.observe(v)
    qs = [h.quantile(q) for q in (0.1, 0.5, 0.9, 1.0)]
    assert qs == sorted(qs)


def test_sample_key_round_trip():
    for name, labels in [("x_total", {}),
                         ("hits", {"shard": "3", "queue": "small"}),
                         ("a", {"b": "c d", "e": "1"})]:
        key = sample_key(name, labels)
        assert parse_sample_key(key) == (name, labels)
    # label names are sorted -> one canonical identity per series
    assert sample_key("n", {"b": "2", "a": "1"}) == 'n{a="1",b="2"}'


def test_registry_conflicts_and_base_labels():
    reg = Registry({"shard": "7"})
    fam = reg.counter("hits_total", ("queue",))
    fam.labels("small").value += 2
    fam.labels("main").value += 1
    with pytest.raises(ValueError):
        reg.gauge("hits_total")  # kind conflict
    with pytest.raises(ValueError):
        reg.counter("hits_total", ("other",))  # labelnames conflict
    with pytest.raises(ValueError):
        fam.labels()  # arity
    got = {k: v for _, _, k, v in reg.samples()}
    assert got == {'hits_total{queue="small",shard="7"}': 2,
                   'hits_total{queue="main",shard="7"}': 1}


def test_on_collect_runs_before_snapshot():
    sink = ObsSink(src="t")
    g = sink.gauge("occupancy", ()).labels()
    state = {"n": 41}
    sink.on_collect(lambda: g.set(float(state["n"])))
    state["n"] = 42
    assert sink.snapshot().gauges["occupancy"] == 42.0


# -- event ring ----------------------------------------------------------------

def test_ring_wraparound_and_sequence():
    ring = EventRing(capacity=8, src="r")
    for i in range(20):
        ring.emit(EV_EVICT, shard=i % 3, a=i, b=i * 2, c=i / 2)
    assert ring.n == 20
    assert ring.dropped == 12
    recs = ring.records()
    assert len(recs) == 8
    assert [r["seq"] for r in recs] == list(range(12, 20))  # oldest first
    assert recs[0] == dict(seq=12, src="r", kind="evict", shard=0,
                           a=12, b=24, c=6.0)


def test_null_ring_is_inert():
    ring = NullRing(src="r")
    ring.emit(EV_EVICT, a=1)
    assert not ring.enabled
    assert ring.records() == [] and ring.dropped == 0 and ring.n == 0


# -- snapshot algebra + export -------------------------------------------------

def one_sink(src, shard, hits, evicts):
    sink = ObsSink(src=src, labels={"shard": str(shard)})
    sink.counter("hits_total", ()).labels().value += hits
    h = sink.histogram("lat_seconds", ()).labels()
    for v in [1e-6] * hits:
        h.observe(v)
    for i in range(evicts):
        sink.emit(EV_EVICT, shard=shard, a=i)
    sink.gauge("cap", ()).labels().set(100.0 + shard)
    return sink


def test_snapshot_json_round_trip():
    snap = one_sink("a", 0, 5, 3).snapshot(ts=1.5)
    back = Snapshot.from_json(snap.to_json())
    assert back == snap
    # inf bucket bound survives JSON (json emits Infinity)
    assert back.hists['lat_seconds{shard="0"}']["le"][-1] == float("inf")


def test_merge_adds_counters_and_hists_keeps_events():
    s0 = one_sink("a", 0, 5, 2).snapshot(ts=1.0)
    s1 = one_sink("b", 1, 7, 1).snapshot(ts=2.0)
    m = merge([s0, s1])
    assert m.ts == 2.0
    assert m.counters['hits_total{shard="0"}'] == 5
    assert m.counters['hits_total{shard="1"}'] == 7
    assert m.hists['lat_seconds{shard="0"}']["count"] == 5
    assert len(m.events) == 3
    assert m.gauges['cap{shard="1"}'] == 101.0
    # same-key merge: counters add
    m2 = merge([s0, one_sink("a", 0, 3, 0).snapshot(ts=3.0)])
    assert m2.counters['hits_total{shard="0"}'] == 8
    assert m2.hists['lat_seconds{shard="0"}']["count"] == 8


def test_delta_subtracts_and_filters_events():
    sink = one_sink("a", 0, 5, 2)
    s0 = sink.snapshot(ts=1.0)
    sink.registry.families["hits_total"].labels().value += 4
    sink.emit(EV_EVICT, shard=0, a=99)
    s1 = sink.snapshot(ts=2.0)
    d = delta(s0, s1)
    assert d.counters['hits_total{shard="0"}'] == 4
    assert [e["a"] for e in d.events] == [99]
    assert d.dropped_events == 0
    # delta then re-add: round-trips to the newer snapshot
    back = merge([s0, d])
    assert back.counters == s1.counters
    assert back.hists == s1.hists


def test_prometheus_exposition():
    snap = one_sink("a", 0, 3, 1).snapshot(ts=1.0)
    text = to_prometheus(snap)
    assert "# TYPE hits_total counter" in text
    assert '\nhits_total{shard="0"} 3\n' in text
    assert "# TYPE lat_seconds histogram" in text
    assert 'le="+Inf"' in text
    assert 'lat_seconds_count{shard="0"} 3' in text
    # buckets are cumulative: the +Inf bucket equals _count
    lines = [ln for ln in text.splitlines() if ln.startswith(
        "lat_seconds_bucket")]
    assert lines[-1].endswith(" 3")


def test_null_sink_counts_but_exports_nothing():
    sink = NullSink(src="n")
    c = sink.counter("hits_total", ()).labels()
    c.value += 7
    sink.emit(EV_EVICT, a=1)
    assert sink.null and not sink.ring.enabled
    assert c.value == 7  # instruments back the semantic stats surfaces
    snap = sink.snapshot()
    assert snap.counters == {} and snap.events == []
    assert snap.meta["null"] == "1"


# -- property tests (hypothesis where available) --------------------------------

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.integers(0, 10_000), max_size=200),
           st.integers(1, 32))
    def test_ring_retains_last_capacity(seqs, cap):
        ring = EventRing(capacity=cap, src="p")
        for a in seqs:
            ring.emit(EV_SNAPSHOT, a=a)
        recs = ring.records()
        assert [r["a"] for r in recs] == seqs[-cap:] if seqs else recs == []
        assert ring.dropped == max(0, len(seqs) - cap)

    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.floats(1e-9, 1e3), max_size=100))
    def test_hist_merge_equals_single(vals):
        h1, h2, both = Histogram(), Histogram(), Histogram()
        for i, v in enumerate(vals):
            (h1 if i % 2 else h2).observe(v)
            both.observe(v)
        s = snapshot([])
        from repro.obs.export import _hist_add
        _hist_add(s.hists, "h", h1.sample())
        _hist_add(s.hists, "h", h2.sample())
        assert s.hists["h"]["counts"] == both.sample()["counts"]
        assert s.hists["h"]["count"] == both.count
except ImportError:  # pragma: no cover - hypothesis is optional
    pass


# -- wiring through the cache stack --------------------------------------------

def test_prod_cache_instrumented_vs_null_identical():
    from repro.core.prodcache import ProdClock2QPlus

    trace = zipf_trace()
    live = ProdClock2QPlus(64)
    nulled = ProdClock2QPlus(64, obs=NullSink(src="n"))
    for k in trace.tolist():
        live.access(k)
        nulled.access(k)
    assert live.hits == nulled.hits and live.misses == nulled.misses
    assert live.flows == nulled.flows
    assert set(live.flows) == set(FLOW_KINDS)
    assert live.hits + live.misses == trace.size
    snap = live.obs.snapshot()
    assert snap.counters['cache_misses_total{shard="0"}'] == live.misses
    hit_sum = sum(v for k, v in snap.counters.items()
                  if k.startswith("cache_hits_total"))
    assert hit_sum == live.hits
    kinds = {e["kind"] for e in snap.events}
    assert "evict" in kinds and "window_enter" in kinds
    assert snap.gauges['cache_capacity{segment="total",shard="0"}'] == 64
    assert nulled.obs.snapshot().counters == {}


def test_flow_keys_match_between_prod_and_sharded():
    from repro.core.prodcache import ProdClock2QPlus
    from repro.shardcache import ShardedClock2QPlus

    trace = zipf_trace(n=2000)
    prod = ProdClock2QPlus(64)
    shard = ShardedClock2QPlus(64, n_shards=4)
    for k in trace.tolist():
        prod.access(k)
        shard.access(k)
    # satellite: one schema — identical key sets from the same counter
    # families, and every key is a canonical FLOW_KINDS member
    assert set(prod.flows) == set(shard.flows) == set(FLOW_KINDS)
    assert sum(shard.flows.values()) > 0


def test_sharded_merge_equals_sum_of_shard_deltas():
    """4-thread replay: the merged snapshot must equal the sum of
    per-shard deltas EXACTLY (lock-free-within-shard counting loses
    nothing; counters/histogram buckets add)."""
    from repro.shardcache import ShardedClock2QPlus
    from repro.shardcache.replay import replay_threaded

    cache = ShardedClock2QPlus(128, n_shards=4)
    sinks = [s.obs for s in cache.shards]
    befores = [s.snapshot(ts=0.0) for s in sinks]
    rep = replay_threaded(cache, zipf_trace(n=8000), n_threads=4,
                          batch_size=256, obs=cache.obs)
    afters = [s.snapshot(ts=1.0) for s in sinks]
    deltas = [delta(b, a) for b, a in zip(befores, afters)]
    summed = merge(deltas)
    merged = merge(afters)  # fresh cache: snapshot == delta-from-zero
    assert summed.counters == merged.counters
    assert summed.hists == merged.hists
    # and the counters agree with the replay's ground truth
    hit_sum = sum(v for k, v in merged.counters.items()
                  if k.startswith("cache_hits_total"))
    miss_sum = sum(v for k, v in merged.counters.items()
                   if k.startswith("cache_misses_total"))
    assert hit_sum == rep.hits
    assert hit_sum + miss_sum == rep.n_requests
    # per-shard series are disjoint labeled keys
    shards_seen = {parse_sample_key(k)[1]["shard"]
                   for k in merged.counters if "shard=" in k}
    assert shards_seen == {"0", "1", "2", "3"}
    # full-stack snapshot renders to Prometheus without error
    full = cache.obs_snapshot()
    assert "cache_hits_total" in to_prometheus(full)
    assert any(h["count"] > 0 for h in full.hists.values())


def test_sharded_rebalance_and_resize_events():
    from repro.shardcache import ShardedClock2QPlus

    cache = ShardedClock2QPlus(64, n_shards=2, max_capacity=128)
    for k in zipf_trace(n=500).tolist():
        cache.access(k)
    caps = [s.capacity for s in cache.shards]
    cache.set_shard_capacities([caps[0] + 8, caps[1] - 8])
    while not cache.rebalance_step(64):
        pass
    ev = cache.obs_snapshot().events
    kinds = {e["kind"] for e in ev}
    assert "rebalance" in kinds and "resize_done" in kinds
    reb = [e for e in ev if e["kind"] == "rebalance"]
    assert {(e["a"], e["b"]) for e in reb} == \
        {(caps[0], caps[0] + 8), (caps[1], caps[1] - 8)}


def test_tuner_emits_rounds_gauges_and_retune_events():
    from repro.core.prodcache import ProdClock2QPlus
    from repro.tuning import OnlineTuner

    cache = ProdClock2QPlus(64, max_small_frac=0.9, min_small_frac=0.05)
    sink = ObsSink(src="tuner")
    tuner = OnlineTuner(cache, retune_every=512, window_fracs=(0.1, 1.0),
                        min_gain=-1.0, confirm_rounds=1, obs=sink)
    trace = zipf_trace(n=1100, universe=512)
    for k in trace.tolist():
        cache.access(k)
        tuner.observe(int(k))
    snap = sink.snapshot()
    rounds = snap.counters["tuner_rounds_total"]
    assert rounds == 2
    est_keys = [k for k in snap.gauges
                if k.startswith("tuner_est_miss_ratio")]
    assert len(est_keys) >= 2  # one gauge per candidate config
    assert all(0.0 <= snap.gauges[k] <= 1.0 for k in est_keys)
    assert "tuner_live_est_miss_ratio" in snap.gauges
    # min_gain=-1 forces retunes: counter and EV_RETUNE event agree
    retunes = snap.counters["tuner_retunes_total"]
    ev = [e for e in snap.events if e["kind"] == EVENT_NAMES[EV_RETUNE]]
    assert retunes == len(ev) >= 1
    assert all(0 <= e["a"] <= 1000 and 0 <= e["b"] <= 1000 for e in ev)


def test_replay_store_snapshot_rows():
    from repro.shardcache import ShardedClock2QPlus
    from repro.shardcache.replay import replay_store

    sink = ObsSink(src="replay")
    cache = ShardedClock2QPlus(64, n_shards=2)
    trace = zipf_trace(n=3000)
    rep = replay_store(cache, trace, chunk_size=1000, obs=sink)
    snap = sink.snapshot()
    rows = [e for e in snap.events if e["kind"] == "snapshot"]
    assert [e["a"] for e in rows] == [1000, 2000, 3000]
    assert rows[-1]["b"] == rep.hits
    assert snap.gauges["replay_accesses"] == 3000.0
    assert snap.gauges["replay_miss_ratio"] == pytest.approx(
        rep.miss_ratio)


# -- obsreport CLI -------------------------------------------------------------

def test_obsreport_renders_snapshot_and_delta(tmp_path, capsys):
    import obsreport

    sink = one_sink("a", 0, 5, 3)
    p0 = tmp_path / "s0.json"
    p0.write_text(sink.snapshot(ts=1.0).to_json())
    sink.registry.families["hits_total"].labels().value += 2
    sink.emit(EV_EVICT, shard=0, a=77)
    p1 = tmp_path / "s1.json"
    p1.write_text(sink.snapshot(ts=2.0).to_json())

    assert obsreport.main([str(p0)]) == 0
    out = capsys.readouterr().out
    assert "hits_total" in out and "lat_seconds" in out and "evict" in out

    assert obsreport.main([str(p0), str(p1), "--events", "5"]) == 0
    out = capsys.readouterr().out
    assert "(delta)" in out and "a=77" in out
    assert " 2" in out  # the counter delta

    assert obsreport.main([str(p1), "--prom"]) == 0
    out = capsys.readouterr().out
    assert "# TYPE hits_total counter" in out
