"""Chunked state-carry replay == single-shot replay, bit for bit.

Each replay engine grew a streaming path (trace arrives as fixed-size
chunks, state threaded across chunk boundaries); these tests pin every
one of them to its single-shot twin.  Chunk sizes are chosen odd and
smaller than the Clock2Q+ correlation window, so chunk boundaries land
mid-window and mid-sequential-run — the cases where a state-carry bug
would actually show.
"""

import numpy as np
import pytest

from repro.core import jax_engine as je
from repro.core import traces
from repro.core.prodcache import ProdClock2QPlus
from repro.shardcache import ShardedClock2QPlus
from repro.shardcache.replay import replay_store, replay_threaded
from repro.traceio.store import iter_chunks
from repro.tuning.profiler import estimate_sweep, estimate_sweep_stream
from repro.tuning.sweep import SweepConfig, relabel

CAP = 120  # small_frac 0.1 -> S=12, window=6: chunks of 7 straddle windows


def _trace(n=12_000, scenario="w03-seqheavy", seed=21):
    tr = traces.make_trace(scenario, n=n, seed=seed)[:n]
    return relabel(tr)


@pytest.mark.parametrize("chunk_size", [7, 1001, 12_000, 50_000])
def test_jax_engine_chunked_matches_single_shot(chunk_size):
    tr, uni = _trace()
    h_ref, mr_ref = je.replay_np("clock2q+", tr, CAP, universe=uni)
    h, n, st = je.replay_chunked("clock2q+", iter_chunks(tr, chunk_size),
                                 CAP, uni)
    assert (h, n) == (h_ref, len(tr))
    # the carried final state must equal the single-shot final state too
    st_ref, _ = je.replay("clock2q+", je.init_state("clock2q+", CAP, uni),
                          np.asarray(tr, np.int32))
    for k in st_ref:
        assert np.array_equal(np.asarray(st_ref[k]), np.asarray(st[k])), k


def test_jax_engine_state_resumes_across_calls():
    """Passing the returned state back in continues the same stream."""
    tr, uni = _trace(n=6_000)
    h_ref, _ = je.replay_np("clock2q+", tr, CAP, universe=uni)
    h1, n1, st = je.replay_chunked("clock2q+", iter_chunks(tr[:2_500], 997),
                                   CAP, uni)
    h2, n2, st = je.replay_chunked("clock2q+", iter_chunks(tr[2_500:], 997),
                                   CAP, uni, state=st)
    assert h1 + h2 == h_ref and n1 + n2 == len(tr)


def test_sharded_replay_chunked_matches_single_shot():
    """Single-threaded chunked streaming is bit-identical to single-shot
    (per-shard order is preserved across any batch/chunk boundaries)."""
    tr, _ = _trace(n=10_000)
    ref_cache = ShardedClock2QPlus(CAP, n_shards=4)
    ref = replay_threaded(ref_cache, tr, n_threads=1)
    cache = ShardedClock2QPlus(CAP, n_shards=4)
    rep = replay_store(cache, tr, n_threads=1, batch_size=256,
                       chunk_size=1003)
    assert rep.hits == ref.hits and rep.n_requests == ref.n_requests
    assert rep.miss_ratio == ref.miss_ratio


def test_sharded_replay_chunked_threaded_fidelity():
    """Multi-threaded streaming inherits replay_threaded's relaxed
    cross-batch ordering (workers race on per-shard order), so it is NOT
    bit-exact vs serial — but every request is still replayed exactly
    once and the miss ratio stays within the harness's fidelity band."""
    tr, _ = _trace(n=10_000)
    ref_cache = ShardedClock2QPlus(CAP, n_shards=4)
    ref = replay_threaded(ref_cache, tr, n_threads=1)
    cache = ShardedClock2QPlus(CAP, n_shards=4)
    rep = replay_store(cache, tr, n_threads=4, batch_size=256,
                       chunk_size=1003)
    assert rep.n_requests == ref.n_requests
    assert abs(rep.miss_ratio - ref.miss_ratio) < 0.01


@pytest.mark.parametrize("chunk_size", [13, 1777, 40_000])
def test_sampled_profiler_stream_matches_whole(chunk_size):
    tr, _ = _trace(n=20_000, scenario="w01-skewed")
    configs = [SweepConfig(64), SweepConfig(256, window_frac=0.3)]
    whole = estimate_sweep(tr, configs, rate_shift=3)
    streamed = estimate_sweep_stream(iter_chunks(tr, chunk_size), configs,
                                     rate_shift=3)
    assert np.array_equal(whole, streamed, equal_nan=True)


@pytest.mark.parametrize("chunk_size", [7, 911])
def test_prodcache_replay_chunked_matches_single_shot(chunk_size):
    tr, _ = _trace(n=8_000)
    ref = ProdClock2QPlus(CAP)
    h_ref = ref.replay(tr)
    prod = ProdClock2QPlus(CAP)
    h = prod.replay(iter_chunks(tr, chunk_size))
    assert h == h_ref == prod.hits
    assert prod.misses == ref.misses
    assert np.array_equal(prod.key, ref.key)  # identical final layout


def test_chunk_boundary_mid_correlation_window_exactness():
    """Adversarial boundary placement: chunk size 1 (every request its own
    chunk) through a ghost-thrash stream — maximal boundary density on
    the ghost/promote paths."""
    tr, uni = _trace(n=600, scenario="ghost-thrash", seed=3)
    h_ref, _ = je.replay_np("clock2q+", tr, 40, universe=uni)
    h, n, _ = je.replay_chunked("clock2q+", iter_chunks(tr, 1), 40, uni)
    assert (h, n) == (h_ref, len(tr))
