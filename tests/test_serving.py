"""Serving-runtime tests: paged generation equivalence, prefix sharing,
eviction under HBM pressure, and live pool resize."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models.model import build
from repro.serving.engine import Request, ServingEngine

pytestmark = pytest.mark.slow  # JAX-compile-heavy (see pytest.ini)


@pytest.fixture(scope="module")
def small_model():
    cfg = reduced(get_config("granite-3-8b"))
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return api, params


def _ref_generate(api, params, prompt, n):
    logits, cache = api.prefill(
        params, {"tokens": jnp.asarray(prompt, jnp.int32)[None]},
        max_len=len(prompt) + n + 1)
    out = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(n - 1):
        logits, cache = api.decode(
            params, jnp.asarray([[out[-1]]], jnp.int32), cache)
        out.append(int(jnp.argmax(logits[0, 0])))
    return out


def test_paged_generation_matches_dense(small_model):
    api, params = small_model
    rng = np.random.default_rng(0)
    prefix = list(rng.integers(0, api.cfg.vocab, 24))
    prompts = [prefix + list(rng.integers(0, api.cfg.vocab,
                                          int(rng.integers(3, 10))))
               for _ in range(4)]
    eng = ServingEngine(api, params, block_size=8, hbm_blocks=24,
                        max_batch=2)
    outs = {c.req_id: c.tokens
            for c in eng.run([Request(i, p, max_new=6)
                              for i, p in enumerate(prompts)])}
    for i, p in enumerate(prompts):
        assert outs[i] == _ref_generate(api, params, p, 6), f"req {i}"


def test_prefix_sharing_hits(small_model):
    api, params = small_model
    rng = np.random.default_rng(1)
    prefix = list(rng.integers(0, api.cfg.vocab, 32))  # 4 full blocks
    eng = ServingEngine(api, params, block_size=8, hbm_blocks=32,
                        max_batch=4)
    reqs = [Request(i, prefix + [int(x)], max_new=2)
            for i, x in enumerate(rng.integers(0, api.cfg.vocab, 5))]
    eng.run(reqs)
    stats, _ = eng.stats
    # 4 shared prefix blocks x 4 follow-up requests = >= 16 hits
    assert stats.hits >= 16


def test_eviction_under_pressure_swaps_to_host(small_model):
    api, params = small_model
    rng = np.random.default_rng(2)
    eng = ServingEngine(api, params, block_size=8, hbm_blocks=10,
                        max_batch=1)
    reqs = [Request(i, list(rng.integers(0, api.cfg.vocab, 24)), max_new=2)
            for i in range(6)]
    outs = eng.run(reqs)
    stats, flows = eng.stats
    assert len(outs) == 6
    assert stats.swap_out > 0          # dirty blocks were flushed/evicted
    assert flows["small_to_ghost"] + flows["evict_main"] \
        + flows["small_bypass"] > 0
    # the merged stack snapshot carries engine + pool + policy telemetry
    snap = eng.obs_snapshot()
    assert snap.counters["serve_requests_total"] == 6
    assert snap.counters['pool_swaps_total{dir="out"}'] == stats.swap_out
    assert snap.counters['pool_lookups_total{result="hit"}'] == stats.hits
    assert snap.hists["serve_request_latency_seconds"]["count"] == 6
    assert snap.hists["serve_decode_step_seconds"]["count"] > 0
    assert snap.gauges['serve_queue_depth{stage="active"}'] == 0.0
    assert sum(v for k, v in snap.counters.items()
               if k.startswith("cache_flow_total")) \
        == sum(flows.values())
    assert {e["kind"] for e in snap.events} >= {"evict", "window_enter"}
    from repro.obs import to_prometheus
    assert "serve_request_latency_seconds_bucket" in to_prometheus(snap)


def test_oversized_prompt_rejected_not_dropped(small_model):
    # a prompt + decode tail needing more blocks than the pool can pin
    # used to wedge the run loop (pinned-beyond-capacity spin); it is
    # now an explicit rejected Completion, and the feasible requests in
    # the same batch are served normally
    api, params = small_model
    rng = np.random.default_rng(4)
    eng = ServingEngine(api, params, block_size=8, hbm_blocks=8,
                        max_batch=2)
    big = Request(0, list(rng.integers(0, api.cfg.vocab, 200)), max_new=4)
    ok = Request(1, list(rng.integers(0, api.cfg.vocab, 16)), max_new=3)
    for run in (eng.run, eng.run_sync):
        outs = {c.req_id: c for c in run([big, ok])}
        assert outs[0].status == "rejected" and outs[0].tokens == []
        assert outs[1].status == "completed"
        assert outs[1].tokens == _ref_generate(api, params, ok.prompt, 3)


def test_live_pool_resize(small_model):
    api, params = small_model
    rng = np.random.default_rng(3)
    eng = ServingEngine(api, params, block_size=8, hbm_blocks=16,
                        max_batch=2)
    eng.pool.policy.max_capacity  # preallocated
    r1 = [Request(i, list(rng.integers(0, api.cfg.vocab, 20)), max_new=2)
          for i in range(3)]
    eng.run(r1)
    eng.pool.resize(8)                 # shrink the HBM budget live
    r2 = [Request(10 + i, list(rng.integers(0, api.cfg.vocab, 20)),
                  max_new=2) for i in range(3)]
    outs = eng.run(r2)
    assert len(outs) == 3
    assert len(eng.pool.policy) <= eng.pool.policy.small_cap \
        + eng.pool.policy.main_cap
