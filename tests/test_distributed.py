"""Multi-device distribution tests (subprocess with 8 virtual devices):
sharded train-step lowering via the rule engine, and elastic checkpoint
restore onto a different mesh."""

import subprocess
import sys
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config, reduced
from repro.launch.specs import make_batch
from repro.models.config import ShapeCell
from repro.models.model import build
from repro.sharding import rules
from repro.training import optim, step as step_lib
from repro.checkpoint.ckpt import CheckpointManager

assert len(jax.devices()) == 8
cfg = reduced(get_config("olmo-1b"))
api = build(cfg)
oc = optim.AdamWConfig(lr=1e-3, warmup_steps=1)
rc = step_lib.RunConfig(adamw=oc)

def run_on_mesh(shape, state_host=None):
    mesh = jax.make_mesh(shape, ("data", "model"))
    log = rules.RuleLog()
    with mesh:
        params_shape = jax.eval_shape(api.init, jax.random.PRNGKey(0))
        pspecs = rules.param_specs(cfg, mesh, params_shape, log)
        ospecs = rules.opt_state_specs(cfg, mesh, params_shape, pspecs, log)
        sspec = step_lib.TrainState(params=pspecs,
            opt=optim.OptState(mu=ospecs, nu=ospecs, step=P()))
        sh = jax.tree.map(lambda s: NamedSharding(mesh, s), sspec,
                          is_leaf=lambda x: isinstance(x, P))
        if state_host is None:
            state = step_lib.init_train_state(api, jax.random.PRNGKey(0), oc)
            state = jax.device_put(state, sh)
        else:
            state = jax.device_put(state_host, sh)
        batch = make_batch(cfg, ShapeCell("t", 32, 8, "train"), seed=5)
        bspecs = rules.batch_specs(cfg, mesh,
            {k: (v.shape, v.dtype) for k, v in batch.items()}, log)
        bsh = {k: NamedSharding(mesh, s) for k, s in bspecs.items()}
        batch = {k: jax.device_put(v, bsh[k]) for k, v in batch.items()}
        step = jax.jit(step_lib.make_train_step(api, rc),
                       in_shardings=(sh, bsh), out_shardings=(sh, None),
                       donate_argnums=(0,))
        state, m = step(state, batch)
        return jax.tree.map(lambda x: np.asarray(x), state), float(m["loss"])

# 1) train one step on a (4, 2) mesh, checkpoint
state42, loss42 = run_on_mesh((4, 2))
mgr = CheckpointManager("/tmp/repro_elastic_ckpt_test")
mgr.save(1, state42, blocking=True)

# 2) ELASTIC restore onto a (2, 4) mesh and take the same next step
like = jax.eval_shape(lambda: state42)
restored = mgr.restore(1, like)
state24, loss24 = run_on_mesh((2, 4), state_host=restored)

# 3) single-device reference for the same step sequence
state11, loss11 = run_on_mesh((1, 1))
print("LOSS42", loss42, "LOSS24", loss24, "LOSS11", loss11)
assert abs(loss42 - loss11) < 1e-3, (loss42, loss11)
# the post-restore step on the new mesh continues from the same state:
state11b, loss11b = run_on_mesh((1, 1), state_host=restored)
assert abs(loss24 - loss11b) < 1e-3, (loss24, loss11b)
print("ELASTIC_OK")
"""


def test_multidevice_sharded_step_and_elastic_restore():
    # JAX_PLATFORMS=cpu: backend probing can hang in the stripped env on
    # sandboxed hosts (see test_hlo_cost.py)
    r = subprocess.run([sys.executable, "-c", SCRIPT],
                       env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
                            "HOME": "/root", "JAX_PLATFORMS": "cpu"},
                       capture_output=True, text=True, timeout=540)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "ELASTIC_OK" in r.stdout
