"""Cross-engine differential conformance suite.

The codebase carries these Clock2Q+ implementations:

  1. the pure-Python reference zoo (``repro.core.policies.clock2qplus``)
  2. the vectorized JAX engine (``repro.core.jax_engine``)
  3. the batched sweep engine's capacity-masked lane (the shared
     ``repro.core.engine.clock2qplus.step`` — the serial JAX replay and
     the sweep now call the SAME function, so 2 and 3 differ only in
     the driver path: degenerate mask vs padded vmap lane)
  4. the Pallas ``cache_sim`` TPU kernel (interpret mode on CPU)
  5. the production array implementation (``ProdClock2QPlus``)

Earlier tests spot-checked them pairwise; this suite locks them together
hit-for-hit, parametrized over the whole scenario registry at three
capacities.  All engines replay the SAME dense-relabeled stream
(replacement is label-invariant), padded to a fixed power-of-two
universe so the jitted engines compile once per capacity and are reused
across every scenario.
"""

import numpy as np
import pytest

from repro.core import jax_engine as je
from repro.core import make_policy, traces
from repro.core.prodcache import ProdClock2QPlus
from repro.tuning.sweep import SweepConfig, lane_hits, relabel

N = 2500          # requests per scenario (sliced after generation)
UNIVERSE = 4096   # shared dense-id space: one jit compile per capacity
CAPS = (20, 80, 320)

SCENARIOS = traces.scenario_names()


def _dense_trace(scenario: str) -> np.ndarray:
    tr = traces.make_trace(scenario, n=N, seed=13)[:N]
    dense, n_unique = relabel(tr)
    assert n_unique <= UNIVERSE, (scenario, n_unique)
    return dense


def _python_hits(trace, cap) -> np.ndarray:
    pol = make_policy("clock2q+", cap)
    return np.asarray([pol.access(int(k)) for k in trace], dtype=bool)


def _prod_hits(trace, cap) -> np.ndarray:
    prod = ProdClock2QPlus(cap)
    return np.asarray([prod.access(int(k)).hit for k in trace], dtype=bool)


def _jax_hits(trace, cap) -> np.ndarray:
    import jax.numpy as jnp
    st = je.init_state("clock2q+", cap, UNIVERSE)
    _, hits = je.replay("clock2q+", st, jnp.asarray(trace, jnp.int32))
    return np.asarray(hits).astype(bool)


def _mismatch(a: np.ndarray, b: np.ndarray) -> str:
    if a.shape != b.shape:
        return f"shape {a.shape} vs {b.shape}"
    bad = np.nonzero(a != b)[0]
    return f"{bad.size} mismatches, first at request {bad[:5]}"


@pytest.mark.conformance
@pytest.mark.parametrize("scenario", SCENARIOS)
def test_engines_agree_hit_for_hit(scenario):
    """python zoo == jax_engine == sweep lane == ProdClock2QPlus, per
    request, at three capacities."""
    trace = _dense_trace(scenario)
    for cap in CAPS:
        ref = _python_hits(trace, cap)
        for engine, fn in (
                ("jax_engine", _jax_hits),
                ("sweep_lane", lambda t, c: lane_hits(
                    t, SweepConfig(c), universe=UNIVERSE)),
                ("prodcache", _prod_hits)):
            got = fn(trace, cap)
            assert np.array_equal(ref, got), \
                f"{scenario} cap={cap} {engine}: {_mismatch(ref, got)}"


@pytest.mark.conformance
@pytest.mark.slow
@pytest.mark.parametrize("scenario", SCENARIOS)
def test_pallas_kernel_agrees_hit_for_hit(scenario):
    """The Pallas cache_sim kernel (interpret mode) vs the python
    reference, per request, at three capacities (compile-heavy: one
    pallas trace per capacity — marked slow)."""
    from repro.kernels.cache_sim.ops import simulate_lanes

    trace = _dense_trace(scenario)
    for cap in CAPS:
        ref = _python_hits(trace, cap)
        _, hits = simulate_lanes(trace[None, :], cap, interpret=True)
        got = np.asarray(hits)[0].astype(bool)
        assert np.array_equal(ref, got), \
            f"{scenario} cap={cap} pallas: {_mismatch(ref, got)}"
