"""Chaos harness for repro.faults: deterministic injection, hardened IO,
crash-consistent snapshot/restore, and shard failover.

The three acceptance pillars from the issue:
  * a seeded FaultPlan replays bit-identically (same seed, same ops,
    same faults — and a whole faulted pool run is replay-deterministic);
  * snapshot -> restore resumes a trace replay hit-for-hit;
  * shard loss + ghost-journal rewarm lands within 1pp of the uninjured
    run's miss ratio on three SUITE traces.
"""

import dataclasses
import pathlib
import struct
import sys

import numpy as np
import pytest

from repro.core.prodcache import ProdClock2QPlus
from repro.core.traces import SUITE
from repro.faults import (
    IO_DELAY, IO_ERROR, PARTIAL_WRITE, SHARD_LOSS, OP_SWAP_IN,
    OP_SWAP_OUT, CircuitBreaker, FaultPlan, FaultSpec, GhostJournal,
    HostIO, NullPlan, RetryPolicy, SnapshotManager, failover,
    load_state_dict, pack, policy_from_snapshot, read_snapshot,
    state_dict, unpack, write_snapshot,
)
from repro.obs import INCIDENT_KINDS, NullSink, ObsSink
from repro.shardcache import ShardedClock2QPlus

GOLDEN = pathlib.Path(__file__).parent / "golden" / "c2qp_snapshot_v1.bin"
GOLDEN_V2 = pathlib.Path(__file__).parent / "golden" / "c2qp_snapshot_v2.bin"
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "tools"))


# =============================================================================
# FaultPlan: seeded determinism
# =============================================================================

def test_plan_same_seed_same_schedule():
    specs = [FaultSpec(IO_ERROR, prob=0.2), FaultSpec(IO_DELAY, prob=0.05,
                                                      ticks=9)]
    a = FaultPlan(42, specs).schedule("swap_in", 2000)
    b = FaultPlan(42, specs).schedule("swap_in", 2000)
    assert a == b  # bit-identical decisions, frozen dataclass equality
    fired = [f for f in a if f is not None]
    assert 0 < len(fired) < 2000  # probabilistic, not all-or-nothing
    assert {f.kind for f in fired} <= {IO_ERROR, IO_DELAY}


def test_plan_different_seeds_differ():
    specs = [FaultSpec(IO_ERROR, prob=0.2)]
    a = FaultPlan(1, specs).schedule("swap_in", 1000)
    b = FaultPlan(2, specs).schedule("swap_in", 1000)
    assert [f is None for f in a] != [f is None for f in b]


def test_plan_scheduled_at_and_op_filter():
    plan = FaultPlan(0, [
        FaultSpec(IO_ERROR, ops=(OP_SWAP_OUT,), at=(3, 7)),
    ])
    outs = [plan.next_op("swap_out") for _ in range(10)]
    assert [i for i, f in enumerate(outs) if f is not None] == [3, 7]
    # swap_in ops never match an OP_SWAP_OUT spec
    plan2 = FaultPlan(0, [FaultSpec(IO_ERROR, ops=(OP_SWAP_OUT,), at=(3,))])
    assert all(plan2.next_op("swap_in") is None for _ in range(10))
    assert plan.injected == 2 and plan.op_seq == 10


def test_plan_first_matching_spec_wins():
    plan = FaultPlan(0, [FaultSpec(IO_DELAY, at=(5,), ticks=4),
                         FaultSpec(IO_ERROR, at=(5,))])
    f = plan.check("swap_in", 5)
    assert f.kind == IO_DELAY and f.ticks == 4 and f.spec_index == 0


def test_nullplan_never_fires_but_counts_ops():
    plan = NullPlan()
    assert not plan.enabled
    assert all(plan.next_op("swap_in") is None for _ in range(100))
    assert plan.op_seq == 100 and plan.injected == 0


def test_spec_validation():
    with pytest.raises(ValueError):
        FaultSpec(99)
    with pytest.raises(ValueError):
        FaultSpec(IO_ERROR, prob=1.5)


# =============================================================================
# HostIO: retry / backoff / deadline / breaker
# =============================================================================

def test_hostio_retries_then_succeeds():
    # fault exactly the first attempt; the retry (a fresh op slot) is clean
    io = HostIO(plan=FaultPlan(0, [FaultSpec(IO_ERROR, at=(0,))]),
                obs=NullSink())
    ran = []
    res = io.run("swap_in", key=7, fn=lambda: ran.append(1))
    assert res.ok and res.attempts == 2 and ran == [1]
    assert res.ticks == 1 and io.clock.now == 1  # backoff(0) == 1


def test_hostio_gives_up_after_max_retries():
    io = HostIO(plan=FaultPlan(0, [FaultSpec(IO_ERROR, prob=1.0)]),
                retry=RetryPolicy(max_retries=3), obs=ObsSink(src="t"))
    ran = []
    res = io.run("swap_out", key=7, fn=lambda: ran.append(1))
    assert not res.ok and not ran
    assert res.attempts == 4  # initial + 3 retries
    assert res.ticks == 1 + 2 + 4  # exponential backoffs actually waited
    snap = io.obs.snapshot()
    kinds = [e["kind"] for e in snap.events]
    assert kinds.count("io_retry") == 3 and kinds.count("io_error") == 1


def test_hostio_delay_spike_blows_deadline():
    # a single 1000-tick spike exceeds deadline_ticks -> op abandoned
    io = HostIO(plan=FaultPlan(0, [FaultSpec(IO_DELAY, at=(0,),
                                             ticks=1000)]),
                retry=RetryPolicy(max_retries=5, deadline_ticks=100),
                obs=NullSink())
    res = io.run("swap_in", key=1)
    assert not res.ok and res.ticks >= 1000


def test_hostio_small_delay_is_transparent():
    io = HostIO(plan=FaultPlan(0, [FaultSpec(IO_DELAY, at=(0,), ticks=5)]),
                obs=NullSink())
    res = io.run("swap_in", key=1)
    assert res.ok and res.attempts == 1 and res.ticks == 5


def test_hostio_partial_write_flags_corrupt():
    io = HostIO(plan=FaultPlan(0, [FaultSpec(PARTIAL_WRITE, at=(0,))]),
                obs=NullSink())
    ran = []
    res = io.run("swap_out", key=1, fn=lambda: ran.append(1))
    assert res.ok and res.corrupt and ran == [1]


def test_breaker_opens_shed_and_probes_back():
    sink = ObsSink(src="t")
    io = HostIO(plan=FaultPlan(0, [FaultSpec(IO_ERROR, prob=1.0)]),
                retry=RetryPolicy(max_retries=0),
                breaker=CircuitBreaker(threshold=4, probe_after=8, obs=sink),
                obs=sink)
    outs = [io.run("swap_in", k) for k in range(20)]
    assert io.degraded and io.breaker.trips >= 1
    assert any(r.shed for r in outs)  # ops skipped while open
    # the fault source clears; the next half-open probe closes the breaker
    io.plan = NullPlan()
    outs2 = [io.run("swap_in", k) for k in range(20)]
    assert not io.degraded and any(r.ok for r in outs2)
    flips = [e["a"] for e in sink.snapshot().events
             if e["kind"] == "degraded"]
    assert 1 in flips and 0 in flips  # entered AND recovered


# =============================================================================
# Pool integration: determinism, degraded read-through, incident trail
# =============================================================================

def _mk_pool(faults=None, n_shards=0, **kw):
    from repro.configs import get_config, reduced
    from repro.kvcache.pool import BlockPool
    cfg = reduced(get_config("granite-3-8b"))
    return BlockPool(cfg, 32, 8, n_shards=n_shards, faults=faults, **kw)


def _drive(pool, n=2500, keyspace=120, seed=0):
    import jax.numpy as jnp
    cfg = pool.cfg
    zeros = jnp.zeros((cfg.n_layers, pool.bs, cfg.n_kv_heads, cfg.hd))
    rng = np.random.default_rng(seed)
    served = 0
    for k in rng.integers(0, keyspace, n):
        slot, needs_fill = pool.lookup(int(k), pin=False)
        assert 0 <= slot < pool.policy.n_slots  # always keeps answering
        if needs_fill:
            pool.write_block(slot, zeros, zeros, key=int(k))
        else:
            served += 1
    return served


def test_pool_replay_deterministic_under_faults():
    mk = lambda: FaultPlan(11, [FaultSpec(IO_ERROR, prob=0.3),
                                FaultSpec(PARTIAL_WRITE, prob=0.1),
                                FaultSpec(IO_DELAY, prob=0.1, ticks=3)])
    a, b = _mk_pool(mk()), _mk_pool(mk())
    sa, sb = _drive(a), _drive(b)
    assert sa == sb
    assert dataclasses.asdict(a.stats) == dataclasses.asdict(b.stats)
    assert sorted(a.host) == sorted(b.host)
    assert a._corrupt == b._corrupt
    assert a._io.plan.injected == b._io.plan.injected > 0
    assert a._io.clock.now == b._io.clock.now


def test_pool_nullplan_matches_uninstrumented():
    plain, instr = _mk_pool(), _mk_pool(NullPlan())
    sp, si = _drive(plain), _drive(instr)
    assert sp == si
    assert dataclasses.asdict(plain.stats) == dataclasses.asdict(instr.stats)


def test_pool_degraded_read_through_and_incident_timeline():
    plan = FaultPlan(3, [FaultSpec(IO_ERROR, prob=1.0)])
    pool = _mk_pool(plan, io_retry=RetryPolicy(max_retries=0))
    _drive(pool, n=1500)
    assert pool.degraded  # breaker open under sustained failure...
    assert pool.stats.swap_in == 0  # ...no host copy ever swapped in
    # ...yet every lookup above returned a servable slot (read-through)
    pool._io.plan = NullPlan()  # the failure clears
    _drive(pool, n=1500, seed=1)
    assert not pool.degraded and pool.stats.swap_in > 0
    kinds = {e["kind"] for e in pool.obs_snapshot().events}
    # the full incident trail is typed events obsreport can filter on
    assert {"fault_inject", "io_error", "degraded"} <= kinds
    assert {"fault_inject", "io_error", "degraded"} <= INCIDENT_KINDS


def test_obsreport_renders_incident_timeline(tmp_path, capsys):
    import obsreport

    # SHARD_LOSS first: specs match in declaration order, and the
    # blanket IO_ERROR would otherwise win op 30 too
    plan = FaultPlan(3, [FaultSpec(SHARD_LOSS, at=(30,), shard=0),
                         FaultSpec(IO_ERROR, prob=1.0)])
    pool = _mk_pool(plan, n_shards=4, journal_every=64,
                    io_retry=RetryPolicy(max_retries=1))
    _drive(pool, n=1500)
    p = tmp_path / "snap.json"
    p.write_text(pool.obs_snapshot().to_json())
    assert obsreport.main([str(p), "--incidents"]) == 0
    out = capsys.readouterr().out
    assert "incident timeline" in out
    assert "injected io_error" in out
    assert "ENTERED read-through" in out
    assert "LOST" in out and "rewarmed" in out
    # non-incident event kinds (hits/evicts/...) are filtered out
    assert "small_to_main" not in out


def test_pool_torn_write_read_repair():
    # every swap-out is torn; reads must detect, drop, and refill
    plan = FaultPlan(5, [FaultSpec(PARTIAL_WRITE, ops=(OP_SWAP_OUT,),
                                   prob=1.0)])
    pool = _mk_pool(plan)
    _drive(pool, n=2500)
    snap = pool.obs_snapshot()
    torn = sum(v for k, v in snap.counters.items()
               if "pool_torn_writes_total" in k)
    dropped = sum(v for k, v in snap.counters.items()
                  if "pool_corrupt_dropped_total" in k)
    assert torn > 0 and dropped > 0
    # a quarantined key is never served from host: its corrupt copy is
    # gone after the read-repair path ran
    assert pool._corrupt.isdisjoint(set())  # type sanity
    for k in pool._corrupt:
        assert k in pool.host  # still quarantined = not yet re-read


def test_pool_auto_failover_on_shard_loss_fault():
    plan = FaultPlan(7, [FaultSpec(SHARD_LOSS, at=(50,), shard=2)])
    pool = _mk_pool(plan, n_shards=4, journal_every=64)
    _drive(pool, n=2500)
    kinds = [e["kind"] for e in pool.obs_snapshot().events]
    assert "shard_lost" in kinds and "shard_rewarm" in kinds
    assert len(pool.policy.shards[2]) > 0  # rebuilt, not left empty


# =============================================================================
# Snapshot / restore: crash consistency
# =============================================================================

def _warm_policy(track_io=False, **kw):
    pol = ProdClock2QPlus(48, max_capacity=64, track_io=track_io,
                          obs=NullSink(), **kw)
    rng = np.random.default_rng(4)
    for k in rng.integers(0, 160, 4000):
        r = pol.access(int(k), dirty=bool(k % 7 == 0))
        if track_io and not r.hit:
            pol.io_done(int(k))
    return pol


def test_snapshot_pack_roundtrip_bitexact():
    pol = _warm_policy()
    d = state_dict(pol)
    buf = pack(d)
    assert pack(unpack(buf)) == buf  # stable fixpoint
    pol2 = policy_from_snapshot(unpack(buf))
    assert pack(state_dict(pol2)) == buf  # restore is lossless


def test_snapshot_restore_resumes_hit_for_hit_prod():
    trace = np.random.default_rng(9).integers(0, 160, 6000)
    first, second = trace[:3000], trace[3000:]
    pol = ProdClock2QPlus(48, max_capacity=64, obs=NullSink())
    for k in first:
        pol.access(int(k))
    d = unpack(pack(state_dict(pol)))  # through the byte format
    ref = [pol.access(int(k)).hit for k in second]
    pol2 = policy_from_snapshot(d)
    got = [pol2.access(int(k)).hit for k in second]
    assert got == ref


def test_snapshot_restore_resumes_hit_for_hit_sharded():
    trace = np.random.default_rng(10).integers(0, 2000, 12000)
    first, second = trace[:6000], trace[6000:]
    mk = lambda: ShardedClock2QPlus(256, n_shards=4, max_capacity=512,
                                    obs=NullSink())
    svc = mk()
    svc.access_many(first)
    d = unpack(pack(state_dict(svc)))
    ref = svc.access_many(second)
    svc2 = mk()
    load_state_dict(svc2, d)
    got = svc2.access_many(second)
    assert np.array_equal(ref, got)


def test_snapshot_survives_mid_resize():
    pol = _warm_policy()
    pol.begin_resize(32)  # leave the migration half-done
    d = unpack(pack(state_dict(pol)))
    pol2 = policy_from_snapshot(d)
    assert pol2.rehash_pending() == pol.rehash_pending()
    trace = np.random.default_rng(12).integers(0, 160, 2000)
    ref = [pol.access(int(k)).hit for k in trace]
    got = [pol2.access(int(k)).hit for k in trace]
    assert got == ref


def test_snapshot_rejects_corruption_and_newer_version():
    pol = _warm_policy()
    buf = bytearray(pack(state_dict(pol)))
    flipped = bytearray(buf)
    flipped[len(flipped) // 2] ^= 0xFF
    with pytest.raises(IOError):
        unpack(bytes(flipped))
    import hashlib
    newer = bytearray(buf)
    struct.pack_into("<I", newer, 8, 99)  # version field...
    newer[-20:] = hashlib.sha1(bytes(newer[:-20])).digest()  # ...re-signed
    with pytest.raises(ValueError):
        unpack(bytes(newer))
    with pytest.raises(ValueError):
        unpack(b"NOTASNAP" + bytes(buf[8:]))


def test_write_snapshot_atomic_file(tmp_path):
    pol = _warm_policy()
    path = tmp_path / "engine.c2qsnap"
    buf = write_snapshot(str(path), pol)
    assert path.read_bytes() == buf
    assert not list(tmp_path.glob("*.tmp.*"))  # no torn temp left behind
    d = read_snapshot(str(path))
    assert pack(d) == buf


def test_snapshot_manager_retention_and_restore(tmp_path):
    pol = _warm_policy()
    mgr = SnapshotManager(str(tmp_path / "snaps"), keep=2)
    rng = np.random.default_rng(13)
    for step in (10, 20, 30):
        for k in rng.integers(0, 160, 500):
            pol.access(int(k))
        mgr.save(pol, step)
    assert mgr.steps() == [20, 30]  # keep=2 retention
    assert mgr.latest_step() == 30
    second = rng.integers(0, 160, 2000)
    ref = [pol.access(int(k)).hit for k in second]
    pol2 = policy_from_snapshot(mgr.load(30))
    got = [pol2.access(int(k)).hit for k in second]
    assert got == ref
    # restore() into a live cache emits the typed restore event
    sink = ObsSink(src="t")
    pol3 = ProdClock2QPlus(48, max_capacity=64, obs=sink)
    assert mgr.restore(pol3) == 30
    assert any(e["kind"] == "restore" and e["a"] == 30
               for e in sink.snapshot().events)


# =============================================================================
# Golden bytes: the on-disk format is pinned (mirrors the oracleGeneral
# record pin in test_traceio.py)
# =============================================================================

def _golden_policy():
    """A fixed, platform-independent engine state (no RNG)."""
    pol = ProdClock2QPlus(24, max_capacity=32, track_io=False,
                          obs=NullSink())
    for i in range(300):
        pol.access((i * 7) % 40, dirty=(i % 11 == 0))
    pol.access(1, pin=True)
    return pol


def test_snapshot_golden_bytes():
    buf = pack(state_dict(_golden_policy()))
    golden = GOLDEN.read_bytes()
    # header layout, field by field (the documented v1 format)
    assert golden[:8] == b"C2QSNAP1"
    version, n_arrays = struct.unpack_from("<II", golden, 8)
    assert version == 1 and n_arrays == 13  # 12 layout arrays + free list
    (meta_len,) = struct.unpack_from("<Q", golden, 16)
    meta = golden[24:24 + meta_len]
    assert meta.startswith(b"{") and b'"version":1' in meta
    import hashlib
    assert golden[-20:] == hashlib.sha1(golden[:-20]).digest()
    # and the full byte string is pinned: any layout/encoding change must
    # bump VERSION and regenerate the golden (see docs/operations.md)
    assert buf == golden
    # the pinned bytes restore to a working engine
    pol = policy_from_snapshot(unpack(golden))
    assert len(pol) > 0 and pol.access(7).hit in (True, False)


def test_snapshot_v2_golden_bytes():
    """v2 = journal base: same encoding, meta additionally carries the
    journal epoch + last folded LSN.  Pinned byte-for-byte, alongside
    (not instead of) the v1 golden — plain captures must keep writing
    v1 so old readers stay compatible."""
    buf = pack(state_dict(_golden_policy(), journal_meta=(3, 1234)))
    golden = GOLDEN_V2.read_bytes()
    assert golden[:8] == b"C2QSNAP1"
    version, n_arrays = struct.unpack_from("<II", golden, 8)
    assert version == 2 and n_arrays == 13
    (meta_len,) = struct.unpack_from("<Q", golden, 16)
    meta = golden[24:24 + meta_len]
    assert b'"version":2' in meta
    assert b'"journal_epoch":3' in meta and b'"journal_lsn":1234' in meta
    assert buf == golden
    # v2 reads back with the journal position intact, and restores
    d = unpack(golden)
    assert d["meta"]["journal_epoch"] == 3
    assert d["meta"]["journal_lsn"] == 1234
    pol = policy_from_snapshot(d)
    assert pack(state_dict(pol)) == pack(state_dict(_golden_policy()))


def test_write_snapshot_fsyncs_parent_directory(tmp_path, monkeypatch):
    """Durability: the rename that publishes a snapshot is only durable
    once the parent directory is fsynced — assert write_snapshot fsyncs
    a directory fd, not just the file."""
    import os
    synced_dirs = []
    real_fsync = os.fsync
    real_fstat = os.fstat

    def spy_fsync(fd):
        import stat
        if stat.S_ISDIR(real_fstat(fd).st_mode):
            synced_dirs.append(fd)
        return real_fsync(fd)

    monkeypatch.setattr(os, "fsync", spy_fsync)
    write_snapshot(str(tmp_path / "s.c2qsnap"), _warm_policy())
    assert synced_dirs, "parent directory was not fsynced after replace"


# =============================================================================
# Shard loss + ghost-journal rewarm: miss-ratio parity on SUITE traces
# =============================================================================

def _suite_trace(name, n):
    spec = next(s for s in SUITE if s.name == name)
    return dataclasses.replace(spec, n=n).data()


def _run_sharded(trace, lose_at=None, chunk=2048):
    svc = ShardedClock2QPlus(2048, n_shards=4, max_capacity=4096,
                             obs=NullSink())
    journal = GhostJournal()
    hits = 0
    done_loss = False
    for lo in range(0, len(trace), chunk):
        batch = trace[lo:lo + chunk]
        hits += int(svc.access_many(batch).sum())
        journal.capture(svc)  # periodic metadata journal (stale <= chunk)
        if lose_at is not None and not done_loss and lo + chunk >= lose_at:
            failover(svc, 1, journal)
            done_loss = True
    return hits / len(trace)


@pytest.mark.parametrize("name", ["w01-skewed", "w02-balanced",
                                  "w03-seqheavy"])
def test_shard_loss_rewarm_miss_parity(name):
    trace = _suite_trace(name, 48_000)
    hr_base = _run_sharded(trace)
    hr_injured = _run_sharded(trace, lose_at=len(trace) // 2)
    # post-recovery miss ratio within 1pp of the uninjured run
    assert abs(hr_base - hr_injured) <= 0.01, \
        f"{name}: base {1 - hr_base:.4f} vs injured {1 - hr_injured:.4f}"


def test_lose_shard_resets_rebalance_mark():
    svc = ShardedClock2QPlus(256, n_shards=4, max_capacity=512,
                             obs=NullSink())
    rng = np.random.default_rng(21)
    svc.access_many(rng.integers(0, 4000, 8000))
    svc.rebalance()
    svc.lose_shard(1)
    assert svc._miss_mark[1] == 0 and len(svc.shards[1]) == 0
    # a rebalance right after the loss must not blow up on negative
    # weights, and the fresh shard keeps a capacity share
    caps = svc.rebalance()
    assert caps[1] >= 2 and sum(caps) == svc.capacity
    # stride (and therefore every global payload handle) is preserved
    assert svc.shards[1].max_small + svc.shards[1].max_main == svc.stride


# =============================================================================
# Serving under chaos (JAX-compile-heavy, slow tier)
# =============================================================================

@pytest.mark.slow
def test_serving_answers_correctly_under_io_faults():
    """Injected host-IO failure must never change tokens — only cost.
    A faulted swap-in degrades to read-through: the manager refills the
    block by prefill, so greedy outputs match the fault-free run."""
    import jax
    from repro.configs import get_config, reduced
    from repro.models.model import build
    from repro.serving.engine import Request, ServingEngine

    cfg = reduced(get_config("granite-3-8b"))
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(30)
    prompts = [list(rng.integers(0, api.cfg.vocab, 24)) for _ in range(6)]
    reqs = lambda: [Request(i, p, max_new=3) for i, p in enumerate(prompts)]
    ref_eng = ServingEngine(api, params, block_size=8, hbm_blocks=10,
                            max_batch=1)
    ref = {c.req_id: c.tokens for c in ref_eng.run(reqs())}
    assert ref_eng.pool.stats.swap_out > 0  # pressure: the swap path ran
    plan = FaultPlan(31, [FaultSpec(IO_ERROR, prob=0.5),
                          FaultSpec(PARTIAL_WRITE, prob=0.2)])
    eng = ServingEngine(api, params, block_size=8, hbm_blocks=10,
                        max_batch=1, faults=plan,
                        io_retry=RetryPolicy(max_retries=1))
    got = {c.req_id: c.tokens for c in eng.run(reqs())}
    assert got == ref
    assert plan.injected > 0  # chaos actually exercised the swap path


def test_failover_rewarm_restores_working_set():
    svc = ShardedClock2QPlus(256, n_shards=4, max_capacity=512,
                             obs=NullSink())
    rng = np.random.default_rng(22)
    svc.access_many(rng.integers(0, 600, 10_000))
    journal = GhostJournal(svc)
    resident_before = set(svc.shards[1].resident_keys())
    assert resident_before
    n_res, n_ghost = failover(svc, 1, journal)
    assert n_res == len(resident_before)
    resident_after = set(svc.shards[1].resident_keys())
    # every journaled resident was readmitted (capacity permitting the
    # coldest few may already have been cycled out by the rewarm itself)
    assert len(resident_after & resident_before) >= \
        int(0.8 * len(resident_before))
    assert len(svc.shards[1].ghost_keys()) > 0  # ghosts survived too
