"""Production array-implementation tests (paper §4): parity, pinning,
DOING-IO, dirty handling, live resize, and the Fig.-6 race protocol."""

import numpy as np

from repro.core import make_policy
from repro.core.prodcache import EMPTY, ProdClock2QPlus


def test_parity_with_reference():
    rng = np.random.default_rng(0)
    T = 5000
    tr = np.empty(T, np.int64)
    tr[0::2] = rng.integers(0, 400, T // 2)
    tr[1::2] = np.arange(T // 2) % 700
    prod = ProdClock2QPlus(60)
    ref = make_policy("clock2q+", 60, dirty_mode="simplified")
    for k in tr:
        assert prod.access(int(k)).hit == ref.access(int(k))


def test_no_allocation_after_init():
    prod = ProdClock2QPlus(50)
    before = (prod.key.ctypes.data, prod.buckets.ctypes.data,
              prod.gkey.ctypes.data)
    rng = np.random.default_rng(1)
    for k in rng.integers(0, 500, 5000):
        prod.access(int(k))
    after = (prod.key.ctypes.data, prod.buckets.ctypes.data,
             prod.gkey.ctypes.data)
    assert before == after  # arrays never reallocated


def test_pinned_blocks_never_evicted():
    prod = ProdClock2QPlus(20)
    prod.access(999, pin=True)
    rng = np.random.default_rng(2)
    for k in rng.integers(0, 200, 3000):
        prod.access(int(k))
    assert prod.contains(999)
    prod.unpin(999)
    for k in rng.integers(200, 400, 3000):
        prod.access(int(k))
    assert not prod.contains(999)


def test_doing_io_waits_counted():
    prod = ProdClock2QPlus(20, track_io=True)
    prod.access(5)             # miss -> DOING-IO
    r = prod.access(5)         # second accessor waits on the entry
    assert r.hit and r.io_pending
    assert prod.io_waits == 1
    prod.io_done(5)
    assert not prod.access(5).io_pending


def test_dirty_blocks_survive_pressure_until_clean():
    prod = ProdClock2QPlus(20)
    prod.access(7, dirty=True)
    rng = np.random.default_rng(3)
    for k in rng.integers(10, 300, 2000):
        prod.access(int(k))
    assert prod.contains(7)      # skipped by both queues' eviction scans
    prod.clean(7)
    for k in rng.integers(300, 600, 2000):
        prod.access(int(k))
    assert not prod.contains(7)


def test_eviction_callback_reports_payload():
    prod = ProdClock2QPlus(4)
    seen = {}
    for k in range(20):
        r = prod.access(k)
        if r.evicted_key != EMPTY:
            seen[r.evicted_key] = r.evicted_block
    assert seen  # evictions happened and reported (key, payload) pairs


def test_resize_grow_then_shrink_under_load():
    prod = ProdClock2QPlus(24, max_capacity=120)
    rng = np.random.default_rng(4)
    for k in rng.integers(0, 400, 1500):
        prod.access(int(k))
    prod.begin_resize(100)
    for k in rng.integers(0, 400, 1500):
        prod.access(int(k))
        prod.resize_step(4)
    assert prod.capacity == 100
    prod.begin_resize(16)
    for k in rng.integers(0, 400, 1500):
        prod.access(int(k))
        prod.resize_step(4)
    for _ in range(500):
        if prod.resize_step(128):
            break
    assert len(prod) <= prod.small_cap + prod.main_cap


def _fill(prod, keys):
    for k in keys:
        if not prod.access(int(k)).hit and prod.track_io:
            prod.io_done(int(k))  # complete the fill so entries are evictable


def test_shrink_with_pinned_dirty_io_beyond_boundary():
    """Shrink with pinned / DOING-IO entries beyond the new boundary: the
    drain must report not-done while they are unevictable, leave them
    resident, then complete once released.  Dirty entries are flushed by
    the drain itself (§4.2.2) and must NOT block completion."""
    prod = ProdClock2QPlus(96, max_capacity=96, track_io=True)
    rng = np.random.default_rng(9)
    for _ in range(4):           # shuffled revisits promote via ghost hits,
        _fill(prod, rng.permutation(60))  # filling the Main Clock
    # mark one resident key per obstacle class, all provably beyond the
    # post-shrink boundary (capacity 8 -> small_cap 1, main_cap 7)
    deep_main = [k for k in range(60)
                 if prod._hash_lookup(k) >= prod.max_small + 7]
    assert len(deep_main) >= 2
    pinned, dirty = deep_main[:2]
    prod.access(pinned, pin=True)
    prod.io_done(pinned)
    prod.set_dirty(dirty)
    while prod.spos == 0:        # park the small cursor past slot 0 so the
        _fill(prod, [20_000 + prod.spos])  # next miss lands beyond it
    doing_io = 10_000
    r = prod.access(doing_io)    # fresh miss -> DOING-IO entry in small
    assert r.io_pending and prod._hash_lookup(doing_io) >= 1
    prod.begin_resize(8)
    for _ in range(200):
        if prod.resize_step(64):
            break
    # pinned + DOING-IO entries may sit beyond the boundary: not done
    assert not prod.resize_step(64)
    assert prod.contains(pinned) and prod.contains(doing_io)
    prod.unpin(pinned)
    prod.io_done(doing_io)
    for _ in range(200):
        if prod.resize_step(64):
            break
    assert prod.resize_step(64)
    assert len(prod) <= prod.small_cap + prod.main_cap
    # every entry now lives inside the logical boundary
    for eid in range(prod.small_cap, prod.max_small):
        assert int(prod.key[eid]) == EMPTY
    for s in range(prod.main_cap, prod.max_main):
        assert int(prod.key[prod.max_small + s]) == EMPTY


def test_resize_step_to_completion_interleaved_with_accesses():
    """Drive resize_step fully to completion while accesses interleave:
    lookups must stay exact (no false miss for a resident key) and the
    final state must be fully migrated (no stray hash entries left)."""
    prod = ProdClock2QPlus(20, max_capacity=120)
    rng = np.random.default_rng(11)
    _fill(prod, rng.integers(0, 300, 800))
    for new_cap in (110, 14):
        prod.begin_resize(new_cap)
        done = False
        for k in rng.integers(0, 300, 600):
            resident = prod.contains(int(k))
            assert prod.access(int(k)).hit == resident
            done = prod.resize_step(2)
        while not done:
            done = prod.resize_step(16)
        # fully migrated: old bucket array retired, lookups need no strays
        assert prod.old_buckets is None
        for k in range(300):
            if prod.contains(k):
                assert prod._hash_lookup(k) != EMPTY
    assert len(prod) <= prod.small_cap + prod.main_cap


def test_shrink_then_regrow_before_any_step_keeps_residents():
    """Retargeting a pending shrink back up (the shardcache rebalancing
    pattern) must not drain entries at the abandoned smaller capacity:
    only the hash migration may be forced before the new targets apply."""
    prod = ProdClock2QPlus(100, max_capacity=100)
    rng = np.random.default_rng(13)
    _fill(prod, rng.integers(0, 90, 2000))
    resident_before = len(prod)
    assert resident_before > 50
    prod.begin_resize(10)    # bucket array swaps; no resize_step yet
    prod.begin_resize(100)   # immediately retarget back up
    assert len(prod) == resident_before  # nobody was evicted
    while not prod.resize_step(256):
        pass
    assert len(prod) == resident_before
    for k in range(90):
        if prod.contains(k):
            assert prod.access(k).hit


def test_ghost_cursor_after_ghost_cap_shrink():
    """Shrinking moves ghost_cap below the current cursor: the cursor must
    wrap back into range and subsequent pushes stay within the new ring."""
    prod = ProdClock2QPlus(80, max_capacity=80)
    # burn through enough one-shot keys to fill the ghost ring and move gpos
    _fill(prod, range(1000, 1000 + 200))
    assert prod.gpos < prod.ghost_cap
    old_gpos = prod.gpos
    prod.begin_resize(10)   # ghost_cap shrinks below the old cursor
    assert prod.ghost_cap < 40
    assert prod.gpos < prod.ghost_cap  # cursor re-anchored, never OOB
    # entries stranded beyond the new ring are purged eagerly — the
    # cursor never revisits those slots, so they would otherwise stay
    # hash-reachable forever (unbounded-age ghost hits)
    assert (prod.gkey[prod.ghost_cap:] == EMPTY).all()
    while not prod.resize_step(64):
        pass
    # pushes after the shrink cycle strictly within the new ring
    seen_slots = set()
    for k in range(5000, 5000 + 3 * prod.ghost_cap):
        prod.access(k)
        assert prod.gpos < prod.ghost_cap
        seen_slots.add(prod.gpos)
    assert seen_slots <= set(range(prod.ghost_cap))
    # ghost hits on the shrunken ring still promote to main
    flows0 = prod.flows["ghost_to_main"]
    recent = [int(k) for k in prod.gkey[:prod.ghost_cap] if int(k) != EMPTY]
    assert recent, "shrunken ghost ring should hold recent demotions"
    prod.access(recent[-1])
    assert prod.flows["ghost_to_main"] == flows0 + 1


def test_fig6_race_stray_migration():
    """The paper's lookup/insert race (Fig. 6) maps to the resize
    protocol's stray handling: a key hashed in the OLD bucket array is
    invisible to plain lookup but MUST be found+migrated by the insertion
    path so the retry succeeds (§4.2.1)."""
    prod = ProdClock2QPlus(16, max_capacity=64)
    for _ in range(4):          # cycle keys into the Main Clock via ghost
        for k in range(6):
            prod.access(k)
    key = next(k for k in range(6) if prod.contains(k))
    prod.begin_resize(60)       # new bucket array; entries still in old
    # no resize_step yet: the key is a stray in the old location
    assert prod._hash_lookup(key) == EMPTY    # plain lookup: false negative
    r = prod.access(key)                       # insertion path migrates
    assert r.hit                               # ... and the retry succeeds
    assert prod._hash_lookup(key) != EMPTY     # now in the new location
