"""Production array-implementation tests (paper §4): parity, pinning,
DOING-IO, dirty handling, live resize, and the Fig.-6 race protocol."""

import numpy as np
import pytest

from repro.core import make_policy
from repro.core.prodcache import EMPTY, ProdClock2QPlus


def test_parity_with_reference():
    rng = np.random.default_rng(0)
    T = 5000
    tr = np.empty(T, np.int64)
    tr[0::2] = rng.integers(0, 400, T // 2)
    tr[1::2] = np.arange(T // 2) % 700
    prod = ProdClock2QPlus(60)
    ref = make_policy("clock2q+", 60, dirty_mode="simplified")
    for k in tr:
        assert prod.access(int(k)).hit == ref.access(int(k))


def test_no_allocation_after_init():
    prod = ProdClock2QPlus(50)
    before = (prod.key.ctypes.data, prod.buckets.ctypes.data,
              prod.gkey.ctypes.data)
    rng = np.random.default_rng(1)
    for k in rng.integers(0, 500, 5000):
        prod.access(int(k))
    after = (prod.key.ctypes.data, prod.buckets.ctypes.data,
             prod.gkey.ctypes.data)
    assert before == after  # arrays never reallocated


def test_pinned_blocks_never_evicted():
    prod = ProdClock2QPlus(20)
    prod.access(999, pin=True)
    rng = np.random.default_rng(2)
    for k in rng.integers(0, 200, 3000):
        prod.access(int(k))
    assert prod.contains(999)
    prod.unpin(999)
    for k in rng.integers(200, 400, 3000):
        prod.access(int(k))
    assert not prod.contains(999)


def test_doing_io_waits_counted():
    prod = ProdClock2QPlus(20, track_io=True)
    prod.access(5)             # miss -> DOING-IO
    r = prod.access(5)         # second accessor waits on the entry
    assert r.hit and r.io_pending
    assert prod.io_waits == 1
    prod.io_done(5)
    assert not prod.access(5).io_pending


def test_dirty_blocks_survive_pressure_until_clean():
    prod = ProdClock2QPlus(20)
    prod.access(7, dirty=True)
    rng = np.random.default_rng(3)
    for k in rng.integers(10, 300, 2000):
        prod.access(int(k))
    assert prod.contains(7)      # skipped by both queues' eviction scans
    prod.clean(7)
    for k in rng.integers(300, 600, 2000):
        prod.access(int(k))
    assert not prod.contains(7)


def test_eviction_callback_reports_payload():
    prod = ProdClock2QPlus(4)
    seen = {}
    for k in range(20):
        r = prod.access(k)
        if r.evicted_key != EMPTY:
            seen[r.evicted_key] = r.evicted_block
    assert seen  # evictions happened and reported (key, payload) pairs


def test_resize_grow_then_shrink_under_load():
    prod = ProdClock2QPlus(24, max_capacity=120)
    rng = np.random.default_rng(4)
    for k in rng.integers(0, 400, 1500):
        prod.access(int(k))
    prod.begin_resize(100)
    for k in rng.integers(0, 400, 1500):
        prod.access(int(k))
        prod.resize_step(4)
    assert prod.capacity == 100
    prod.begin_resize(16)
    for k in rng.integers(0, 400, 1500):
        prod.access(int(k))
        prod.resize_step(4)
    for _ in range(500):
        if prod.resize_step(128):
            break
    assert len(prod) <= prod.small_cap + prod.main_cap


def test_fig6_race_stray_migration():
    """The paper's lookup/insert race (Fig. 6) maps to the resize
    protocol's stray handling: a key hashed in the OLD bucket array is
    invisible to plain lookup but MUST be found+migrated by the insertion
    path so the retry succeeds (§4.2.1)."""
    prod = ProdClock2QPlus(16, max_capacity=64)
    for _ in range(4):          # cycle keys into the Main Clock via ghost
        for k in range(6):
            prod.access(k)
    key = next(k for k in range(6) if prod.contains(k))
    prod.begin_resize(60)       # new bucket array; entries still in old
    # no resize_step yet: the key is a stray in the old location
    assert prod._hash_lookup(key) == EMPTY    # plain lookup: false negative
    r = prod.access(key)                       # insertion path migrates
    assert r.hit                               # ... and the retry succeeds
    assert prod._hash_lookup(key) != EMPTY     # now in the new location
