"""Training-stack tests: loss descends, microbatch-accumulation
equivalence, checkpoint roundtrip/resume, data pipeline determinism."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import CheckpointManager
from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.launch.specs import make_batch
from repro.models.config import ShapeCell
from repro.models.model import build
from repro.training import optim, step as step_lib

pytestmark = pytest.mark.slow  # JAX-compile-heavy (see pytest.ini)


@pytest.fixture(scope="module")
def tiny():
    cfg = reduced(get_config("olmo-1b"))
    api = build(cfg)
    return api


def test_loss_decreases(tiny):
    api = tiny
    oc = optim.AdamWConfig(lr=3e-3, warmup_steps=1)
    rc = step_lib.RunConfig(adamw=oc)
    state = step_lib.init_train_state(api, jax.random.PRNGKey(0), oc)
    step = jax.jit(step_lib.make_train_step(api, rc))
    dc = DataConfig(vocab=api.cfg.vocab, seq_len=32, global_batch=4, seed=1)
    pipe = TokenPipeline(dc)
    losses = []
    for i in range(12):
        b = pipe.batch(i % 2)  # repeat 2 batches -> must overfit
        state, m = step(state, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses


def test_microbatch_accumulation_matches_full_batch(tiny):
    api = tiny
    oc = optim.AdamWConfig()
    state = step_lib.init_train_state(api, jax.random.PRNGKey(0), oc)
    batch = make_batch(api.cfg, ShapeCell("t", 32, 4, "train"), seed=5)
    s1 = step_lib.make_train_step(api, step_lib.RunConfig(adamw=oc))
    s4 = step_lib.make_train_step(
        api, step_lib.RunConfig(microbatches=4, adamw=oc))
    st1, m1 = jax.jit(s1)(state, batch)
    st4, m4 = jax.jit(s4)(state, batch)
    # same data -> same mean loss and same updated params (fp32 tolerance)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-4
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     st1.params, st4.params)
    assert max(jax.tree.leaves(d)) < 1e-4


def test_checkpoint_roundtrip_and_resume(tiny, tmp_path):
    api = tiny
    oc = optim.AdamWConfig(lr=1e-3, warmup_steps=1)
    rc = step_lib.RunConfig(adamw=oc)
    state = step_lib.init_train_state(api, jax.random.PRNGKey(0), oc)
    step = jax.jit(step_lib.make_train_step(api, rc))
    batch = make_batch(api.cfg, ShapeCell("t", 32, 4, "train"), seed=5)
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for i in range(3):
        state, _ = step(state, batch)
    mgr.save(3, state, blocking=True)
    state_a, _ = step(state, batch)
    # restart: restore and take the same step -> identical params
    like = jax.eval_shape(lambda: state)
    restored = mgr.restore(None, like)
    restored = jax.tree.map(jnp.asarray, restored)
    state_b, _ = step(restored, batch)
    diff = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))),
        state_a.params, state_b.params)
    assert max(jax.tree.leaves(diff)) == 0.0
    assert mgr.latest_step() == 3


def test_checkpoint_retention_and_verify(tiny, tmp_path):
    api = tiny
    oc = optim.AdamWConfig()
    state = step_lib.init_train_state(api, jax.random.PRNGKey(1), oc)
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3):
        mgr.save(s, state, blocking=True)
    assert mgr.all_steps() == [2, 3]
    like = jax.eval_shape(lambda: state)
    mgr.restore(2, like, verify=True)  # digest check passes


def test_pipeline_determinism_and_index_cache():
    dc = DataConfig(vocab=1000, seq_len=64, global_batch=8, seed=7)
    p1 = TokenPipeline(dc)
    p2 = TokenPipeline(dc)
    b1 = p1.batch(5)
    b2 = p2.batch(5)
    assert (b1["tokens"] == b2["tokens"]).all()
    assert (b1["labels"][:, :-1] == b1["tokens"][:, 1:]).all()
    # correlated references on index blocks -> cache absorbs them
    for s in range(30):
        p1.batch(s)
    assert p1.index_hit_ratio > 0.3


def test_pipeline_elastic_host_slices():
    dc = DataConfig(vocab=1000, seq_len=32, global_batch=8, seed=9)
    whole = TokenPipeline(dc).batch(3)["tokens"]
    h0 = TokenPipeline(dc, host_id=0, n_hosts=2).batch(3)["tokens"]
    h1 = TokenPipeline(dc, host_id=1, n_hosts=2).batch(3)["tokens"]
    assert (np.concatenate([h0, h1]) == whole).all()


def test_grad_compression_roundtrip():
    rng = jax.random.PRNGKey(0)
    g = jax.random.normal(rng, (256, 64)) * 0.01
    q, scale = optim.compress_int8(g, rng)
    back = optim.decompress_int8(q, scale)
    rel = float(jnp.linalg.norm(back - g) / jnp.linalg.norm(g))
    assert q.dtype == jnp.int8 and rel < 0.02
