"""Tuning-subsystem tests: exact parity of the batched grid sweep with
serial replays, sampled-MRC estimation error, the runtime ``retune``
setter on the live-resize protocol, and OnlineTuner behaviour/invariants
(standalone and sharded) including the convergence acceptance criterion."""

import numpy as np
import pytest

from repro.core import make_policy, traces
from repro.core.prodcache import EMPTY, ProdClock2QPlus, drive_resize
from repro.shardcache import ShardedClock2QPlus
from repro.tuning import (
    OnlineTuner, estimate_sweep, make_grid, sample_trace, serial_sweep_hits,
    sweep_grid, sweep_hits,
)

ACCEPT_SPECS = traces.SUITE[:3]  # >= 3 SUITE traces (acceptance criterion)


def _mixed_trace(seed, T=2500, U=300):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, U, T // 2)
    b = np.arange(T // 2) % (U + 50)
    out = np.empty(T, np.int64)
    out[0::2] = a
    out[1::2] = b
    return out


def _meta_prefix(spec, n=100_000):
    return traces.derive_metadata(spec.data())[:n]


# -- invariant checker (run after every tuning/resize step) ---------------------

def check_invariants(cache) -> None:
    """Structural invariants of the production cache(s): payload handles
    unique and disjoint from the free list, every resident key reachable
    through the hash, ghost keys reachable through the ghost hash, and
    (when no resize is pending) residency within the logical bounds and
    the window consistent with the live tuning."""
    shards = cache.shards if isinstance(cache, ShardedClock2QPlus) else [cache]
    for s in shards:
        live = s.block[s.key != EMPTY].tolist()
        assert len(set(live)) == len(live), "duplicate payload handles"
        assert set(s.free_blocks).isdisjoint(live)
        assert len(s.free_blocks) + len(live) == s.n_slots
        for eid in np.nonzero(s.key != EMPTY)[0].tolist():
            k = int(s.key[eid])
            assert s.contains(k), f"resident key {k} unreachable"
            assert s.slot_of(k) == int(s.block[eid])
        for g in np.nonzero(s.gkey != EMPTY)[0].tolist():
            if g < s.ghost_cap:
                assert s._ghost_lookup(int(s.gkey[g])) == g
        assert s.window == int(round(s._window_frac * s.small_cap))
        if not s.rehash_pending() and s.undrained_count() == 0:
            assert len(s) <= s.small_cap + s.main_cap
            assert s.spos < s.small_cap and s.hand < s.main_cap
            assert s.gpos < s.ghost_cap


# -- batched sweep engine --------------------------------------------------------

def test_batched_sweep_matches_serial_replays_exactly():
    """Acceptance: a full >=8x4 grid in ONE jitted call, every config's
    hit count equal to its serial jax_engine replay."""
    trace = _mixed_trace(0)
    grid = make_grid([8, 12, 16, 24, 32, 48, 64, 96], (0.1, 0.3, 0.5, 1.0))
    assert len(grid) == 32
    hb = sweep_hits(trace, grid)
    hs = serial_sweep_hits(trace, grid)
    assert (hb == hs).all(), np.nonzero(hb != hs)


def test_batched_sweep_frac_and_skiplimit_variants_exact():
    trace = _mixed_trace(1)
    grid = (make_grid([24, 60], (0.3, 1.0), small_fracs=(0.05, 0.25),
                      ghost_fracs=(0.25, 1.0))
            + make_grid([16, 40], skip_limit=1)
            + make_grid([16, 40], skip_limit=3))
    assert (sweep_hits(trace, grid) == serial_sweep_hits(trace, grid)).all()


def test_batched_sweep_matches_python_reference():
    """Transitively the sweep matches the pure-Python zoo; spot-check a
    few configurations directly (incl. non-default window/fractions)."""
    from repro.tuning.sweep import relabel
    trace = _mixed_trace(2)
    trl, _ = relabel(trace)
    grid = make_grid([30, 80], (0.1, 1.0), small_fracs=(0.2,))
    hb = sweep_hits(trace, grid)
    for cfg, h in zip(grid, hb):
        pol = make_policy("clock2q+", cfg.capacity,
                          small_frac=cfg.small_frac,
                          ghost_frac=cfg.ghost_frac,
                          window_frac=cfg.window_frac)
        assert sum(pol.access(int(k)) for k in trl) == h, cfg


# -- sampled MRC profiler --------------------------------------------------------

@pytest.mark.parametrize("spec", traces.SUITE[:2], ids=lambda s: s.name)
def test_sampled_mrc_close_to_exact(spec):
    """Spatial sampling at ~1/16 keeps the MRC estimate within a few pp
    of the exact curve (>=2 SUITE traces)."""
    tr = _meta_prefix(spec, 80_000)
    fp = traces.footprint(tr)
    caps = [max(8, int(fp * f)) for f in (0.01, 0.02, 0.05, 0.1)]
    grid = make_grid(caps)
    exact = sweep_grid(tr, grid)
    est = estimate_sweep(tr, grid, rate_shift=4)
    assert np.isfinite(est).all()
    assert np.abs(est - exact).max() < 0.04, (est, exact)
    # the estimate preserves the MRC's monotone-in-capacity shape
    assert (np.diff(est) <= 0.02).all()


def test_sample_trace_is_spatial():
    """Hash sampling keeps or drops a KEY wholesale (every occurrence)."""
    tr = _mixed_trace(3)
    sampled = sample_trace(tr, 3)
    kept = set(sampled.tolist())
    assert 0 < len(sampled) < len(tr)
    for k in kept:
        assert int((tr == k).sum()) == int((sampled == k).sum())


# -- runtime retune setter -------------------------------------------------------

def test_retune_runtime_setter_preserves_invariants():
    p = ProdClock2QPlus(100, max_small_frac=0.3, max_ghost_frac=1.0)
    rng = np.random.default_rng(4)
    for k in rng.integers(0, 500, 3000):
        p.access(int(k))
    for kw in (dict(window_frac=1.0), dict(small_frac=0.25),
               dict(small_frac=0.05, ghost_frac=1.0, window_frac=0.1),
               dict(small_frac=0.1, ghost_frac=0.5, window_frac=0.5)):
        p.retune(**kw)
        drive_resize(p)
        check_invariants(p)
        for k in rng.integers(0, 500, 2000):
            r = p.access(int(k))
            assert 0 <= r.block < p.n_slots
        check_invariants(p)
    assert p.tuning == dict(small_frac=0.1, ghost_frac=0.5, window_frac=0.5)


def test_retune_mid_resize_and_interleaved_accesses():
    """Retuning composes with the live-resize protocol: lookups stay
    exact while boundaries move under traffic."""
    p = ProdClock2QPlus(60, max_capacity=120, max_small_frac=0.4)
    rng = np.random.default_rng(5)
    for k in rng.integers(0, 400, 2000):
        p.access(int(k))
    p.begin_resize(100)          # a capacity resize in flight...
    p.retune(small_frac=0.35)    # ...retargeted by a tuning change
    done = False
    for k in rng.integers(0, 400, 1500):
        resident = p.contains(int(k))
        assert p.access(int(k)).hit == resident
        done = p.resize_step(4)
    while not done:
        done = p.resize_step(64)
    check_invariants(p)
    assert p.small_cap == round(0.35 * 100)


def test_retune_rejects_bad_fractions():
    p = ProdClock2QPlus(50)
    with pytest.raises(ValueError):
        p.retune(small_frac=0.0)
    with pytest.raises(ValueError):
        p.retune(small_frac=1.5)
    with pytest.raises(ValueError):
        p.retune(ghost_frac=-0.1)
    with pytest.raises(ValueError):
        p.retune(window_frac=-1.0)
    # a rejected call must not half-apply: the valid leading argument of
    # an invalid call stays un-assigned
    before = p.tuning
    with pytest.raises(ValueError):
        p.retune(small_frac=0.2, ghost_frac=-1.0)
    assert p.tuning == before


def test_sharded_retune_applies_to_all_shards():
    sh = ShardedClock2QPlus(64, n_shards=4)
    rng = np.random.default_rng(6)
    for k in rng.integers(0, 400, 5000):
        sh.access(int(k))
    sh.retune(window_frac=1.0)
    assert sh.tuning["window_frac"] == 1.0
    for s in sh.shards:
        assert s._window_frac == 1.0
    check_invariants(sh)
    hits = sh.access_many(rng.integers(0, 400, 5000))
    assert hits.shape == (5000,)
    check_invariants(sh)


# -- OnlineTuner -----------------------------------------------------------------

def _burst_trace(n=45_000, seed=3):
    return traces.correlated_burst_trace(n, universe=1 << 15, alpha=0.9,
                                         seed=seed)


def test_tuner_applies_and_never_violates_invariants():
    """The tuner retargets a live cache under traffic; the production
    invariants must hold after every decision (applied or not)."""
    tr = _burst_trace(40_000)
    cap = max(10, int(0.02 * traces.footprint(tr)))
    cache = ProdClock2QPlus(cap, window_frac=0.0)
    tuner = OnlineTuner(cache, window_fracs=(0.0, 0.3, 1.0),
                        retune_every=15_000, rate_shift=4, min_gain=0.002)
    seen = 0
    for k in tr:
        cache.access(int(k))
        tuner.observe(int(k))
        if len(tuner.decisions) > seen:
            seen = len(tuner.decisions)
            check_invariants(cache)
    assert seen >= 3
    assert any(d.applied for d in tuner.decisions)
    assert cache.tuning["window_frac"] != 0.0  # moved off the bad start
    check_invariants(cache)


def test_tuner_under_sharding_preserves_invariants():
    tr = _burst_trace(20_000, seed=5)
    cap = max(32, int(0.02 * traces.footprint(tr)))
    sh = ShardedClock2QPlus(cap, n_shards=4, window_frac=0.0)
    tuner = OnlineTuner(sh, window_fracs=(0.0, 0.3, 1.0),
                        retune_every=8_000, rate_shift=4, min_gain=0.002)
    seen = 0
    for k in tr:
        sh.access(int(k))
        tuner.observe(int(k))
        if len(tuner.decisions) > seen:
            seen = len(tuner.decisions)
            check_invariants(sh)
    assert seen >= 2
    check_invariants(sh)
    # one tuning decision retargets every shard identically
    fracs = {s._window_frac for s in sh.shards}
    assert len(fracs) == 1


def test_candidate_grid_drops_unrealizable_fractions():
    """Fraction candidates the preallocation cannot realize are filtered
    (they would silently clamp — up-tuning past max_small, or
    down-tuning into a main larger than max_main, which would shrink the
    effective capacity); headroom knobs widen the search space."""
    plain = ProdClock2QPlus(100)
    t = OnlineTuner(plain, small_fracs=(0.05, 0.1, 0.3))
    sfs = {c.small_frac for c in t.candidate_grid()}
    assert sfs == {0.1}  # 0.3 exceeds max_small; 0.05 would clamp main
    roomy = ProdClock2QPlus(100, max_small_frac=0.3, min_small_frac=0.05)
    t = OnlineTuner(roomy, small_fracs=(0.05, 0.1, 0.3))
    assert {0.05, 0.1, 0.3} <= {c.small_frac for c in t.candidate_grid()}
    # a realizable down-tune keeps the full logical capacity
    roomy.retune(small_frac=0.05)
    drive_resize(roomy)
    assert roomy.small_cap + roomy.main_cap == roomy.capacity
    check_invariants(roomy)


def test_candidate_grid_carries_live_skip_limit():
    """Estimates must simulate the eviction policy the cache runs —
    including the convention mismatch: prod None = unlimited = sweep 0,
    and prod 0 forces after one skip, i.e. sweep 1."""
    p = ProdClock2QPlus(100, skip_limit=8)
    assert all(c.skip_limit == 8 for c in OnlineTuner(p).candidate_grid())
    assert all(c.skip_limit == 0
               for c in OnlineTuner(ProdClock2QPlus(100)).candidate_grid())
    zero = ProdClock2QPlus(100, skip_limit=0)
    assert all(c.skip_limit == 1 for c in OnlineTuner(zero).candidate_grid())


def test_tuner_observe_many_matches_observe():
    """Batched observation fills the same window and fires the same
    profiling rounds as per-access observation."""
    tr = _burst_trace(12_000, seed=9)
    cap = max(10, int(0.05 * traces.footprint(tr)))

    def mk():
        return OnlineTuner(ProdClock2QPlus(cap), window_fracs=(0.1, 1.0),
                           retune_every=10_000, rate_shift=3,
                           min_gain=10.0)  # never applies: pure profiling
    a, b = mk(), mk()
    for k in tr:
        a.observe(int(k))
    for lo in range(0, len(tr), 3_000):
        b.observe_many(tr[lo:lo + 3_000])
    assert a.n_observed == b.n_observed
    assert np.array_equal(a.recent(), b.recent())
    assert len(a.decisions) == len(b.decisions) >= 2
    for da, db in zip(a.decisions, b.decisions):
        assert da.chosen == db.chosen and da.rate_shift == db.rate_shift


def test_tuner_debounce_needs_consecutive_wins():
    """A single winning round must not retarget the cache."""
    tr = _burst_trace(20_000, seed=7)
    cap = max(10, int(0.02 * traces.footprint(tr)))
    cache = ProdClock2QPlus(cap, window_frac=0.0)
    tuner = OnlineTuner(cache, window_fracs=(0.0, 1.0), retune_every=6_000,
                        rate_shift=4, min_gain=0.002, confirm_rounds=10_000)
    for k in tr:
        cache.access(int(k))
        tuner.observe(int(k))
    assert tuner.decisions and not any(d.applied for d in tuner.decisions)
    assert cache.tuning["window_frac"] == 0.0


@pytest.mark.parametrize("spec", ACCEPT_SPECS, ids=lambda s: s.name)
def test_tuner_convergence_acceptance(spec):
    """Acceptance: from a deliberately bad correlation window, the tuner
    converges to a window whose full-trace miss ratio is within 1pp of
    the best offline fig13-style sweep value, on >=3 SUITE traces."""
    tr = _meta_prefix(spec, 100_000)
    cap = traces.suite_capacity(tr)
    wfs = (0.1, 0.3, 0.5, 1.0)
    offline = sweep_grid(tr, make_grid([cap], wfs))
    best = float(offline.min())
    cache = ProdClock2QPlus(cap, window_frac=8.0)  # deliberately bad
    tuner = OnlineTuner(cache, window_fracs=wfs, retune_every=25_000,
                        rate_shift=4, min_gain=0.001)
    for k in tr:
        cache.access(int(k))
        tuner.observe(int(k))
    check_invariants(cache)
    final_wf = cache.tuning["window_frac"]
    final = float(sweep_grid(tr, make_grid([cap], [final_wf]))[0])
    assert final - best < 0.01, (spec.name, final_wf, final, best)


# -- BlockPool / serving integration ---------------------------------------------

def test_blockpool_autotune_backend():
    from repro.configs import get_config, reduced
    from repro.kvcache.pool import BlockPool
    cfg = reduced(get_config("granite-3-8b"))
    pool = BlockPool(cfg, 32, 8, autotune=dict(
        window_fracs=(0.1, 0.5, 1.0), retune_every=600, rate_shift=2,
        min_gain=0.0, min_samples=64))
    assert pool.tuner is not None and pool.tuner.cache is pool.policy
    rng = np.random.default_rng(0)
    for k in rng.integers(0, 120, 2500):
        slot, needs_fill = pool.lookup(int(k), pin=False)
        assert 0 <= slot < pool.policy.n_slots
        if needs_fill:
            pool.policy.io_done(int(k))
    assert pool.tuner.decisions  # the tuner profiled the stream
    check_invariants(pool.policy)
    # sharded policy backend + autotune compose
    pool = BlockPool(cfg, 32, 8, n_shards=4, autotune=dict(
        retune_every=600, rate_shift=2, min_gain=0.0, min_samples=64))
    for k in rng.integers(0, 120, 1500):
        slot, needs_fill = pool.lookup(int(k), pin=False)
        if needs_fill:
            pool.policy.io_done(int(k))
    assert pool.tuner.decisions
    check_invariants(pool.policy)
