"""End-to-end system behaviour: the paper's headline claims on synthetic
workloads (directional reproduction), plus a mini train->checkpoint->
resume->serve pipeline across subsystems."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.core import stats, traces
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models.model import build
from repro.serving.engine import Request, ServingEngine
from repro.training import optim, step as step_lib
from repro.checkpoint.ckpt import CheckpointManager

pytestmark = pytest.mark.slow  # JAX-compile-heavy (see pytest.ini)


def test_clock2qplus_beats_s3fifo_on_metadata_traces():
    """Paper §5.3 headline (directional): on derived metadata traces at
    production cache sizes, Clock2Q+ achieves a lower mean miss ratio
    than S3-FIFO 2-bit, and both beat Clock."""
    wins = 0
    cells = 0
    tot = {"clock2q+": 0.0, "s3fifo": 0.0, "clock": 0.0}
    for spec in traces.SUITE[:4]:
        meta = spec.metadata()
        fp = traces.footprint(meta)
        for frac in (0.05, 0.1):
            cap = max(10, int(frac * fp))
            mrs = stats.miss_ratios(["clock2q+", "s3fifo", "clock"],
                                    meta, cap)
            for k, v in mrs.items():
                tot[k] += v
            wins += mrs["clock2q+"] <= mrs["s3fifo"]
            cells += 1
    assert tot["clock2q+"] < tot["s3fifo"] < tot["clock"]
    assert wins >= cells * 0.6


def test_correlated_burst_traces_separate_the_algorithms():
    """On explicitly correlated-reference workloads the window filter must
    give Clock2Q+ a clear edge over S3-FIFO (the paper's mechanism)."""
    tr = traces.correlated_burst_trace(60_000, universe=1 << 14,
                                       alpha=0.9, seed=11)
    fp = traces.footprint(tr)
    cap = max(16, int(0.05 * fp))
    mrs = stats.miss_ratios(["clock2q+", "s3fifo", "clock"], tr, cap)
    assert mrs["clock2q+"] < mrs["s3fifo"]


def test_full_stack_train_checkpoint_resume_serve(tmp_path):
    cfg = reduced(get_config("olmo-1b"))
    api = build(cfg)
    oc = optim.AdamWConfig(lr=1e-3, warmup_steps=2)
    rc = step_lib.RunConfig(adamw=oc)
    state = step_lib.init_train_state(api, jax.random.PRNGKey(0), oc)
    step = jax.jit(step_lib.make_train_step(api, rc))
    pipe = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=32,
                                    global_batch=4, seed=3))
    mgr = CheckpointManager(str(tmp_path))
    for i in range(4):
        b = pipe.batch(i)
        state, m = step(state, {k: jnp.asarray(v) for k, v in b.items()})
    mgr.save(4, state, blocking=True)
    like = jax.eval_shape(lambda: state)
    restored = jax.tree.map(jnp.asarray, mgr.restore(None, like))
    # serve with the trained params through the paged engine
    eng = ServingEngine(api, restored.params, block_size=8, hbm_blocks=16,
                        max_batch=2)
    outs = eng.run([Request(0, [1, 2, 3, 4, 5], max_new=4),
                    Request(1, [1, 2, 3, 9, 9], max_new=4)])
    assert len(outs) == 2
    assert all(len(c.tokens) == 4 for c in outs)
    assert all(0 <= t < cfg.vocab for c in outs for t in c.tokens)
