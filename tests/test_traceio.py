"""Trace I/O subsystem tests: format round-trips, the oracleGeneral
binary layout, TraceStore streaming, the convert CLI, and the
large-trace acceptance run (20M accesses on disk, replayed in bounded
memory, bit-identical to in-memory replay — marked slow)."""

import os
import struct
import tempfile

import numpy as np
import pytest

from repro.core import jax_engine as je
from repro.core import traces
from repro.traceio import (
    ORACLE_DTYPE, TraceStore, iter_chunks, load_trace, save_trace,
    sniff_format,
)
from repro.traceio.convert import main as convert_main

FORMATS = ["oracle", "csv", "npz", "npy"]
_EXT = {"oracle": "bin", "csv": "csv", "npz": "npz", "npy": "npy"}


def _roundtrip(keys, fmt, tmp_path):
    p = str(tmp_path / f"t.{_EXT[fmt]}")
    save_trace(p, keys, fmt)
    return load_trace(p, fmt)


@pytest.mark.parametrize("fmt", FORMATS)
def test_roundtrip_identity(fmt, tmp_path):
    keys = traces.make_trace("w02-balanced", 5_000, seed=9)
    back = _roundtrip(keys, fmt, tmp_path)
    assert back.dtype == np.int64
    assert np.array_equal(back, keys)


def test_oracle_record_layout(tmp_path):
    """Byte-level pin of the libCacheSim oracleGeneral layout: packed
    little-endian <IQIq records with a correct next_access_vtime."""
    assert ORACLE_DTYPE.itemsize == struct.calcsize("<IQIq") == 24
    p = str(tmp_path / "t.bin")
    save_trace(p, np.asarray([7, 9, 7], dtype=np.int64))
    raw = open(p, "rb").read()
    assert len(raw) == 72
    assert struct.unpack("<IQIq", raw[0:24]) == (0, 7, 1, 2)   # 7 recurs at 2
    assert struct.unpack("<IQIq", raw[24:48]) == (1, 9, 1, -1)  # never again
    assert struct.unpack("<IQIq", raw[48:72]) == (2, 7, 1, -1)


def test_sniff_format_and_errors(tmp_path):
    assert sniff_format("x.bin") == "oracle"
    assert sniff_format("x.csv") == "csv"
    assert sniff_format("x.csv", "npy") == "npy"  # explicit wins
    with pytest.raises(ValueError):
        sniff_format("x.dat")
    with pytest.raises(ValueError):
        sniff_format("x.bin", "nope")
    with pytest.raises(ValueError):
        save_trace(str(tmp_path / "neg.npy"),
                   np.asarray([-1, 2], dtype=np.int64))


def test_csv_headerless_and_single_column(tmp_path):
    p = str(tmp_path / "bare.csv")
    with open(p, "w") as f:
        f.write("5\n6\n5\n")
    assert load_trace(p).tolist() == [5, 6, 5]
    with open(p, "w") as f:
        f.write("0,42,1\n1,43,1\n")  # no header
    assert load_trace(p).tolist() == [42, 43]


def test_csv_blank_lines_do_not_truncate(tmp_path):
    """Leading blank lines (before or after the header) must not be
    mistaken for an empty file — loadtxt skips them."""
    p = str(tmp_path / "blank.csv")
    with open(p, "w") as f:
        f.write("\n1,2,3\n4,5,6\n")
    assert load_trace(p).tolist() == [2, 5]
    with open(p, "w") as f:
        f.write("time,obj_id,obj_size\n\n1,2,3\n")
    assert load_trace(p).tolist() == [2]
    with open(p, "w") as f:
        f.write("time,obj_id,obj_size\n\n\n")  # header + blanks only
    assert load_trace(p).size == 0


def test_store_chunks_reassemble_and_stats(tmp_path):
    keys = traces.make_trace("zipf", 30_000, seed=4)
    for fmt in ("oracle", "npy"):
        p = str(tmp_path / f"s.{_EXT[fmt]}")
        save_trace(p, keys, fmt)
        store = TraceStore(p)
        assert len(store) == keys.size
        assert store.max_key() == int(keys.max())
        parts = list(store.chunks(999))
        assert all(c.size <= 999 for c in parts)  # bounded materialization
        assert np.array_equal(np.concatenate(parts), keys)
    with pytest.raises(ValueError):
        TraceStore(str(tmp_path / "s.bin"), "csv")


def test_iter_chunks_sources():
    arr = np.arange(10, dtype=np.int64)
    assert np.array_equal(np.concatenate(list(iter_chunks(arr, 3))), arr)
    pre = [arr[:4], arr[4:]]
    assert np.array_equal(np.concatenate(list(iter_chunks(pre))), arr)
    with pytest.raises(TypeError):
        list(iter_chunks(42))


def test_convert_cli_roundtrip_and_scenario(tmp_path, capsys):
    src = str(tmp_path / "in.npz")
    dst = str(tmp_path / "out.bin")
    keys = traces.make_trace("cyclic-loop", 2_000, seed=2)
    save_trace(src, keys)
    assert convert_main([src, dst]) == 0
    assert np.array_equal(load_trace(dst), keys)
    out = str(tmp_path / "scen.npy")
    assert convert_main(["--scenario", "ghost-thrash", "--n", "1000",
                         "--seed", "5", out]) == 0
    assert np.array_equal(load_trace(out),
                          traces.make_trace("ghost-thrash", 1000, seed=5))
    assert convert_main(["--list-scenarios"]) == 0
    assert "ghost-thrash" in capsys.readouterr().out
    assert convert_main(["--info", dst]) == 0
    assert f"n={keys.size}" in capsys.readouterr().out


def test_convert_relabel_densifies_sparse_ids(tmp_path):
    """Raw production obj_ids are sparse/hashed 64-bit; --relabel maps
    them to [0, n_unique) so the dense-table engines can ingest them."""
    from repro.tuning.sweep import relabel

    sparse = np.asarray([1 << 40, 7, 1 << 40, (1 << 62) - 1, 7],
                        dtype=np.int64)
    src = str(tmp_path / "sparse.bin")
    dst = str(tmp_path / "dense.npy")
    save_trace(src, sparse)
    assert convert_main(["--relabel", src, dst]) == 0
    dense = load_trace(dst)
    expect, n_unique = relabel(sparse)
    assert np.array_equal(dense, expect) and int(dense.max()) == n_unique - 1
    # and the engine refuses the un-relabelled trace loudly
    with pytest.raises(ValueError, match="relabel"):
        je.replay_store("clock2q+", TraceStore(src), 16)
    with pytest.raises(ValueError, match="universe"):
        je.replay_chunked("clock2q+", iter_chunks(dense, 2), 16, universe=2)
    # hashed obj_ids >= 2**63 wrap negative through the uint64->int64
    # load: they must hit the loud guard, not wrap-index the tables
    wrapped = np.asarray([3, -(1 << 62), 5], dtype=np.int64)
    with pytest.raises(ValueError, match="relabel"):
        je.replay_chunked("clock2q+", iter_chunks(wrapped, 2), 16,
                          universe=64)


# -- property tests (hypothesis) ----------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # optional dev dependency, matching test_property.py
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    keys_strategy = st.lists(
        st.integers(min_value=0, max_value=(1 << 62) - 1),
        min_size=0, max_size=300)

    @settings(max_examples=20, deadline=None)
    @given(keys=keys_strategy, fmt=st.sampled_from(FORMATS))
    def test_write_read_roundtrip_property(keys, fmt):
        arr = np.asarray(keys, dtype=np.int64)
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, f"t.{_EXT[fmt]}")
            save_trace(p, arr, fmt)
            assert np.array_equal(load_trace(p, fmt), arr)

    @settings(max_examples=20, deadline=None)
    @given(keys=st.lists(st.integers(min_value=0, max_value=1 << 40),
                         min_size=1, max_size=500),
           chunk=st.integers(min_value=1, max_value=600),
           fmt=st.sampled_from(["oracle", "npy"]))
    def test_store_streaming_equals_whole_load_property(keys, chunk, fmt):
        arr = np.asarray(keys, dtype=np.int64)
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, f"t.{_EXT[fmt]}")
            save_trace(p, arr, fmt)
            store = TraceStore(p)
            streamed = np.concatenate(list(store.chunks(chunk)))
            assert np.array_equal(streamed, store.keys())
            assert np.array_equal(streamed, arr)
else:  # pragma: no cover
    @pytest.mark.skip(reason="optional dev dependency")
    def test_traceio_property_suite():
        pass


# -- the acceptance run: >=20M accesses, on disk, bounded memory ---------------

@pytest.mark.slow
def test_20m_stream_replay_bit_identical(tmp_path):
    """Replay a 20M-access on-disk trace through jax_engine via TraceStore
    chunks: miss ratio bit-identical to the in-memory path, with per-chunk
    materialization bounded by chunk_size (the in-memory path holds all
    20M keys; the streamed path holds 1M at a time)."""
    n = 20_000_000
    set_size = 1 << 15
    keys = traces.make_trace("ghost-thrash", n, seed=1, set_size=set_size)
    assert keys.size >= 20_000_000
    p = str(tmp_path / "big.npy")
    save_trace(p, keys)

    chunk = 1 << 20
    store = TraceStore(p)
    seen_max = 0

    def bounded_chunks():
        nonlocal seen_max
        for c in store.chunks(chunk):
            seen_max = max(seen_max, c.size)
            yield c

    h_stream, n_stream, _ = je.replay_chunked(
        "fifo", bounded_chunks(), 4096, set_size)
    assert n_stream == keys.size
    assert seen_max <= chunk  # bounded memory: one chunk at a time

    h_mem, mr_mem = je.replay_np("fifo", keys, 4096, universe=set_size)
    assert h_stream == h_mem
    assert 1.0 - h_stream / n_stream == mr_mem
