"""Per-kernel shape/dtype sweeps, assert_allclose against the ref.py
pure-jnp oracles (interpret=True executes the kernel bodies on CPU)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.cache_sim.ops import simulate_lanes
from repro.kernels.cache_sim.ref import cache_sim_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mamba_scan.ops import mamba_scan
from repro.kernels.mamba_scan.ref import mamba_scan_ref
from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref

RNG = np.random.default_rng(0)


def _randn(shape, dtype):
    return jnp.asarray(RNG.normal(0, 1, shape), dtype)


@pytest.mark.parametrize("B,S,H,Hkv,hd,causal", [
    (2, 128, 4, 2, 64, True),
    (1, 256, 2, 2, 128, False),
    (2, 96, 4, 1, 80, True),      # ragged blocks + padded head_dim + MQA
    (1, 64, 2, 2, 32, True),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, S, H, Hkv, hd, causal, dtype):
    q, k, v = (_randn((B, S, H, hd), dtype),
               _randn((B, S, Hkv, hd), dtype),
               _randn((B, S, Hkv, hd), dtype))
    o = flash_attention(q, k, v, causal=causal, block_q=64, block_kv=64,
                        interpret=True)
    kr = jnp.repeat(k, H // Hkv, axis=2)
    vr = jnp.repeat(v, H // Hkv, axis=2)
    qb = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kb = kr.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    vb = vr.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    ref = attention_ref(qb, kb, vb, causal=causal).reshape(
        B, H, S, hd).transpose(0, 2, 1, 3)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("B,H,Hkv,d,N,bs,nb", [
    (3, 4, 2, 64, 16, 8, 4),
    (2, 8, 8, 128, 32, 16, 3),
    (1, 4, 1, 32, 8, 4, 2),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention(B, H, Hkv, d, N, bs, nb, dtype):
    q = _randn((B, H, d), dtype)
    kp = _randn((N, bs, Hkv, d), dtype)
    vp = _randn((N, bs, Hkv, d), dtype)
    bt = jnp.asarray(RNG.choice(N, size=(B, nb)), jnp.int32)
    lens = jnp.asarray(RNG.integers(1, nb * bs + 1, size=(B,)), jnp.int32)
    o = paged_attention(q, kp, vp, bt, lens, interpret=True)
    ref = paged_attention_ref(q, kp, vp, bt, lens)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("B,S,din,N,db,ck", [
    (2, 64, 128, 16, 64, 32),
    (1, 100, 96, 8, 32, 32),     # ragged chunk tail
    (2, 32, 64, 4, 64, 16),
])
def test_mamba_scan(B, S, din, N, db, ck):
    u = _randn((B, S, din), jnp.float32)
    dt = jnp.abs(_randn((B, S, din), jnp.float32)) * 0.1
    Bc = _randn((B, S, N), jnp.float32)
    Cc = _randn((B, S, N), jnp.float32)
    Al = _randn((din, N), jnp.float32) * 0.5
    y = mamba_scan(u, dt, Bc, Cc, Al, d_block=db, chunk=ck, interpret=True)
    ref = mamba_scan_ref(u, dt, Bc, Cc, Al)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-4)


@pytest.mark.parametrize("cap,T,L,U", [(40, 600, 4, 200), (24, 400, 8, 80)])
def test_cache_sim_bit_exact(cap, T, L, U):
    traces = np.stack([
        np.concatenate([RNG.integers(0, U, T // 2),
                        np.arange(T // 2) % max(2, U // 2)])
        for _ in range(L)])
    RNG.shuffle(traces, axis=1)
    mr, hits = simulate_lanes(traces, cap, interpret=True)
    ref = cache_sim_ref(traces, cap)
    assert (np.asarray(hits) == ref.astype(np.int32)).all()
