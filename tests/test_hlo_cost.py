"""Regression tests for the loop-aware HLO cost model that all roofline
numbers depend on (EXPERIMENTS.md §Dry-run)."""

import subprocess
import sys
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch import hlo_cost

D, L, B = 512, 8, 64
W = jnp.zeros((L, D, D), jnp.bfloat16)
x = jnp.zeros((B, D), jnp.bfloat16)

def scanned(W, x):
    def body(x, w):
        return x @ w, None
    return jax.lax.scan(body, x, W)[0]

def unrolled(W, x):
    for i in range(L):
        x = x @ W[i]
    return x

exp = 2 * B * D * D * L
for fn in (scanned, unrolled):
    r = hlo_cost.analyze(jax.jit(fn).lower(W, x).compile().as_text())
    assert abs(r["flops"] - exp) / exp < 0.01, (fn.__name__, r["flops"], exp)

# sharded: per-device flops + collectives inside loops multiplied by trips
mesh = jax.make_mesh((2, 2), ("data", "model"))
def loss(W, x):
    def body(c, w):
        return jax.nn.relu(c @ w), None
    c, _ = jax.lax.scan(body, x, W)
    return jnp.sum(c.astype(jnp.float32))
j = jax.jit(loss,
            in_shardings=(NamedSharding(mesh, P(None, None, "model")),
                          NamedSharding(mesh, P("data", None))),
            out_shardings=NamedSharding(mesh, P()))
r = hlo_cost.analyze(j.lower(W, x).compile().as_text())
assert abs(r["flops"] - exp / 4) / (exp / 4) < 0.01, r["flops"]
ag = r["collectives"]["all-gather"]
assert ag["count"] == L, ag  # one all-gather per scan iteration, x L trips
print("HLO_COST_OK")
"""


def test_loop_aware_cost_model():
    # JAX_PLATFORMS=cpu: without it, backend probing in the stripped env
    # can hang for minutes on sandboxed hosts (observed: 300s timeout)
    r = subprocess.run([sys.executable, "-c", SCRIPT],
                       env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
                            "HOME": "/root", "JAX_PLATFORMS": "cpu"},
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "HLO_COST_OK" in r.stdout
