"""Cache-policy explorer — a mini libCacheSim over the synthetic suite.

Compare any registered policies on data / derived-metadata / object
traces at several cache sizes; optionally cross-check with the
vectorized JAX engine.

    PYTHONPATH=src python examples/cache_explorer.py \
        --policies clock,arc,s3fifo,clock2q+ --kind meta --fracs 0.01,0.1
"""

import argparse

import numpy as np

from repro.core import jax_engine as je
from repro.core import policy_names, stats, traces


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--policies", default="clock,arc,s3fifo,clock2q+")
    ap.add_argument("--kind", default="meta", choices=["meta", "data",
                                                       "object"])
    ap.add_argument("--fracs", default="0.01,0.05,0.1")
    ap.add_argument("--trace", default="w01-skewed",
                    choices=[s.name for s in traces.SUITE] + ["object"])
    ap.add_argument("--jax-check", action="store_true",
                    help="cross-check clock2q+ with the vectorized engine")
    args = ap.parse_args()

    pols = args.policies.split(",")
    unknown = set(pols) - set(policy_names())
    if unknown:
        raise SystemExit(f"unknown policies {unknown}; have {policy_names()}")

    if args.kind == "object":
        tr = traces.object_trace(300_000, seed=1)
    else:
        spec = next(s for s in traces.SUITE if s.name == args.trace)
        tr = spec.metadata() if args.kind == "meta" else spec.data()
    fp = traces.footprint(tr)
    print(f"trace={args.trace} kind={args.kind} requests={len(tr)} "
          f"footprint={fp}")
    header = "frac   cap     " + "  ".join(f"{p:>10s}" for p in pols)
    print(header)
    for frac in [float(f) for f in args.fracs.split(",")]:
        cap = max(8, int(frac * fp))
        mrs = stats.miss_ratios(pols, tr, cap)
        print(f"{frac:<6} {cap:<7} "
              + "  ".join(f"{mrs[p]:>10.4f}" for p in pols))
    if args.jax_check and "clock2q+" in pols:
        cap = max(8, int(0.05 * fp))
        h, mr = je.replay_np("clock2q+", np.asarray(tr), cap)
        ref = stats.simulate("clock2q+", tr, cap)
        print(f"jax-engine cross-check @5%: jax_mr={mr:.6f} "
              f"ref_mr={ref.miss_ratio:.6f} "
              f"{'MATCH' if abs(mr-ref.miss_ratio) < 1e-9 else 'DIFF'}")


if __name__ == "__main__":
    main()
