"""Quickstart: train a tiny LM end-to-end on CPU in ~a minute.

Shows the full substrate in one script: config -> model -> data pipeline
(with its Clock2Q+-managed shard-index cache) -> train steps -> checkpoint
-> restore.

    PYTHONPATH=src python examples/quickstart.py [--steps 20] [--smoke]

``--smoke`` shrinks it to the few-second version CI runs on every push
(3 steps, tiny batch) — same code path, just less of it.
"""

import argparse
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.ckpt import CheckpointManager
from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models.model import build
from repro.training import optim, step as step_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke mode: 3 steps, batch 2, temp ckpt dir")
    args = ap.parse_args()
    if args.smoke:
        args.steps = min(args.steps, 3)

    cfg = reduced(get_config(args.arch))
    print(f"arch={cfg.name} params={cfg.n_params():,} (reduced config)")
    api = build(cfg)
    oc = optim.AdamWConfig(lr=3e-3, warmup_steps=5)
    state = step_lib.init_train_state(api, jax.random.PRNGKey(0), oc)
    step = jax.jit(step_lib.make_train_step(
        api, step_lib.RunConfig(adamw=oc)))
    pipe = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=64,
                                    global_batch=2 if args.smoke else 8,
                                    seed=0))
    ckpt_dir = (tempfile.mkdtemp(prefix="repro_quickstart_") if args.smoke
                else "/tmp/repro_quickstart_ckpt")
    mgr = CheckpointManager(ckpt_dir)

    t0 = time.time()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(i).items()}
        state, m = step(state, batch)
        if i % 5 == 0 or i == args.steps - 1:
            print(f"step {i:3d} loss={float(m['loss']):.4f} "
                  f"gnorm={float(m['grad_norm']):.3f} "
                  f"index_cache_hit={pipe.index_hit_ratio:.2f} "
                  f"({time.time()-t0:.1f}s)")
    mgr.save(args.steps, state, blocking=True)
    print(f"checkpoint saved at step {mgr.latest_step()}")
    like = jax.eval_shape(lambda: state)
    mgr.restore(None, like, verify=True)
    print("restore+verify OK")


if __name__ == "__main__":
    main()
