"""End-to-end driver: train a ~100M-parameter LM with the full substrate
(data pipeline w/ Clock2Q+ index cache, AdamW, remat, checkpoint/resume).

On a TPU slice this config trains at full speed; on this CPU container a
step takes tens of seconds, so the default is a short demonstration run —
pass --steps 300 for the real "few hundred steps" run.

    PYTHONPATH=src python examples/train_100m.py --steps 8
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.ckpt import CheckpointManager
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models.config import ModelConfig
from repro.models.model import build
from repro.training import optim, step as step_lib

# ~124M parameters (GPT-2-small-class, SwiGLU/RMSNorm/RoPE)
CFG_100M = ModelConfig(
    name="lm-100m", family="dense", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=12, d_ff=2048, vocab=32_000,
    norm="rmsnorm", act="swiglu", dtype="float32", param_dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ckpt", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    api = build(CFG_100M)
    print(f"model: {CFG_100M.name}  params={CFG_100M.n_params():,}")
    oc = optim.AdamWConfig(lr=6e-4, warmup_steps=50)
    rc = step_lib.RunConfig(adamw=oc)
    step = jax.jit(step_lib.make_train_step(api, rc))
    pipe = TokenPipeline(DataConfig(vocab=CFG_100M.vocab, seq_len=args.seq,
                                    global_batch=args.batch, seed=0))
    mgr = CheckpointManager(args.ckpt)
    start = mgr.latest_step() or 0
    if start:
        like = jax.eval_shape(
            lambda r: step_lib.init_train_state(api, r, oc),
            jax.random.PRNGKey(0))
        state = jax.tree.map(jnp.asarray, mgr.restore(start, like))
        print(f"resumed at step {start}")
    else:
        state = step_lib.init_train_state(api, jax.random.PRNGKey(0), oc)

    t0 = time.time()
    for i in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(i).items()}
        state, m = step(state, batch)
        dt = time.time() - t0
        print(f"step {i:4d} loss={float(m['loss']):.4f} "
              f"gnorm={float(m['grad_norm']):.2f} "
              f"tok/s={(i - start + 1) * args.batch * args.seq / dt:,.0f}")
        if (i + 1) % 50 == 0:
            mgr.save(i + 1, state, blocking=False)
    mgr.save(args.steps, state, blocking=True)
    print(f"checkpoints: {mgr.all_steps()}")


if __name__ == "__main__":
    main()
