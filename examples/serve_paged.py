"""Serve a small model with batched requests through the Clock2Q+-paged
KV cache — the paper's technique as a serving substrate.

Demonstrates: prefix-cache sharing (correlated references at admission),
HBM pressure -> Clock2Q+ eviction to the host tier, dirty-block flushing,
and LIVE HBM-pool resizing mid-service (paper §4.2).

    PYTHONPATH=src python examples/serve_paged.py
"""

import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models.model import build
from repro.serving.engine import Request, ServingEngine


def main():
    cfg = reduced(get_config("granite-3-8b"))
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    system_prompt = list(rng.integers(0, cfg.vocab, 32))  # shared prefix
    reqs = [Request(i, system_prompt
                    + list(rng.integers(0, cfg.vocab,
                                        int(rng.integers(4, 12)))),
                    max_new=8) for i in range(8)]

    eng = ServingEngine(api, params, block_size=8, hbm_blocks=28,
                        max_batch=4)
    t0 = time.time()
    done = eng.run(reqs[:4])
    stats, flows = eng.stats
    print(f"phase 1: {len(done)} completions in {time.time()-t0:.1f}s")
    print(f"  pool: hits={stats.hits} misses={stats.misses} "
          f"hit_ratio={stats.hit_ratio:.2f} swap_out={stats.swap_out} "
          f"swap_in={stats.swap_in}")
    print(f"  clock2q+ flows: {flows}")

    print("live-shrinking the HBM pool 28 -> 14 blocks (paper §4.2) ...")
    eng.pool.resize(14)
    done2 = eng.run(reqs[4:])
    stats, flows = eng.stats
    print(f"phase 2 (half HBM): {len(done2)} completions")
    print(f"  pool: hits={stats.hits} misses={stats.misses} "
          f"hit_ratio={stats.hit_ratio:.2f} swap_out={stats.swap_out} "
          f"swap_in={stats.swap_in}")
    sample = done[0]
    print(f"sample completion req{sample.req_id}: {sample.tokens}")


if __name__ == "__main__":
    main()
