#!/usr/bin/env python
"""Import-layering checker: the dependency direction of the repo.

Enforced order (lower number = lower layer; module-level imports may
only point DOWNWARD or sideways within a package, never upward):

    0  repro.core.engine     the capacity-masked policy core
    0  repro.obs             metrics/event telemetry (SEALED: imports
                             no other layered package, not even layer 0
                             — every layer instruments, none leaks back)
    1  repro.core            reference zoo, prod cache, replay drivers
    2  repro.traceio         trace storage/streaming
    2  repro.faults          fault injection & recovery, incl. the
                             write-ahead delta journal (faults.journal)
                             and hot-standby replication
                             (faults.replica) (RESTRICTED: besides the
                             usual downward rule it may import ONLY
                             repro.core and repro.obs — never traceio
                             sideways, and replica duck-types the
                             sharded service rather than importing
                             repro.shardcache — so chaos machinery
                             stays a leaf the layers above thread in)
    3  repro.tuning, repro.shardcache, repro.kvcache, repro.kernels
    4  repro.serving

Only MODULE-LEVEL imports count: a function-level (lazy) import is an
explicit escape hatch for same-layer or upward references on cold paths
(e.g. ``kvcache.pool`` building an ``OnlineTuner`` only when
``autotune=`` is requested, or ``faults.snapshot`` reaching the
checkpoint store) and is deliberately exempt.  Packages not listed
(models, checkpoint, training, ...) are outside the cache subsystem and
unconstrained.

Run from the repo root:  python tools/check_layering.py
Exits non-zero listing every violation.  Also run by
tests/test_layering.py, so `pytest` catches violations locally.
"""

from __future__ import annotations

import ast
import pathlib
import sys

# longest prefix wins: repro.core.engine is layer 0, the rest of
# repro.core layer 1
LAYERS = {
    "repro.core.engine": 0,
    "repro.obs": 0,
    "repro.core": 1,
    "repro.traceio": 2,
    "repro.faults": 2,
    "repro.tuning": 3,
    "repro.shardcache": 3,
    "repro.kvcache": 3,
    "repro.kernels": 3,
    "repro.serving": 4,
}

# sealed packages may not import ANY other layered package, sideways
# included: obs is instrumented BY every layer, so an obs -> cache
# import would be a cycle waiting to happen
SEALED = {"repro.obs"}

# restricted packages have an explicit allow-list of layered packages
# they may import (tighter than the downward rule): repro.faults must
# stay a leaf over the policy core — a faults -> traceio edge, although
# "sideways", would let chaos machinery grow into a second trace stack
RESTRICTED = {
    "repro.faults": ("repro.core", "repro.obs", "repro.faults"),
}


def _sealed_prefix(module: str) -> str | None:
    for prefix in SEALED:
        if module == prefix or module.startswith(prefix + "."):
            return prefix
    return None


def _restricted_prefix(module: str) -> str | None:
    for prefix in RESTRICTED:
        if module == prefix or module.startswith(prefix + "."):
            return prefix
    return None


def _in_allowed(imported: str, allowed: tuple) -> bool:
    return any(imported == p or imported.startswith(p + ".")
               for p in allowed)


def layer_of(module: str) -> int | None:
    best = None
    for prefix, layer in LAYERS.items():
        if module == prefix or module.startswith(prefix + "."):
            if best is None or len(prefix) > len(best[0]):
                best = (prefix, layer)
    return None if best is None else best[1]


def module_name(path: pathlib.Path, src: pathlib.Path) -> str:
    rel = path.relative_to(src).with_suffix("")
    parts = list(rel.parts)
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def module_level_imports(tree: ast.Module):
    """(lineno, imported-module) for imports at module scope only —
    anything nested in a function/method body is a lazy import and
    exempt.  Class-body imports count as module level (they run at
    import time)."""
    out = []

    def walk(node):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                continue
            if isinstance(child, ast.Import):
                out.extend((child.lineno, a.name) for a in child.names)
            elif isinstance(child, ast.ImportFrom):
                if child.level == 0 and child.module:
                    out.append((child.lineno, child.module))
            else:
                walk(child)

    walk(tree)
    return out


def check(src: pathlib.Path):
    violations = []
    for path in sorted(src.rglob("*.py")):
        mod = module_name(path, src)
        mod_layer = layer_of(mod)
        if mod_layer is None:
            continue
        tree = ast.parse(path.read_text(), filename=str(path))
        sealed = _sealed_prefix(mod)
        restricted = _restricted_prefix(mod)
        for lineno, imported in module_level_imports(tree):
            imp_layer = layer_of(imported)
            if imp_layer is None:
                continue
            if sealed and _sealed_prefix(imported) != sealed:
                violations.append(
                    f"{path}:{lineno}: {mod} (sealed) imports layered "
                    f"package {imported}")
            elif restricted and not _in_allowed(imported,
                                                RESTRICTED[restricted]):
                violations.append(
                    f"{path}:{lineno}: {mod} (restricted) imports "
                    f"{imported} — allowed: "
                    f"{', '.join(RESTRICTED[restricted])}")
            elif imp_layer > mod_layer:
                violations.append(
                    f"{path}:{lineno}: {mod} (layer {mod_layer}) imports "
                    f"{imported} (layer {imp_layer}) at module level")
    return violations


def main() -> int:
    src = pathlib.Path(__file__).resolve().parent.parent / "src"
    violations = check(src)
    for v in violations:
        print(v)
    if violations:
        print(f"\n{len(violations)} layering violation(s)")
        return 1
    print("layering OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
