#!/usr/bin/env python
"""Render an obs JSON snapshot (or the delta of two) as a readable report.

    PYTHONPATH=src python tools/obsreport.py SNAP.json
    PYTHONPATH=src python tools/obsreport.py OLD.json NEW.json   # delta
    ... --events 40        # show up to N trailing events (default 20)
    ... --incidents        # incident timeline only (faults, retries,
                           # degraded-mode flips, shard loss/rewarm,
                           # restores, rebalances/resizes)
    ... --prom             # emit Prometheus text instead of the report

Snapshots come from ``ObsSink.snapshot().to_json()`` anywhere in the
stack (``ProdClock2QPlus.obs``, ``ShardedClock2QPlus.obs_snapshot()``,
``BlockPool.obs_snapshot()``, ``ServingEngine.obs_snapshot()``) — the CI
bench job uploads one as ``experiments/obs_snapshot.json``.
"""

from __future__ import annotations

import argparse
import pathlib
import sys
from collections import defaultdict

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "src"))

from repro.faults import FAULT_NAMES  # noqa: E402
from repro.obs import INCIDENT_KINDS, Snapshot, delta, to_prometheus  # noqa: E402
from repro.obs.metrics import parse_sample_key  # noqa: E402
from repro.serving.admission import SHED_REASONS  # noqa: E402


def load(path: str) -> Snapshot:
    return Snapshot.from_json(pathlib.Path(path).read_text())


def _label_str(labels: dict) -> str:
    return ",".join(f"{k}={v}" for k, v in sorted(labels.items()))


def render(snap: Snapshot, n_events: int = 20) -> str:
    out = []
    title = "obs snapshot" + (" (delta)" if snap.meta.get("delta") else "")
    out.append(f"== {title} @ ts={snap.ts:.3f} ==")
    if snap.meta:
        out.append("meta: " + _label_str(snap.meta))

    if snap.counters:
        out.append("\n-- counters --")
        by_name = defaultdict(list)
        for key, v in snap.counters.items():
            name, labels = parse_sample_key(key)
            by_name[name].append((_label_str(labels), v))
        for name in sorted(by_name):
            rows = sorted(by_name[name])
            total = sum(v for _, v in rows)
            out.append(f"{name}  (total {total})")
            for lbl, v in rows:
                out.append(f"    {lbl or '-':<48} {v}")

    if snap.gauges:
        out.append("\n-- gauges --")
        for key in sorted(snap.gauges):
            out.append(f"    {key:<52} {snap.gauges[key]:g}")

    if snap.hists:
        out.append("\n-- histograms --")
        for key in sorted(snap.hists):
            h = snap.hists[key]
            count = h["count"]
            out.append(f"{key}: count={count} sum={h['sum']:.6g}")
            if count > 0:
                mean = h["sum"] / count
                qs = {q: _quantile(h, q) for q in (0.5, 0.9, 0.99)}
                out.append(
                    f"    mean={mean:.3e}  p50<={qs[0.5]:.3e}  "
                    f"p90<={qs[0.9]:.3e}  p99<={qs[0.99]:.3e}")
                out.append("    " + _sparkline(h))

    if snap.events:
        out.append(f"\n-- events (last {min(n_events, len(snap.events))} "
                   f"of {len(snap.events)} retained, "
                   f"{snap.dropped_events} wrapped away) --")
        for e in snap.events[-n_events:]:
            out.append(f"    [{e['src']}:{e['seq']}] {e['kind']:<14} "
                       f"shard={e['shard']} a={e['a']} b={e['b']} "
                       f"c={e['c']:g}")
    return "\n".join(out) + "\n"


def _describe_incident(e: dict) -> str:
    kind, shard, a, b = e["kind"], e["shard"], e["a"], e["b"]
    if kind == "fault_inject":
        return f"injected {FAULT_NAMES.get(a, a)} (op #{b})"
    if kind == "io_retry":
        return f"IO retry #{a} after {b}-tick backoff"
    if kind == "io_error":
        return f"IO op on key {a} abandoned after {b} attempts"
    if kind == "degraded":
        return ("ENTERED read-through (breaker open)" if a
                else "recovered to healthy (breaker closed)")
    if kind == "shard_lost":
        return f"shard {shard} LOST ({a} resident entries gone)"
    if kind == "shard_rewarm":
        return f"shard {shard} rewarmed: {a} residents readmitted, " \
               f"{b} ghost-seeded"
    if kind == "restore":
        return f"restored snapshot step {a} ({b} resident entries)"
    if kind == "journal_truncated":
        return f"journal torn tail truncated at LSN {a} ({b} bytes cut)"
    if kind == "promote":
        return f"shard {shard} PROMOTED from standby ({a} journal " \
               f"records replayed, lag {b} at loss)"
    if kind == "rebalance":
        return f"shard {shard} capacity retarget {a} -> {b}"
    if kind in ("resize", "resize_done"):
        return f"shard {shard} resize" + \
               (" complete" if kind == "resize_done" else f" -> {a}")
    if kind in ("shed", "reject"):
        # scheduler events carry the virtual tick in the shard column
        return f"req {a} {kind} at tick {shard} ({SHED_REASONS.get(b, b)})"
    return f"shard={shard} a={a} b={b}"


def render_incidents(snap: Snapshot, n_events: int = 200) -> str:
    """The incident timeline: only fault/recovery-relevant typed events
    (``obs.INCIDENT_KINDS``), one annotated line each, in ring order."""
    incidents = [e for e in snap.events if e["kind"] in INCIDENT_KINDS]
    out = [f"== incident timeline @ ts={snap.ts:.3f} "
           f"({len(incidents)} incident events of {len(snap.events)} "
           f"retained) =="]
    for e in incidents[-n_events:]:
        out.append(f"  [{e['src']}:{e['seq']:>6}] {e['kind']:<13} "
                   f"{_describe_incident(e)}")
    if not incidents:
        out.append("  (no incidents recorded)")
    # replication health alongside the timeline: the per-shard standby
    # lag gauges (repro.faults.replica) are what the promote-vs-rewarm
    # decision reads, so an incident review needs them in view
    lags = sorted(k for k in snap.gauges
                  if k.startswith("cache_replica_lag_lsn"))
    if lags:
        out.append("  -- replication lag (journal records behind) --")
        for k in lags:
            out.append(f"    {k} = {snap.gauges[k]:g}")
    return "\n".join(out) + "\n"


def _quantile(h: dict, q: float) -> float:
    total = h["count"]
    run = 0
    finite = [b for b in h["le"] if b != float("inf")]
    for le, c in zip(h["le"], h["counts"]):
        run += c
        if run >= q * total:
            return le if le != float("inf") else finite[-1]
    return finite[-1] if finite else float("nan")


def _sparkline(h: dict, width: int = 40) -> str:
    counts = h["counts"]
    # trim empty head/tail buckets for a readable strip
    nz = [i for i, c in enumerate(counts) if c]
    if not nz:
        return ""
    lo, hi = nz[0], nz[-1] + 1
    blocks = " .:-=+*#%@"
    peak = max(counts[lo:hi])
    strip = "".join(
        blocks[min(len(blocks) - 1,
                   int(round((len(blocks) - 1) * c / peak)))]
        for c in counts[lo:hi])
    return f"buckets[{lo}:{hi}] |{strip[:width]}|"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("snapshot", help="obs snapshot JSON file")
    ap.add_argument("newer", nargs="?", default=None,
                    help="second snapshot: report the delta old -> new")
    ap.add_argument("--events", type=int, default=20,
                    help="max trailing events to show (default 20)")
    ap.add_argument("--prom", action="store_true",
                    help="emit Prometheus text exposition instead")
    ap.add_argument("--incidents", action="store_true",
                    help="render only the incident timeline (faults, "
                         "retries, degraded flips, shard loss/rewarm, "
                         "restores, rebalances)")
    args = ap.parse_args(argv)

    snap = load(args.snapshot)
    if args.newer:
        snap = delta(snap, load(args.newer))
        snap.meta["delta"] = "1"
    if args.prom:
        sys.stdout.write(to_prometheus(snap))
    elif args.incidents:
        sys.stdout.write(render_incidents(snap, max(args.events, 200)))
    else:
        sys.stdout.write(render(snap, args.events))
    return 0


if __name__ == "__main__":
    sys.exit(main())
