"""Fault-tolerant checkpointing.

Design for 1000+ nodes (DESIGN.md §6): every host writes only its
addressable shards, keyed by (step, leaf-path, shard-index), plus a
manifest with shapes/dtypes/content hashes; restore reshards to whatever
mesh the job restarts with (elastic).  In this single-process container
the host owns all shards, so leaves are saved whole — the manifest and
reshard-on-restore code paths are the same ones a multi-host deployment
exercises.

Features: atomic manifest commit (write + rename), async save thread,
retention of the last K checkpoints, corruption detection via xxhash-like
content digests, resume-from-latest.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, List, Optional, Tuple

import jax
import numpy as np


def _leaf_paths(tree: Any, prefix: str = "") -> List[Tuple[str, Any]]:
    if isinstance(tree, dict):
        out = []
        for k in sorted(tree):
            out.extend(_leaf_paths(tree[k], f"{prefix}{k}/"))
        return out
    if hasattr(tree, "_fields"):
        out = []
        for k in tree._fields:
            out.extend(_leaf_paths(getattr(tree, k), f"{prefix}{k}/"))
        return out
    return [(prefix[:-1], tree)]


def _digest(arr: np.ndarray) -> str:
    return hashlib.sha1(arr.tobytes()).hexdigest()[:16]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # -- save ------------------------------------------------------------------
    def save(self, step: int, state: Any, blocking: bool = True) -> None:
        host_state = jax.tree.map(lambda x: np.asarray(x), state)
        if blocking:
            self._write(step, host_state)
        else:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host_state), daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_state: Any) -> None:
        tmp = self.dir / f".tmp_step_{step}"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "time": time.time(), "leaves": {}}
        for i, (path, leaf) in enumerate(_leaf_paths(host_state)):
            fn = f"leaf_{i:05d}.npy"
            np.save(tmp / fn, leaf)
            manifest["leaves"][path] = {
                "file": fn, "shape": list(leaf.shape),
                "dtype": str(leaf.dtype), "digest": _digest(leaf)}
        with open(tmp / "manifest.json", "w") as f:
            json.dump(manifest, f)
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # -- restore -----------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for p in self.dir.glob("step_*"):
            try:
                out.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.all_steps()
        return s[-1] if s else None

    def restore(self, step: Optional[int], like: Any,
                shardings: Any = None, verify: bool = False) -> Any:
        """Restore into the structure of ``like``; optionally device_put
        with ``shardings`` (pytree of NamedSharding) — this is the elastic
        path: the target mesh may differ from the one that saved."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:08d}"
        with open(d / "manifest.json") as f:
            manifest = json.load(f)
        leaves = {}
        for path, meta in manifest["leaves"].items():
            arr = np.load(d / meta["file"])
            if verify and _digest(arr) != meta["digest"]:
                raise IOError(f"corrupt leaf {path} in step {step}")
            leaves[path] = arr
        flat_like = _leaf_paths(like)
        missing = [p for p, _ in flat_like if p not in leaves]
        if missing:
            raise KeyError(f"checkpoint step {step} missing leaves "
                           f"{missing[:5]}...")
        shard_flat = (_leaf_paths(shardings) if shardings is not None
                      else None)

        out_leaves = []
        for i, (path, leaf_like) in enumerate(flat_like):
            arr = leaves[path]
            if list(arr.shape) != list(leaf_like.shape):
                raise ValueError(f"shape mismatch for {path}: "
                                 f"{arr.shape} vs {leaf_like.shape}")
            if shard_flat is not None:
                arr = jax.device_put(arr, shard_flat[i][1])
            out_leaves.append(arr)
        return _unflatten_like(like, iter(out_leaves))


def _unflatten_like(tree: Any, leaves) -> Any:
    if isinstance(tree, dict):
        return {k: _unflatten_like(tree[k], leaves) for k in sorted(tree)}
    if hasattr(tree, "_fields"):
        return type(tree)(*[_unflatten_like(getattr(tree, k), leaves)
                            for k in tree._fields])
    return next(leaves)
