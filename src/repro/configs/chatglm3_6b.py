"""chatglm3-6b [dense] — RoPE 2d (partial rotary), GQA kv=2, qkv bias.
[arXiv:2406.12793; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", family="dense", n_layers=28, d_model=4096,
    n_heads=32, n_kv_heads=2, d_ff=13696, vocab=65024,
    rope_frac=0.5, qkv_bias=True, norm="rmsnorm", act="swiglu")
