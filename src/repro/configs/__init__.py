"""Architecture registry: ``get_config("<arch-id>")`` plus reduced configs
for CPU smoke tests (same family/topology, tiny dims)."""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict

from repro.models.config import ModelConfig

ARCH_IDS = [
    "chatglm3-6b", "olmo-1b", "granite-3-8b", "phi3-medium-14b",
    "llava-next-mistral-7b", "zamba2-2.7b", "whisper-tiny",
    "olmoe-1b-7b", "kimi-k2-1t-a32b", "falcon-mamba-7b",
]

_MODULE_OF = {a: "repro.configs." + a.replace("-", "_").replace(".", "_")
              for a in ARCH_IDS}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULE_OF:
        raise KeyError(f"unknown arch {arch!r}; have {ARCH_IDS}")
    return importlib.import_module(_MODULE_OF[arch]).CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    kw = dict(
        name=cfg.name + "-reduced",
        n_layers=min(cfg.n_layers, 2),
        d_model=64,
        n_heads=4,
        n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab=512,
        head_dim=16 if cfg.head_dim else 0,
    )
    if cfg.family == "moe":
        kw.update(n_experts=8, experts_per_tok=2, moe_d_ff=32,
                  n_shared_experts=min(cfg.n_shared_experts, 1))
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=8, ssm_head_dim=16, ssm_chunk=32, dt_rank=8)
    if cfg.family == "hybrid":
        # every=1 keeps TWO shared-attn invocations (weight reuse across
        # calls, G=2 caches — same as the old 4-layer/every-2 shape) at
        # half the mamba-layer compile cost
        kw.update(shared_attn_every=1)
    if cfg.family == "encdec":
        kw.update(n_enc_layers=2)
    if cfg.family == "vlm":
        kw.update(n_patches=16)
    kw.update(dtype="float32", param_dtype="float32")
    return dataclasses.replace(cfg, **kw)
