"""llava-next-mistral-7b [vlm] — Mistral-7B backbone; anyres patch frontend
is a STUB (input_specs supplies precomputed patch embeddings).
[hf:llava-hf/llava-v1.6-mistral-7b-hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=32000,
    norm="rmsnorm", act="swiglu", frontend="patch_stub", n_patches=576)
