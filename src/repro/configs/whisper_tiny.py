"""whisper-tiny [audio] — enc-dec backbone; conv audio frontend is a STUB
(input_specs supplies precomputed frame embeddings). [arXiv:2212.04356]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="encdec", n_layers=4, n_enc_layers=4,
    d_model=384, n_heads=6, n_kv_heads=6, d_ff=1536, vocab=51865,
    norm="layernorm", act="gelu", tie_embeddings=True,
    frontend="audio_stub")
