"""olmoe-1b-7b [moe] — 64 experts, top-8, expert d_ff=1024.
[arXiv:2409.02060; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe", n_layers=16, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1024, vocab=50304,
    n_experts=64, experts_per_tok=8, moe_d_ff=1024,
    norm="rmsnorm", act="swiglu")
