"""Trace conversion / materialization CLI.

    # convert between formats (suffix-sniffed; override with --in/--out-format)
    python -m repro.traceio.convert input.csv output.bin
    python -m repro.traceio.convert trace.npz trace.npy

    # densify raw production obj_ids (sparse/hashed 64-bit) to [0, n_unique)
    # for the int32 dense-table replay engines (replacement is
    # label-invariant, so miss ratios are unchanged)
    python -m repro.traceio.convert --relabel cloudphysics.bin dense.npy

    # materialize a registered scenario (repro.core.traces.SCENARIOS) to disk
    python -m repro.traceio.convert --scenario ghost-thrash --n 20000000 \
        --seed 3 trace.bin

    # list scenarios / inspect a trace
    python -m repro.traceio.convert --list-scenarios
    python -m repro.traceio.convert --info trace.bin

Conversion loads the key column and rewrites it (an oracleGeneral output
recomputes next_access_vtime, which needs the whole key column anyway);
streaming replay of the result is TraceStore's job, not convert's.
"""

from __future__ import annotations

import argparse
import sys

import numpy as np

from repro.traceio.formats import load_trace, save_trace, sniff_format
from repro.traceio.store import TraceStore


def _info(path: str, fmt: str | None) -> str:
    resolved = sniff_format(path, fmt)
    if resolved in ("oracle", "npy"):
        store = TraceStore(path, resolved)
        n = len(store)
        mx = store.max_key()
    else:
        keys = load_trace(path, resolved)
        n = keys.size
        mx = int(keys.max()) if n else -1
    return f"{path}: format={resolved} n={n} max_key={mx}"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.traceio.convert", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("input", nargs="?", help="input trace (omit with --scenario)")
    ap.add_argument("output", nargs="?", help="output trace path")
    ap.add_argument("--in-format", default=None,
                    help="oracle|csv|npz|npy (default: sniff suffix)")
    ap.add_argument("--out-format", default=None,
                    help="oracle|csv|npz|npy (default: sniff suffix)")
    ap.add_argument("--relabel", action="store_true",
                    help="densify keys to [0, n_unique) while converting "
                         "(required before the dense-table replay engines "
                         "can ingest sparse/hashed production obj_ids)")
    ap.add_argument("--scenario", default=None,
                    help="generate this registered scenario instead of reading")
    ap.add_argument("--n", type=int, default=1_000_000,
                    help="scenario length (with --scenario)")
    ap.add_argument("--seed", type=int, default=0,
                    help="scenario seed (with --scenario)")
    ap.add_argument("--list-scenarios", action="store_true",
                    help="print the scenario registry and exit")
    ap.add_argument("--info", action="store_true",
                    help="print trace stats for `input` and exit")
    args = ap.parse_args(argv)

    if args.list_scenarios:
        from repro.core.traces import SCENARIOS
        for name in sorted(SCENARIOS):
            print(f"{name:20s} {SCENARIOS[name].description}")
        return 0

    if args.info:
        if not args.input:
            ap.error("--info needs an input path")
        print(_info(args.input, args.in_format))
        return 0

    if args.scenario:
        out = args.output or args.input
        if not out:
            ap.error("--scenario needs an output path")
        from repro.core.traces import make_trace
        keys = make_trace(args.scenario, n=args.n, seed=args.seed)
    else:
        if not (args.input and args.output):
            ap.error(
                "need input and output paths (or --scenario/--list-scenarios)")
        out = args.output
        keys = np.asarray(load_trace(args.input, args.in_format))
    if args.relabel:
        from repro.traceio.formats import relabel
        keys = relabel(keys)[0].astype(np.int64)
    save_trace(out, keys, args.out_format)
    mx = int(keys.max()) if keys.size else -1
    print(f"{out}: format={sniff_format(out, args.out_format)} "
          f"n={keys.size} max_key={mx}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
