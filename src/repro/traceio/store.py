"""``TraceStore`` — mmap-backed streaming access to an on-disk trace.

The store never loads the trace into RAM: the ``oracle`` (structured
24-byte records) and ``npy`` (raw int64 keys) formats are memory-mapped,
and ``chunks()`` materializes one fixed-size int64 chunk at a time, so a
replay's peak host memory is bounded by the chunk size no matter how
long the trace is (the OS pages mapped bytes in and out behind the
view).  CSV/npz traces have no random-access record layout; convert them
once with ``repro.traceio.convert`` and stream the result.

``iter_chunks`` is the shared chunk-source adapter used by every chunked
replay driver: it accepts an in-memory array, a ``TraceStore``, or any
iterable of key arrays, so callers write one loop for all three.
"""

from __future__ import annotations

import os
from typing import Iterable, Iterator

import numpy as np

from repro.traceio.formats import ORACLE_DTYPE, sniff_format

DEFAULT_CHUNK = 1 << 20  # 1M accesses / 8 MiB of int64 keys per chunk


class TraceStore:
    """Memory-mapped on-disk trace with bounded-memory chunk iteration."""

    def __init__(self, path: str | os.PathLike, fmt: str | None = None):
        self.path = str(path)
        self.fmt = sniff_format(path, fmt)
        if self.fmt == "oracle":
            if os.path.getsize(self.path) == 0:  # mmap rejects empty files
                self._rec = None
                self._keys = np.empty(0, dtype=np.int64)
            else:
                self._rec = np.memmap(self.path, dtype=ORACLE_DTYPE, mode="r")
                self._keys = self._rec["obj_id"]  # strided view on the mmap
        elif self.fmt == "npy":
            self._rec = None
            self._keys = np.load(self.path, mmap_mode="r")
        else:
            raise ValueError(
                f"TraceStore streams 'oracle' or 'npy' traces; {self.fmt!r} "
                "has no mmap-able record layout — convert it first "
                "(python -m repro.traceio.convert)")

    def __len__(self) -> int:
        return int(self._keys.shape[0])

    def chunk(self, start: int, stop: int) -> np.ndarray:
        """Materialize ``[start, stop)`` as an int64 array (a copy — the
        only bytes this touches are the chunk's own pages)."""
        return np.asarray(self._keys[start:stop]).astype(np.int64)

    def chunks(self, chunk_size: int = DEFAULT_CHUNK) -> Iterator[np.ndarray]:
        """Yield consecutive fixed-size key chunks (last one may be short).
        Concatenating the yields reproduces the trace exactly."""
        if chunk_size < 1:
            raise ValueError(f"chunk_size must be >= 1, got {chunk_size}")
        for start in range(0, len(self), chunk_size):
            yield self.chunk(start, min(start + chunk_size, len(self)))

    def keys(self) -> np.ndarray:
        """Whole-trace load (int64).  Defeats the bounded-memory point —
        for tests/small traces only."""
        return self.chunk(0, len(self))

    def max_key(self, chunk_size: int = DEFAULT_CHUNK) -> int:
        """Streaming max over the key column (bounded memory)."""
        best = -1
        for c in self.chunks(chunk_size):
            if c.size:
                best = max(best, int(c.max()))
        return best

    def universe(self, chunk_size: int = DEFAULT_CHUNK) -> int:
        """Dense-id universe bound: max key + 1 (0 for an empty trace)."""
        return self.max_key(chunk_size) + 1


def iter_chunks(source, chunk_size: int = DEFAULT_CHUNK
                ) -> Iterator[np.ndarray]:
    """Uniform chunk iteration over an ndarray, a TraceStore, or any
    iterable of key arrays.  Arrays/stores are cut to ``chunk_size``;
    pre-chunked iterables are passed through as-is."""
    if isinstance(source, TraceStore):
        yield from source.chunks(chunk_size)
    elif isinstance(source, np.ndarray):
        src = source.ravel()
        for start in range(0, src.size, chunk_size):
            yield src[start:start + chunk_size]
    elif isinstance(source, Iterable):
        for c in source:
            yield np.asarray(c).ravel()
    else:
        raise TypeError(f"cannot iterate trace chunks from {type(source)!r}")
