"""Trace I/O + streaming subsystem.

The paper's evaluation replays multi-hundred-million-access production
traces (CloudPhysics, Meta, Tencent); this package is the repro's path to
that scale: on-disk trace formats (the libCacheSim-compatible
``oracleGeneral`` binary layout, CSV, npz, raw npy), a ``TraceStore``
that mmaps a trace and yields fixed-size chunks so replay runs in
bounded memory regardless of trace length, and a ``convert`` CLI
(``python -m repro.traceio.convert``) that translates between formats
and materializes any registered scenario (``repro.core.traces.SCENARIOS``)
to disk.

Chunked *state-carry* replay drivers live next to their engines
(``core.jax_engine.replay_chunked``/``replay_store``,
``shardcache.replay.replay_store``, ``tuning.profiler.
estimate_sweep_stream``, ``ProdClock2QPlus.replay``); each is
bit-identical to its single-shot path — asserted in
tests/test_chunked.py.
"""

from repro.traceio.formats import (  # noqa: F401
    ORACLE_DTYPE, load_trace, relabel, save_trace, sniff_format,
    read_csv, read_npy, read_npz, read_oracle,
    write_csv, write_npy, write_npz, write_oracle,
)
from repro.traceio.store import TraceStore, iter_chunks  # noqa: F401
