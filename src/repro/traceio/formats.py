"""On-disk trace formats: oracleGeneral (binary), CSV, npz, raw npy.

``oracleGeneral`` is the libCacheSim binary layout — the lingua franca of
the cache-research tooling the paper's evaluation sits on — so real
production traces (CloudPhysics/Meta/Tencent releases) drop straight in:
packed little-endian 24-byte records

    uint32 real_time | uint64 obj_id | uint32 obj_size | int64 next_access_vtime

where ``next_access_vtime`` is the virtual time (request index) of the
key's next access, or -1 if never re-referenced (the "oracle" used by
Belady-family baselines).  The writer computes it in one vectorized
stable-argsort pass, so converting a 20M-access trace is seconds, not a
Python loop.

Readers return the int64 KEY column only — replacement decisions depend
only on key identity, and that is all the replay engines consume.
Writers accept optional ``times``/``sizes`` arrays (synthesizing
``arange``/1 otherwise), but a format conversion rewrites just the keys:
real timestamps and object sizes are NOT carried through ``convert``.
``load_trace``/``save_trace`` dispatch on an explicit format name or the
file suffix.
"""

from __future__ import annotations

import os
from pathlib import Path

import numpy as np

# packed little-endian, itemsize 24 — matches libCacheSim's oracleGeneral
ORACLE_DTYPE = np.dtype([
    ("time", "<u4"),
    ("obj_id", "<u8"),
    ("size", "<u4"),
    ("next_access_vtime", "<i8"),
])
assert ORACLE_DTYPE.itemsize == 24

CSV_HEADER = "time,obj_id,obj_size"

_SUFFIXES = {
    ".bin": "oracle", ".oracle": "oracle", ".oraclegeneral": "oracle",
    ".csv": "csv", ".npz": "npz", ".npy": "npy",
}


def sniff_format(path: str | os.PathLike, fmt: str | None = None) -> str:
    """Resolve a format name: explicit ``fmt`` wins, else file suffix."""
    if fmt:
        fmt = fmt.lower()
        if fmt not in ("oracle", "csv", "npz", "npy"):
            raise ValueError(f"unknown trace format {fmt!r}")
        return fmt
    suffix = Path(path).suffix.lower()
    if suffix not in _SUFFIXES:
        raise ValueError(
            f"cannot infer trace format from suffix {suffix!r} "
            f"(known: {sorted(_SUFFIXES)}); pass an explicit format")
    return _SUFFIXES[suffix]


def next_access_vtime(keys: np.ndarray) -> np.ndarray:
    """next_access_vtime[i] = index of the next access to keys[i] after i,
    or -1 (vectorized: stable sort groups each key's accesses in request
    order, so its successor within the group IS the next access)."""
    keys = np.asarray(keys)
    n = keys.size
    nxt = np.full(n, -1, dtype=np.int64)
    if n == 0:
        return nxt
    order = np.argsort(keys, kind="stable")
    sk = keys[order]
    same = sk[1:] == sk[:-1]
    nxt[order[:-1][same]] = order[1:][same]
    return nxt


def relabel(trace: np.ndarray) -> "tuple[np.ndarray, int]":
    """Dense relabelling: raw (possibly hashed 64-bit) keys ->
    ``[0, n_unique)`` int32 ids, preserving request order.  Replacement
    is label-invariant, so miss ratios are unchanged; the dense-table
    replay engines require it.  The single implementation shared by
    ``repro.tuning.sweep.relabel`` and the convert CLI's ``--relabel``
    (numpy-only on purpose: the CLI must not import JAX)."""
    uniq, inv = np.unique(np.asarray(trace), return_inverse=True)
    return inv.astype(np.int32), int(uniq.size)


def _as_keys(keys: np.ndarray) -> np.ndarray:
    keys = np.asarray(keys, dtype=np.int64).ravel()
    if keys.size and keys.min() < 0:
        raise ValueError("trace keys must be non-negative")
    return keys


# -- oracleGeneral ------------------------------------------------------------

def write_oracle(path: str | os.PathLike, keys: np.ndarray,
                 times: np.ndarray | None = None,
                 sizes: np.ndarray | None = None) -> None:
    keys = _as_keys(keys)
    rec = np.empty(keys.size, dtype=ORACLE_DTYPE)
    rec["time"] = np.arange(keys.size, dtype=np.uint32) if times is None \
        else np.asarray(times, dtype=np.uint32)
    rec["obj_id"] = keys.astype(np.uint64)
    rec["size"] = 1 if sizes is None else np.asarray(sizes, dtype=np.uint32)
    rec["next_access_vtime"] = next_access_vtime(keys)
    rec.tofile(str(path))


def read_oracle(path: str | os.PathLike) -> np.ndarray:
    """Whole-file load of the key column (stream with TraceStore instead
    for traces that should not live in RAM)."""
    rec = np.fromfile(str(path), dtype=ORACLE_DTYPE)
    return rec["obj_id"].astype(np.int64)


# -- CSV ----------------------------------------------------------------------

def write_csv(path: str | os.PathLike, keys: np.ndarray,
              times: np.ndarray | None = None,
              sizes: np.ndarray | None = None) -> None:
    keys = _as_keys(keys)
    t = np.arange(keys.size, dtype=np.int64) if times is None \
        else np.asarray(times, dtype=np.int64)
    s = np.ones(keys.size, dtype=np.int64) if sizes is None \
        else np.asarray(sizes, dtype=np.int64)
    cols = np.stack([t, keys, s], axis=1)
    np.savetxt(str(path), cols, fmt="%d", delimiter=",",
               header=CSV_HEADER, comments="")


def read_csv(path: str | os.PathLike) -> np.ndarray:
    """Reads ``time,obj_id,obj_size`` (with or without header) or bare
    one-key-per-line files."""
    with open(path) as f:
        first = f.readline()
        skip = 1 if any(c.isalpha() for c in first) else 0
        has_data = bool(first.strip()) and skip == 0
        if not has_data:  # scan past blank lines (loadtxt skips them too)
            has_data = any(line.strip() for line in f)
    if not has_data:  # empty / header-only file: loadtxt would warn
        return np.empty(0, dtype=np.int64)
    data = np.loadtxt(str(path), delimiter=",", skiprows=skip,
                      dtype=np.int64, ndmin=2)
    if data.size == 0:
        return np.empty(0, dtype=np.int64)
    return data[:, 1] if data.shape[1] >= 2 else data[:, 0]


# -- npz / npy ----------------------------------------------------------------

def write_npz(path: str | os.PathLike, keys: np.ndarray,
              times: np.ndarray | None = None,
              sizes: np.ndarray | None = None) -> None:
    arrays = {"keys": _as_keys(keys)}
    if times is not None:
        arrays["times"] = np.asarray(times, dtype=np.int64)
    if sizes is not None:
        arrays["sizes"] = np.asarray(sizes, dtype=np.int64)
    np.savez_compressed(str(path), **arrays)


def read_npz(path: str | os.PathLike) -> np.ndarray:
    with np.load(str(path)) as z:
        if "keys" not in z:
            raise ValueError(f"{path}: npz trace must contain a 'keys' array")
        return z["keys"].astype(np.int64)


def write_npy(path: str | os.PathLike, keys: np.ndarray, **_ignored) -> None:
    np.save(str(path), _as_keys(keys))


def read_npy(path: str | os.PathLike) -> np.ndarray:
    return np.load(str(path)).astype(np.int64)


# -- dispatch -----------------------------------------------------------------

_READERS = {"oracle": read_oracle, "csv": read_csv,
            "npz": read_npz, "npy": read_npy}
_WRITERS = {"oracle": write_oracle, "csv": write_csv,
            "npz": write_npz, "npy": write_npy}


def load_trace(path: str | os.PathLike, fmt: str | None = None) -> np.ndarray:
    """Whole-file load -> int64 key array (format from suffix unless given)."""
    return _READERS[sniff_format(path, fmt)](path)


def save_trace(path: str | os.PathLike, keys: np.ndarray,
               fmt: str | None = None, times: np.ndarray | None = None,
               sizes: np.ndarray | None = None) -> None:
    _WRITERS[sniff_format(path, fmt)](path, keys, times=times, sizes=sizes)
