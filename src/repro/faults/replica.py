"""Hot-standby shard replication over the write-ahead delta journal.

A ``ShardReplica`` is a metadata mirror of one live shard: a private
``ProdClock2QPlus`` built from the journal's base snapshot that tails
the journal — applying records past its ``applied_lsn`` — so at any
instant it holds the shard's exact state as of some recent LSN.  The
staleness is *bounded* and *measured*: ``lag`` is exactly how many
journal records the standby is behind, exported as the
``cache_replica_lag_lsn{shard}`` gauge.

``ShardReplicator`` runs one journal + replica pair per shard of a
sharded service (duck-typed: anything with ``n_shards`` / ``shards`` /
``locks`` / ``lose_shard`` — no shardcache import, per the layering
rules).  ``poll()`` is the replication tick, driven from the pool's
lookup path on the virtual IO clock.  On shard loss, ``promote()``
replaces PR 8's cold ghost-rewarm: the standby first drains the journal
tail (so its state is bit-exact at the moment of loss), its state is
loaded wholesale into the fresh shard, and only the *payloads* need
refilling — keys whose payloads cannot be recovered are demoted to the
Ghost ring, where the paper's readmission machinery picks them up.
Because the full replacement-state structure (queues, clock hand,
recency bits, correlation-window seqs) survives, the post-failover miss
ratio matches the uninterrupted run far closer than a rewarm, which
must rebuild all of it through synthetic re-accesses.

The promote-vs-rewarm decision belongs to the caller (the pool): when
replication lag exceeds its threshold — the standby fell too far behind
to be worth promoting — fall back to ghost rewarm and ``reattach`` the
journal afterwards.  Either path bumps the journal epoch, starting a
fresh base + segment chain for the shard's new incarnation.
"""

from __future__ import annotations

import os
from typing import List, NamedTuple, Optional

from repro.core.prodcache import EMPTY
from repro.faults.io import Clock
from repro.faults.journal import JRecord, ShardJournal, apply_record
from repro.faults.snapshot import (
    load_state_dict, policy_from_snapshot, state_dict,
)
from repro.obs.events import EV_PROMOTE
from repro.obs.export import NullSink


class ShardReplica:
    """A bounded-staleness mirror of one journaled shard."""

    def __init__(self, journal: ShardJournal):
        self.journal = journal
        base = journal.base_state()
        self.mirror = policy_from_snapshot(base, obs=NullSink())
        self.applied_lsn = int(base["meta"].get("journal_lsn", 0))

    @property
    def lag(self) -> int:
        """Records the standby is behind the journal head (0 = caught up)."""
        return self.journal.lsn - self.applied_lsn

    def apply(self, rec: JRecord) -> bool:
        """Apply one record to the mirror.  Records at or below
        ``applied_lsn`` are skipped (idempotent — re-delivery after a
        resume is harmless); an LSN *gap* raises, because skipping a
        record would silently fork the mirror."""
        if rec.lsn <= self.applied_lsn:
            return False
        if rec.lsn != self.applied_lsn + 1:
            raise ValueError(
                f"replica at lsn {self.applied_lsn} handed record "
                f"{rec.lsn}: journal gap")
        apply_record(self.mirror, rec)
        self.applied_lsn = rec.lsn
        return True

    def catch_up(self, upto: Optional[int] = None) -> int:
        """Drain the journal tail into the mirror (optionally only up to
        LSN ``upto``); returns records applied."""
        n = 0
        for rec in self.journal.records_since(self.applied_lsn):
            if upto is not None and rec.lsn > upto:
                break
            if self.apply(rec):
                n += 1
        return n


class PromoteResult(NamedTuple):
    """What ``ShardReplicator.promote`` did."""

    replayed: int      # journal records drained into the standby at loss
    lag_at_loss: int   # how stale the standby was when the shard died
    refilled: int      # resident keys whose payloads were recovered
    demoted: int       # residents demoted to ghost (payload unrecoverable)


def _demote_to_ghost(sh, key: int) -> None:
    """Drop a resident entry whose payload is gone: remove it from the
    hash + payload maps (clearing pins — the payload no longer exists to
    stay pinned) and seed the key into the Ghost ring so its next touch
    readmits it through normal ghost promotion."""
    eid = sh._hash_lookup(key)
    if eid == EMPTY:
        eid = sh._find_stray(key)
    if eid == EMPTY:
        return
    sh._hash_remove(eid)
    sh.free_blocks.append(int(sh.block[eid]))
    sh.key[eid] = EMPTY
    sh.block[eid] = EMPTY
    sh.ref[eid] = False
    sh.pin[eid] = 0
    sh.io[eid] = False
    sh.dirty[eid] = False
    sh._ghost_push(key)


class ShardReplicator:
    """One journal + hot-standby replica per shard of a sharded service.

    ``directory=None`` keeps every journal in memory (pure hot-standby);
    a path gives each shard its own durable journal under
    ``directory/shard{i}``.  ``lag_threshold`` is advisory state for the
    caller's promote-vs-rewarm decision (``should_promote``); ``clock``
    is the virtual tick clock replication time is measured on (shared
    with the pool's ``HostIO`` when faults are wired).
    """

    def __init__(self, svc, directory: Optional[str] = None, *,
                 lag_threshold: int = 4096, segment_records: int = 4096,
                 sync_every: int = 0, clock: Optional[Clock] = None,
                 obs=None, plan=None):
        self.svc = svc
        self.directory = directory
        self.lag_threshold = int(lag_threshold)
        self.clock = clock if clock is not None else Clock()
        self.obs = obs
        self._segment_records = int(segment_records)
        self._sync_every = int(sync_every)
        self._plan = plan
        self.journals: List[ShardJournal] = []
        self.replicas: List[ShardReplica] = []
        self._g_lag = (obs.gauge("cache_replica_lag_lsn", ("shard",))
                       if obs is not None else None)
        self._lag_cells = []
        for i in range(svc.n_shards):
            jr = self._new_journal(i, epoch=0)
            with svc.locks[i]:
                jr.attach(svc.shards[i])
            self.journals.append(jr)
            self.replicas.append(ShardReplica(jr))
            self._lag_cells.append(
                self._g_lag.labels(str(i)) if self._g_lag is not None
                else None)

    def _new_journal(self, sid: int, epoch: int) -> ShardJournal:
        d = (os.path.join(self.directory, f"shard{sid}")
             if self.directory is not None else None)
        return ShardJournal(d, shard_id=sid, epoch=epoch,
                            segment_records=self._segment_records,
                            sync_every=self._sync_every, plan=self._plan)

    def lag(self, sid: int) -> int:
        """Current replication lag of shard ``sid`` in journal records."""
        return self.replicas[sid].lag

    def should_promote(self, sid: int) -> bool:
        """The promote-vs-rewarm decision: promote while the standby's
        lag is within threshold (it can replay the tail and be exact);
        past it, a ghost rewarm is the better recovery."""
        return self.lag(sid) <= self.lag_threshold

    def poll(self) -> int:
        """One replication tick: export pre-drain lag, catch every
        standby up to its journal head, advance the virtual clock.
        Returns total records applied."""
        applied = 0
        for i, rep in enumerate(self.replicas):
            cell = self._lag_cells[i]
            if cell is not None:
                cell.value = float(rep.lag)
            applied += rep.catch_up()
        self.clock.advance(1)
        return applied

    def reattach(self, sid: int) -> None:
        """Start the next journal epoch for shard ``sid``'s current
        incarnation: seal the old journal, open a fresh one (new base,
        new segment chain) and rebuild the standby from it.  Called
        after promote AND after a rewarm fallback, so journaling always
        resumes on the shard that is actually serving."""
        old = self.journals[sid]
        old.close()
        jr = self._new_journal(sid, epoch=old.epoch + 1)
        with self.svc.locks[sid]:
            jr.attach(self.svc.shards[sid])
        self.journals[sid] = jr
        self.replicas[sid] = ShardReplica(jr)

    def promote(self, sid: int, fill=None) -> PromoteResult:
        """Fail shard ``sid`` over to its hot standby.

        Drains the journal tail into the standby (making it bit-exact at
        the moment of loss), swaps the dead shard for a fresh one
        (``svc.lose_shard``), loads the standby's full replacement state
        into it, and refills payloads: ``fill(key)`` returns a
        ``filler(local_slot)`` when the payload is recoverable (host
        tier) or None when it is not — those keys are demoted to the
        Ghost ring for organic readmission.  ``fill=None`` (the whole
        callback absent) means payloads are not modeled at all —
        metadata-only callers, same convention as
        ``GhostJournal.rewarm`` — and every resident is kept.  Finally
        ``reattach`` bumps the journal epoch and emits ``EV_PROMOTE``.
        """
        rep = self.replicas[sid]
        lag_at_loss = rep.lag
        replayed = rep.catch_up()  # exact state at loss, from the tail
        self.svc.lose_shard(sid)
        refilled = 0
        demoted = 0
        with self.svc.locks[sid]:
            sh = self.svc.shards[sid]
            load_state_dict(sh, state_dict(rep.mirror))
            if fill is not None:
                for key in sh.resident_keys():
                    filler = fill(key)
                    if filler is None:
                        _demote_to_ghost(sh, key)
                        demoted += 1
                    else:
                        filler(sh.slot_of(key))
                        sh.io_done(key)
                        refilled += 1
        self.reattach(sid)
        if self.obs is not None and self.obs.ring.enabled:
            self.obs.emit(EV_PROMOTE, shard=sid, a=replayed, b=lag_at_loss)
        return PromoteResult(replayed=replayed, lag_at_loss=lag_at_loss,
                             refilled=refilled, demoted=demoted)
