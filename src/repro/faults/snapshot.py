"""Crash-consistent snapshot/restore of the full replacement-engine state.

A snapshot captures EVERYTHING the paper's engine carries between
accesses — the layout arrays (keys, ref/dirty/pin/DOING-IO bits, payload
handles, both hash tables), the ghost ring with its hash and cursor, the
correlation-window state (per-entry insertion sequence numbers + the
global ``small_seq`` counter), the clock hand / small cursor, the
live-resize migration state, and the free payload-handle stack — so a
restored cache resumes a replay **hit for hit** against the uninjured
run (the chaos suite asserts this).  Telemetry (obs counters/rings) is
deliberately NOT state: a warm-restarted process starts fresh counters.

Three layers, lowest first:

  * ``state_dict(cache)`` / ``load_state_dict(cache, d)`` — plain-data
    capture/restore for ``ProdClock2QPlus`` and (duck-typed, captured
    under every shard lock) ``ShardedClock2QPlus``.
  * ``pack(d)`` / ``unpack(b)`` — the versioned on-disk byte format
    (documented in docs/operations.md, byte-pinned by
    ``tests/test_faults.py::test_snapshot_golden_bytes``), plus
    ``write_snapshot``/``read_snapshot`` single-file atomic IO.
  * ``SnapshotManager`` — retention/atomic-commit/digest-verified store
    built on ``repro.checkpoint.ckpt.CheckpointManager`` (the snapshot
    becomes a pytree checkpoint; version + scalars ride as a packed
    meta leaf), for periodic background snapshots of a serving cache.
"""

from __future__ import annotations

import hashlib
import json
import os
import struct
from typing import Dict, Optional

import numpy as np

from repro.obs import EV_RESTORE

MAGIC = b"C2QSNAP1"
VERSION = 2   # newest format this module can read/write
_V1 = 1       # plain engine-state snapshots (no journal linkage)

# meta keys restored as plain attributes of a ProdClock2QPlus
_PROD_SCALARS = (
    "capacity", "small_cap", "main_cap", "ghost_cap", "window",
    "spos", "hand", "gpos", "small_seq",
    "n_buckets", "g_n_buckets", "old_n_buckets",
    "max_capacity", "max_small", "max_main", "max_ghost",
    "skip_limit", "dirty_scan_limit", "track_io", "shard_id",
)
_PROD_ARRAYS = ("key", "ref", "dirty", "pin", "io", "block", "seq",
                "buckets", "nxt", "gkey", "gbuckets", "gnxt")


def _is_sharded(cache) -> bool:
    return hasattr(cache, "shards")


# -- capture -------------------------------------------------------------------

def _prod_state(pol) -> Dict:
    meta = {k: getattr(pol, k) for k in _PROD_SCALARS}
    meta.update(version=_V1, kind="prod",
                rehash_cursor=pol._rehash_cursor,
                small_frac=pol._small_frac, ghost_frac=pol._ghost_frac,
                window_frac=pol._window_frac)
    arrays = {name: getattr(pol, name).copy() for name in _PROD_ARRAYS}
    arrays["free_blocks"] = np.asarray(pol.free_blocks, dtype=np.int64)
    if pol.old_buckets is not None:
        arrays["old_buckets"] = pol.old_buckets.copy()
    return {"meta": meta, "arrays": arrays}


def state_dict(cache, journal_meta=None) -> Dict:
    """Point-in-time plain-data state of a cache.

    For a sharded service every shard lock is held while its shard is
    captured AND the facade scalars are read, so the snapshot is a
    crash-consistent cut: no access can interleave with the capture.

    ``journal_meta=(epoch, lsn)`` stamps the snapshot as a v2 journal
    *base*: the meta additionally records the write-ahead journal epoch
    and the last LSN folded into this state, so recovery knows exactly
    where journal replay must resume (``repro.faults.journal``).
    Without it the output is a plain v1 snapshot, byte-identical to what
    earlier readers pin.
    """
    d = _prod_state(cache) if not _is_sharded(cache) else None
    if d is not None:
        if journal_meta is not None:
            epoch, lsn = journal_meta
            d["meta"].update(version=VERSION, journal_epoch=int(epoch),
                             journal_lsn=int(lsn))
        return d
    meta = {"version": _V1, "kind": "sharded",
            "n_shards": cache.n_shards, "capacity": cache.capacity,
            "max_capacity": cache.max_capacity,
            "shard_max": cache.shard_max, "stride": cache.stride,
            "miss_mark": list(cache._miss_mark),
            "resizing": sorted(cache._resizing)}
    arrays: Dict[str, np.ndarray] = {}
    for i, (sh, lk) in enumerate(zip(cache.shards, cache.locks)):
        with lk:
            sub = _prod_state(sh)
        meta[f"s{i}"] = sub["meta"]
        for name, arr in sub["arrays"].items():
            arrays[f"s{i}/{name}"] = arr
    if journal_meta is not None:
        epoch, lsn = journal_meta
        meta.update(version=VERSION, journal_epoch=int(epoch),
                    journal_lsn=int(lsn))
    return {"meta": meta, "arrays": arrays}


# -- restore -------------------------------------------------------------------

def _load_prod(pol, meta: Dict, arrays: Dict[str, np.ndarray]) -> None:
    if (meta["max_small"], meta["max_main"], meta["max_ghost"]) != \
            (pol.max_small, pol.max_main, pol.max_ghost):
        raise ValueError(
            "snapshot preallocation (max_small/max_main/max_ghost="
            f"{meta['max_small']}/{meta['max_main']}/{meta['max_ghost']}) "
            f"does not match the target cache "
            f"({pol.max_small}/{pol.max_main}/{pol.max_ghost}); construct "
            "the target via policy_from_snapshot() for a cold restore")
    for name in _PROD_ARRAYS:
        src = arrays[name]
        dst = getattr(pol, name)
        if dst.shape == src.shape:
            np.copyto(dst, src)
        else:  # the resident hash array is re-sized by live resizes
            setattr(pol, name, src.copy())
    pol.free_blocks = arrays["free_blocks"].astype(np.int64).tolist()
    ob = arrays.get("old_buckets")
    pol.old_buckets = None if ob is None else ob.copy()
    for k in ("capacity", "small_cap", "main_cap", "ghost_cap", "window",
              "spos", "hand", "gpos", "small_seq", "n_buckets",
              "g_n_buckets", "old_n_buckets", "dirty_scan_limit",
              "track_io", "shard_id"):
        setattr(pol, k, meta[k])
    pol.skip_limit = meta["skip_limit"]
    pol._rehash_cursor = meta["rehash_cursor"]
    pol._small_frac = meta["small_frac"]
    pol._ghost_frac = meta["ghost_frac"]
    pol._window_frac = meta["window_frac"]
    g = pol._g_cap
    g["total"].value = float(pol.capacity)
    g["small"].value = float(pol.small_cap)
    g["main"].value = float(pol.main_cap)
    g["ghost"].value = float(pol.ghost_cap)
    g["window"].value = float(pol.window)


def load_state_dict(cache, d: Dict, step: int = -1) -> None:
    """Restore a ``state_dict`` into a compatibly-preallocated cache.

    The target must have the same preallocated maxima (and, for a
    sharded service, the same shard count) as the snapshot source;
    logical capacities, cursors, and every entry's residency state are
    overwritten wholesale.  Emits ``EV_RESTORE`` on the cache's sink.
    """
    meta = d["meta"]
    if meta.get("version", 0) > VERSION:
        raise ValueError(f"snapshot version {meta['version']} is newer "
                         f"than this reader (max {VERSION})")
    if _is_sharded(cache):
        if meta["kind"] != "sharded":
            raise ValueError("snapshot is not of a sharded cache")
        if meta["n_shards"] != cache.n_shards:
            raise ValueError(f"snapshot has {meta['n_shards']} shards, "
                             f"target has {cache.n_shards}")
        for i, (sh, lk) in enumerate(zip(cache.shards, cache.locks)):
            sub = {n[len(f"s{i}/"):]: a for n, a in d["arrays"].items()
                   if n.startswith(f"s{i}/")}
            with lk:
                _load_prod(sh, meta[f"s{i}"], sub)
        cache.capacity = meta["capacity"]
        cache._miss_mark = list(meta["miss_mark"])
        with cache._resize_lock:
            cache._resizing = set(meta["resizing"])
    else:
        if meta["kind"] != "prod":
            raise ValueError("snapshot is not of a single-instance cache")
        _load_prod(cache, meta, d["arrays"])
    obs = getattr(cache, "obs", None)
    if obs is not None and obs.ring.enabled:
        n = sum(len(s) for s in cache.shards) if _is_sharded(cache) \
            else len(cache)
        obs.emit(EV_RESTORE, a=step, b=n)


def policy_from_snapshot(d: Dict, obs=None):
    """Cold restore: construct a fresh ``ProdClock2QPlus`` shaped like
    the snapshot (same preallocated maxima), then load the state.
    ``obs`` overrides the new instance's sink (a ``NullSink`` keeps a
    replica mirror telemetry-free)."""
    from repro.core.prodcache import ProdClock2QPlus

    meta = d["meta"]
    if meta["kind"] != "prod":
        raise ValueError("policy_from_snapshot restores single instances; "
                         "build the sharded service and use "
                         "load_state_dict")
    mc = meta["max_capacity"]
    pol = ProdClock2QPlus(
        meta["capacity"], small_frac=meta["small_frac"],
        ghost_frac=meta["ghost_frac"], window_frac=meta["window_frac"],
        skip_limit=meta["skip_limit"],
        dirty_scan_limit=meta["dirty_scan_limit"], max_capacity=mc,
        track_io=bool(meta["track_io"]),
        max_small_frac=meta["max_small"] / mc,
        max_ghost_frac=meta["max_ghost"] / mc,
        min_small_frac=max(0.0, mc - meta["max_main"]) / mc,
        shard_id=meta["shard_id"], obs=obs)
    load_state_dict(pol, d)
    return pol


# -- the on-disk byte format (v1/v2) -------------------------------------------
#
#   offset  size  field
#        0     8  magic  b"C2QSNAP1"
#        8     4  u32 version (1 or 2), little-endian (as are all ints below)
#       12     4  u32 n_arrays
#       16     8  u64 meta_len
#       24     .  meta: canonical JSON (sorted keys, compact separators),
#                 UTF-8 — the scalar state + per-shard sub-metas
#        .     .  n_arrays sections, sorted by name:
#                   u32 name_len, name utf-8
#                   u32 dtype_len, numpy dtype str (little-endian codes)
#                   u32 ndim, ndim x u64 shape
#                   u64 data_len, raw C-order array bytes
#        .    20  sha1 of every preceding byte (corruption detection)
#
# Compat policy: readers accept version <= their own and must reject
# newer; adding scalars is a same-version change (readers ignore unknown
# meta keys), adding/renaming arrays or changing any encoding bumps the
# version.  tests/test_faults.py pins the layout byte-for-byte against
# tests/golden/c2qp_snapshot_v1.bin.
#
# v2 (journal bases): identical encoding; the meta additionally carries
# ``journal_epoch`` + ``journal_lsn`` — the write-ahead-journal position
# this state is a prefix fold of (``repro.faults.journal``).  Plain
# captures keep writing version 1, so the v1 golden stays byte-exact;
# tests/golden/c2qp_snapshot_v2.bin pins the v2 layout.

def _canon_meta(meta: Dict) -> bytes:
    return json.dumps(meta, sort_keys=True,
                      separators=(",", ":")).encode("utf-8")


def pack(d: Dict) -> bytes:
    """Serialize a ``state_dict`` to the versioned byte format (the
    header version field mirrors ``meta["version"]``: 1 for plain state,
    2 for journal-base snapshots carrying epoch/LSN meta).

    Fully deterministic: the same engine state always packs to the same
    bytes (canonical JSON meta, name-sorted little-endian arrays,
    trailing sha1) — which is what makes golden-file pinning and
    content-addressed snapshot dedup possible.
    """
    meta_b = _canon_meta(d["meta"])
    arrays = d["arrays"]
    version = int(d["meta"].get("version", VERSION))
    out = [MAGIC, struct.pack("<II", version, len(arrays)),
           struct.pack("<Q", len(meta_b)), meta_b]
    for name in sorted(arrays):
        arr = np.ascontiguousarray(arrays[name])
        if arr.dtype.byteorder == ">":  # normalize to little-endian
            arr = arr.astype(arr.dtype.newbyteorder("<"))
        nb = name.encode("utf-8")
        db = arr.dtype.str.encode("ascii")
        raw = arr.tobytes()
        out.append(struct.pack("<I", len(nb)) + nb)
        out.append(struct.pack("<I", len(db)) + db)
        out.append(struct.pack("<I", arr.ndim)
                   + struct.pack(f"<{arr.ndim}Q", *arr.shape))
        out.append(struct.pack("<Q", len(raw)) + raw)
    payload = b"".join(out)
    return payload + hashlib.sha1(payload).digest()


def unpack(buf: bytes) -> Dict:
    """Parse snapshot bytes (v1 or v2) back into a ``state_dict``
    (verifying the magic, version, and trailing digest)."""
    if len(buf) < len(MAGIC) + 36 or buf[:8] != MAGIC:
        raise ValueError("not a Clock2Q+ snapshot (bad magic)")
    payload, digest = buf[:-20], buf[-20:]
    if hashlib.sha1(payload).digest() != digest:
        raise IOError("snapshot corrupt: digest mismatch")
    version, n_arrays = struct.unpack_from("<II", buf, 8)
    if version > VERSION:
        raise ValueError(f"snapshot version {version} is newer than this "
                         f"reader (max {VERSION})")
    (meta_len,) = struct.unpack_from("<Q", buf, 16)
    off = 24
    meta = json.loads(buf[off:off + meta_len].decode("utf-8"))
    off += meta_len
    arrays: Dict[str, np.ndarray] = {}
    for _ in range(n_arrays):
        (nl,) = struct.unpack_from("<I", buf, off)
        off += 4
        name = buf[off:off + nl].decode("utf-8")
        off += nl
        (dl,) = struct.unpack_from("<I", buf, off)
        off += 4
        dtype = np.dtype(buf[off:off + dl].decode("ascii"))
        off += dl
        (ndim,) = struct.unpack_from("<I", buf, off)
        off += 4
        shape = struct.unpack_from(f"<{ndim}Q", buf, off)
        off += 8 * ndim
        (raw_len,) = struct.unpack_from("<Q", buf, off)
        off += 8
        arrays[name] = np.frombuffer(
            buf[off:off + raw_len], dtype=dtype).reshape(shape).copy()
        off += raw_len
    return {"meta": meta, "arrays": arrays}


def _fsync_dir(path: str) -> None:
    """fsync a directory so a rename/create inside it is itself durable
    (a crashed host may otherwise forget the rename even though the file
    contents were fsync'd).  No-op where directories can't be opened."""
    try:
        fd = os.open(path or ".", os.O_RDONLY)
    except OSError:
        return  # e.g. non-POSIX: directory fsync unsupported
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _atomic_write(path: str, buf: bytes) -> None:
    """Write ``buf`` to ``path`` crash-durably: temp file + fsync +
    rename + parent-directory fsync (the rename itself must survive a
    crash, not just the bytes)."""
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "wb") as f:
        f.write(buf)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    _fsync_dir(os.path.dirname(path))


def write_snapshot(path: str, cache, journal_meta=None) -> bytes:
    """Capture ``cache`` and atomically write the packed snapshot to
    ``path`` (write-to-temp + fsync + rename + directory fsync: a crash
    mid-write never leaves a torn snapshot where a restore might find
    it, and the rename itself is durable).  ``journal_meta=(epoch,
    lsn)`` writes a v2 journal-base snapshot.  Returns the bytes."""
    buf = pack(state_dict(cache, journal_meta=journal_meta))
    _atomic_write(path, buf)
    return buf


def read_snapshot(path: str) -> Dict:
    """Read + verify a packed snapshot file into a ``state_dict``."""
    with open(path, "rb") as f:
        return unpack(f.read())


# -- retention-managed store (on checkpoint/ckpt.py) ---------------------------

class SnapshotManager:
    """Periodic engine snapshots with retention, built on
    ``repro.checkpoint.ckpt.CheckpointManager``.

    Each ``save`` commits the snapshot as a checkpoint step: arrays are
    the pytree leaves (one digest-verified ``.npy`` each), the scalar
    meta rides as a packed uint8 leaf, and CheckpointManager supplies
    the atomic manifest commit, retention of the last ``keep`` steps,
    and per-leaf corruption detection.  The checkpoint import is lazy so
    ``repro.faults`` stays importable without JAX.
    """

    def __init__(self, directory: str, keep: int = 3):
        from repro.checkpoint.ckpt import CheckpointManager

        self._mgr = CheckpointManager(directory, keep=keep)

    def save(self, cache, step: int) -> None:
        """Snapshot ``cache`` and commit it as checkpoint ``step``."""
        d = state_dict(cache)
        tree = {f"a/{n}": a for n, a in d["arrays"].items()}
        tree["meta"] = np.frombuffer(_canon_meta(d["meta"]),
                                     dtype=np.uint8).copy()
        self._mgr.save(step, tree, blocking=True)

    def steps(self):
        """Committed snapshot steps, oldest first."""
        return self._mgr.all_steps()

    def latest_step(self) -> Optional[int]:
        """Newest committed snapshot step, or None."""
        return self._mgr.latest_step()

    def load(self, step: Optional[int] = None,
             verify: bool = True) -> Dict:
        """Read a committed snapshot back into a ``state_dict``."""
        if step is None:
            step = self._mgr.latest_step()
        if step is None:
            raise FileNotFoundError(f"no snapshots in {self._mgr.dir}")
        d = self._mgr.dir / f"step_{step:08d}"
        with open(d / "manifest.json") as f:
            manifest = json.load(f)
        like = {path: np.zeros(m["shape"],
                               dtype=np.dtype(m["dtype"]))
                for path, m in manifest["leaves"].items()}
        tree = self._mgr.restore(step, like, verify=verify)
        meta = json.loads(bytes(tree.pop("meta")).decode("utf-8"))
        arrays = {n[len("a/"):]: a for n, a in tree.items()}
        return {"meta": meta, "arrays": arrays}

    def restore(self, cache, step: Optional[int] = None,
                verify: bool = True) -> int:
        """Restore the latest (or a specific) snapshot into ``cache``;
        returns the step restored."""
        if step is None:
            step = self._mgr.latest_step()
            if step is None:
                raise FileNotFoundError(f"no snapshots in {self._mgr.dir}")
        load_state_dict(cache, self.load(step, verify=verify), step=step)
        return step
