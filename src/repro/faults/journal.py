"""Append-only write-ahead delta journal for one Clock2Q+ shard.

The journal records the *inputs* of every state-mutating policy call
(access / io_done / unpin / clean / set_dirty / retune / begin_resize /
resize_step) as compact fixed-size binary records with a monotonic LSN
and a CRC32 per record.  Because the Clock2Q+ engine is deterministic —
same starting state + same call sequence = bit-identical arrays — a
*physiological* log of the call stream is enough to reconstruct a shard
exactly: replaying the journal on top of its base snapshot yields the
pre-crash state up to the last durable record.  Access records carry the
observed outcome (hit / evicted key / block / bypass) purely as a
cross-check: replay verifies them and raises ``ReplayDivergence`` if the
engine ever disagrees with the log, instead of silently rebuilding a
different cache.

On-disk layout (one directory per shard):

  ``base-EEEEEEEE-LLLLLLLLLLLL.c2qsnap``  — snapshot v2 (journal base):
      the state with every record up to LSN L of epoch E folded in
      (``repro.faults.snapshot`` format; meta carries journal_epoch /
      journal_lsn).
  ``seg-EEEEEEEE-LLLLLLLLLLLL.c2qj``      — a journal segment: a 36-byte
      CRC-guarded header (magic ``C2QJSEG1``, version, shard id, epoch,
      start LSN) followed by consecutive 38-byte records.

Records are 38 bytes: ``<QBBqqq`` body (lsn u64, op u8, flags u8, three
i64 payload words) + u32 CRC32 of the body.  A torn tail — a record cut
mid-write by a crash — fails its CRC (or is short), and ``recover``
truncates the file back to the last whole record rather than applying
garbage; crash-point fuzzing in ``tests/test_recovery_fuzz.py`` kills
the writer at every record boundary and at random intra-record byte
offsets to prove it.

Epochs number shard incarnations: a promote/reattach bumps the epoch and
starts a fresh base + segment chain (LSNs restart at 0 per epoch), so a
recovering reader always picks the newest base by (epoch, lsn) and
replays only that epoch's segments.  ``compact()`` folds all sealed
segments into a new base and deletes them, bounding replay length.

Durability: ``sync_every=N`` fsyncs every N records; segment rotation
and ``close``/``sync`` always fsync (file then directory, the same
rename-barrier discipline ``write_snapshot`` uses).  ``directory=None``
keeps everything in process memory — zero-IO journaling for hot-standby
replication (``repro.faults.replica``) and for tests.
"""

from __future__ import annotations

import dataclasses
import glob
import os
import struct
import zlib
from collections import deque
from typing import List, NamedTuple, Optional

from repro.faults.plan import CRASH, OP_JOURNAL_APPEND
from repro.faults.snapshot import (
    _atomic_write, _fsync_dir, pack, policy_from_snapshot, state_dict,
    unpack,
)
from repro.obs.events import EV_JOURNAL_TRUNCATED
from repro.obs.export import NullSink

# -- record encoding -----------------------------------------------------------

# journal op codes (the u8 `op` field)
J_ACCESS = 1       # p0=key, p1=evicted_key, p2=block; flags carry the rest
J_IO_DONE = 2      # p0=key
J_UNPIN = 3        # p0=key
J_CLEAN = 4        # p0=key
J_SET_DIRTY = 5    # p0=key
J_RETUNE = 6       # p0/p1/p2 = float64 bit patterns of the absolute
                   # post-retune small/ghost/window fractions
J_RESIZE = 7       # p0=new_capacity (begin_resize)
J_RESIZE_STEP = 8  # p0=n_entries

# J_ACCESS flag bits: inputs (dirty/pin) and observed outcomes (hit/
# bypass) — outcomes exist so replay can detect divergence, not to
# steer it
JF_DIRTY = 1
JF_PIN = 2
JF_HIT = 4
JF_BYPASS = 8

_BODY = "<QBBqqq"                       # lsn, op, flags, p0, p1, p2
_BODY_SIZE = struct.calcsize(_BODY)     # 34
RECORD_SIZE = _BODY_SIZE + 4            # + u32 crc32(body) = 38

SEG_MAGIC = b"C2QJSEG1"
SEG_VERSION = 1
_SEG_HDR = "<8sIIQQI"                   # magic, version, shard, epoch,
_SEG_HDR_SIZE = struct.calcsize(_SEG_HDR)  # start_lsn, crc = 36 bytes


class JRecord(NamedTuple):
    """One decoded journal record."""

    lsn: int
    op: int
    flags: int
    p0: int
    p1: int
    p2: int


class JournalCrash(RuntimeError):
    """The (simulated) process died mid-append — raised by a CRASH
    ``FaultSpec`` targeting ``OP_JOURNAL_APPEND``; whatever prefix of the
    record the spec's ``ticks`` allowed is already flushed to disk."""


class ReplayDivergence(RuntimeError):
    """Journal replay produced a different outcome than the log recorded
    (hit/miss, victim, or block mismatch) — the recovered state cannot be
    trusted and recovery must fall back to the base snapshot."""


def _f_bits(f: float) -> int:
    return struct.unpack("<q", struct.pack("<d", float(f)))[0]


def _bits_f(b: int) -> float:
    return struct.unpack("<d", struct.pack("<q", int(b)))[0]


def encode_record(lsn: int, op: int, flags: int = 0, p0: int = 0,
                  p1: int = 0, p2: int = 0) -> bytes:
    """Serialize one record: 34-byte body + CRC32 trailer (38 bytes)."""
    body = struct.pack(_BODY, lsn, op, flags, p0, p1, p2)
    return body + struct.pack("<I", zlib.crc32(body) & 0xFFFFFFFF)


def decode_record(buf: bytes, off: int = 0) -> Optional[JRecord]:
    """Decode the record at ``off``; None when short or CRC-corrupt
    (a torn tail, never an exception — torn tails are expected)."""
    if off + RECORD_SIZE > len(buf):
        return None
    body = buf[off:off + _BODY_SIZE]
    (crc,) = struct.unpack_from("<I", buf, off + _BODY_SIZE)
    if crc != (zlib.crc32(body) & 0xFFFFFFFF):
        return None
    return JRecord(*struct.unpack(_BODY, body))


def _seg_header(shard_id: int, epoch: int, start_lsn: int) -> bytes:
    head = struct.pack("<8sIIQQ", SEG_MAGIC, SEG_VERSION, shard_id, epoch,
                       start_lsn)
    return head + struct.pack("<I", zlib.crc32(head) & 0xFFFFFFFF)


def _parse_seg_header(buf: bytes):
    """(shard_id, epoch, start_lsn) or None if the header is torn."""
    if len(buf) < _SEG_HDR_SIZE:
        return None
    magic, ver, shard, epoch, start, crc = struct.unpack_from(_SEG_HDR, buf)
    if magic != SEG_MAGIC or ver != SEG_VERSION:
        return None
    if crc != (zlib.crc32(buf[:_SEG_HDR_SIZE - 4]) & 0xFFFFFFFF):
        return None
    return shard, epoch, start


def _decode_segment(buf: bytes):
    """Decode a segment buffer into (records, good_end, header).

    ``good_end`` is the byte offset of the last whole valid record —
    everything past it is a torn tail (or, when the header itself is
    torn, 0: the whole file is garbage).  Decoding stops at the first
    short / CRC-failed / LSN-discontinuous record.
    """
    hdr = _parse_seg_header(buf)
    if hdr is None:
        return [], 0, None
    _, _, start_lsn = hdr
    recs: List[JRecord] = []
    off = _SEG_HDR_SIZE
    expect = start_lsn
    while True:
        rec = decode_record(buf, off)
        if rec is None or rec.lsn != expect:
            break
        recs.append(rec)
        off += RECORD_SIZE
        expect += 1
    return recs, off, hdr


# -- the journal ---------------------------------------------------------------

class ShardJournal:
    """Append-only WAL for one shard (see module docstring).

    ``directory=None`` journals to process memory (hot-standby feed);
    a path journals to ``base-*/seg-*`` files with fsync barriers.
    ``segment_records`` bounds segment length (rotation point),
    ``sync_every=N`` fsyncs every N appends (0 = only on rotate/close),
    ``plan`` is an optional ``FaultPlan`` whose CRASH specs (targeting
    ``OP_JOURNAL_APPEND``) kill the writer mid-record, and ``tail_cap``
    bounds the decoded in-memory tail serving ``records_since``.
    """

    def __init__(self, directory: Optional[str] = None, shard_id: int = 0,
                 *, epoch: int = 0, segment_records: int = 4096,
                 sync_every: int = 0, plan=None, tail_cap: int = 65536):
        if segment_records < 1:
            raise ValueError("segment_records must be >= 1")
        self.directory = directory
        self.shard_id = int(shard_id)
        self.epoch = int(epoch)
        self.segment_records = int(segment_records)
        self.sync_every = int(sync_every)
        self.plan = plan
        self._lsn = 0          # last assigned LSN (0 = nothing journaled)
        self._durable = 0      # last LSN known flushed+fsynced
        self._base_lsn = 0
        self._base_bytes: Optional[bytes] = None
        self._base_path: Optional[str] = None
        self._tail: deque = deque(maxlen=int(tail_cap))
        self._seg_count = 0    # records in the current segment
        self._seg_start = 1
        self._f = None                       # dir mode: open segment file
        self._seg_paths: List[str] = []      # dir mode: sealed + current
        self._segments: List[bytearray] = []  # memory mode
        self._closed = False
        if directory is not None:
            os.makedirs(directory, exist_ok=True)

    # -- positions ------------------------------------------------------------
    @property
    def lsn(self) -> int:
        """Last assigned LSN (the newest record, durable or not)."""
        return self._lsn

    @property
    def durable_lsn(self) -> int:
        """Last LSN guaranteed on stable storage (== ``lsn`` in memory
        mode, where there is no volatile page cache to lose)."""
        return self._durable

    @property
    def base_lsn(self) -> int:
        """LSN already folded into the base snapshot."""
        return self._base_lsn

    # -- lifecycle ------------------------------------------------------------
    def attach(self, pol) -> "ShardJournal":
        """Write the base snapshot of ``pol`` and start journaling its
        mutations (sets ``pol._journal``).  Returns self."""
        self._write_base(pol)
        self._open_segment(self._lsn + 1)
        pol._journal = self
        return self

    def _write_base(self, pol) -> None:
        buf = pack(state_dict(pol, journal_meta=(self.epoch, self._lsn)))
        self._base_bytes = buf
        self._base_lsn = self._lsn
        if self.directory is not None:
            path = os.path.join(
                self.directory,
                f"base-{self.epoch:08d}-{self._lsn:012d}.c2qsnap")
            _atomic_write(path, buf)
            old = self._base_path
            self._base_path = path
            if old is not None and old != path and os.path.exists(old):
                os.unlink(old)
                _fsync_dir(self.directory)

    def _open_segment(self, start_lsn: int) -> None:
        hdr = _seg_header(self.shard_id, self.epoch, start_lsn)
        self._seg_start = start_lsn
        self._seg_count = 0
        if self.directory is None:
            self._segments.append(bytearray(hdr))
            return
        path = os.path.join(
            self.directory, f"seg-{self.epoch:08d}-{start_lsn:012d}.c2qj")
        self._f = open(path, "wb")
        self._f.write(hdr)
        self._f.flush()
        self._seg_paths.append(path)

    def sync(self) -> None:
        """Flush + fsync the current segment (durability barrier)."""
        if self._f is not None:
            self._f.flush()
            os.fsync(self._f.fileno())
        self._durable = self._lsn

    def close(self) -> None:
        """Seal the journal: fsync the open segment (and its directory)
        and stop accepting appends."""
        if self._closed:
            return
        if self._f is not None:
            self.sync()
            self._f.close()
            self._f = None
            _fsync_dir(self.directory)
        self._durable = self._lsn
        self._closed = True

    def _rotate(self) -> None:
        if self._f is not None:
            self.sync()
            self._f.close()
            self._f = None
            _fsync_dir(self.directory)
        self._durable = self._lsn
        self._open_segment(self._lsn + 1)

    # -- the append hot path --------------------------------------------------
    def _write(self, data: bytes) -> None:
        if self._f is not None:
            self._f.write(data)
        else:
            self._segments[-1] += data

    def append(self, op: int, flags: int = 0, p0: int = 0, p1: int = 0,
               p2: int = 0) -> int:
        """Append one record; returns its LSN.  A CRASH fault on the
        plan's ``journal_append`` stream flushes a record *prefix*
        (``ticks`` bytes) and raises ``JournalCrash`` — the torn tail the
        recovery fuzzer then has to detect."""
        if self._closed:
            raise ValueError("journal is closed")
        lsn = self._lsn + 1
        rec = encode_record(lsn, op, flags, p0, p1, p2)
        plan = self.plan
        if plan is not None and plan.enabled:
            f = plan.next_op(OP_JOURNAL_APPEND)
            if f is not None and f.kind == CRASH:
                cut = max(0, min(RECORD_SIZE, int(f.ticks)))
                self._write(rec[:cut])
                if self._f is not None:
                    self._f.flush()
                    os.fsync(self._f.fileno())
                self._closed = True
                raise JournalCrash(
                    f"journal writer killed mid-append: lsn {lsn}, "
                    f"{cut}/{RECORD_SIZE} bytes reached disk")
        self._write(rec)
        self._lsn = lsn
        self._seg_count += 1
        self._tail.append(JRecord(lsn, op, flags, p0, p1, p2))
        if self._f is None:
            self._durable = lsn
        elif self.sync_every and self._seg_count % self.sync_every == 0:
            self.sync()
        if self._seg_count >= self.segment_records:
            self._rotate()
        return lsn

    # -- policy-facing hooks (duck-typed from ProdClock2QPlus._journal) -------
    def on_access(self, key: int, dirty: bool, pin: bool, r) -> None:
        """Journal one ``access`` with its observed outcome flags."""
        flags = ((JF_DIRTY if dirty else 0) | (JF_PIN if pin else 0)
                 | (JF_HIT if r.hit else 0)
                 | (JF_BYPASS if r.bypassed_to_main else 0))
        self.append(J_ACCESS, flags, int(key), int(r.evicted_key),
                    int(r.block))

    def on_io_done(self, key: int) -> None:
        """Journal an ``io_done``."""
        self.append(J_IO_DONE, 0, int(key))

    def on_unpin(self, key: int) -> None:
        """Journal an ``unpin``."""
        self.append(J_UNPIN, 0, int(key))

    def on_clean(self, key: int) -> None:
        """Journal a ``clean``."""
        self.append(J_CLEAN, 0, int(key))

    def on_set_dirty(self, key: int) -> None:
        """Journal a ``set_dirty``."""
        self.append(J_SET_DIRTY, 0, int(key))

    def on_retune(self, small_frac: float, ghost_frac: float,
                  window_frac: float) -> None:
        """Journal a ``retune`` as ONE record of absolute post-values
        (the retune's internal ``begin_resize`` is suppressed)."""
        self.append(J_RETUNE, 0, _f_bits(small_frac), _f_bits(ghost_frac),
                    _f_bits(window_frac))

    def on_resize(self, new_capacity: int) -> None:
        """Journal a direct ``begin_resize``."""
        self.append(J_RESIZE, 0, int(new_capacity))

    def on_resize_step(self, n_entries: int) -> None:
        """Journal a ``resize_step`` drive."""
        self.append(J_RESIZE_STEP, 0, int(n_entries))

    # -- readers --------------------------------------------------------------
    def base_state(self):
        """The base snapshot as a ``state_dict`` (fresh unpack — callers
        may mutate the result freely)."""
        if self._base_bytes is None:
            raise ValueError("journal has no base (attach() not called)")
        return unpack(self._base_bytes)

    def records_since(self, from_lsn: int) -> List[JRecord]:
        """All records with ``lsn > from_lsn``, in order.  Served from
        the decoded in-memory tail when it reaches back far enough,
        otherwise re-decoded from the segment store."""
        if from_lsn >= self._lsn:
            return []
        if self._tail and self._tail[0].lsn <= from_lsn + 1:
            return [r for r in self._tail if r.lsn > from_lsn]
        return self._scan(from_lsn)

    def _segment_buffers(self) -> List[bytes]:
        if self.directory is None:
            return [bytes(s) for s in self._segments]
        if self._f is not None:
            self._f.flush()
        out = []
        for path in self._seg_paths:
            with open(path, "rb") as f:
                out.append(f.read())
        return out

    def _scan(self, from_lsn: int) -> List[JRecord]:
        out: List[JRecord] = []
        for buf in self._segment_buffers():
            recs, _, hdr = _decode_segment(buf)
            if hdr is None:
                continue
            out.extend(r for r in recs if r.lsn > from_lsn)
        return out

    # -- compaction -----------------------------------------------------------
    def compact(self) -> int:
        """Fold every *sealed* segment into a fresh base snapshot and
        delete them (replay-length bound).  The open segment is left
        alone.  Returns the number of records folded."""
        n_sealed = (len(self._seg_paths) if self.directory is not None
                    else len(self._segments)) - 1
        if n_sealed < 1:
            return 0
        bufs = self._segment_buffers()[:n_sealed]
        mirror = policy_from_snapshot(self.base_state(), obs=NullSink())
        folded = 0
        for buf in bufs:
            recs, _, hdr = _decode_segment(buf)
            if hdr is None:
                raise ValueError("sealed journal segment has a torn header")
            for rec in recs:
                if rec.lsn <= self._base_lsn:
                    continue
                apply_record(mirror, rec)
                self._base_lsn = rec.lsn
                folded += 1
        self._base_bytes = pack(
            state_dict(mirror, journal_meta=(self.epoch, self._base_lsn)))
        if self.directory is not None:
            path = os.path.join(
                self.directory,
                f"base-{self.epoch:08d}-{self._base_lsn:012d}.c2qsnap")
            _atomic_write(path, self._base_bytes)
            old = self._base_path
            self._base_path = path
            for sealed in self._seg_paths[:n_sealed]:
                os.unlink(sealed)
            del self._seg_paths[:n_sealed]
            if old is not None and old != path and os.path.exists(old):
                os.unlink(old)
            _fsync_dir(self.directory)
        else:
            del self._segments[:n_sealed]
        return folded


# -- replay --------------------------------------------------------------------

def apply_record(pol, rec: JRecord, verify: bool = True) -> None:
    """Apply one journal record to a policy instance.

    ``verify=True`` cross-checks J_ACCESS outcomes (hit, victim, block,
    bypass) against what the log recorded and raises
    ``ReplayDivergence`` on any mismatch — replay must reproduce the
    original run bit-exactly or fail loudly, never silently drift.
    """
    op = rec.op
    if op == J_ACCESS:
        r = pol.access(rec.p0, dirty=bool(rec.flags & JF_DIRTY),
                       pin=bool(rec.flags & JF_PIN))
        if verify:
            hit = bool(rec.flags & JF_HIT)
            if (r.hit != hit
                    or r.bypassed_to_main != bool(rec.flags & JF_BYPASS)
                    or int(r.block) != rec.p2
                    or (not hit and int(r.evicted_key) != rec.p1)):
                raise ReplayDivergence(
                    f"replay of lsn {rec.lsn} (access key {rec.p0}) "
                    f"diverged: got hit={r.hit} block={int(r.block)} "
                    f"evicted={int(r.evicted_key)} "
                    f"bypass={r.bypassed_to_main}, journal says "
                    f"hit={hit} block={rec.p2} evicted={rec.p1} "
                    f"bypass={bool(rec.flags & JF_BYPASS)}")
    elif op == J_IO_DONE:
        pol.io_done(rec.p0)
    elif op == J_UNPIN:
        pol.unpin(rec.p0)
    elif op == J_CLEAN:
        pol.clean(rec.p0)
    elif op == J_SET_DIRTY:
        pol.set_dirty(rec.p0)
    elif op == J_RETUNE:
        pol.retune(small_frac=_bits_f(rec.p0), ghost_frac=_bits_f(rec.p1),
                   window_frac=_bits_f(rec.p2))
    elif op == J_RESIZE:
        pol.begin_resize(rec.p0)
    elif op == J_RESIZE_STEP:
        pol.resize_step(rec.p0)
    else:
        raise ReplayDivergence(f"unknown journal op {op} at lsn {rec.lsn}")


@dataclasses.dataclass
class RecoveryResult:
    """What ``recover`` reconstructed from a journal directory."""

    policy: object          # the recovered ProdClock2QPlus
    epoch: int              # journal epoch recovered
    lsn: int                # last durable LSN applied
    applied: int            # records replayed past the base
    truncated_bytes: int    # torn-tail bytes cut (0 = clean shutdown)


def recover(directory: str, *, obs=None, verify: bool = True,
            truncate: bool = True) -> RecoveryResult:
    """Rebuild a shard from its journal directory.

    Picks the newest base snapshot by (epoch, lsn), replays that epoch's
    segments in LSN order, stops at the first torn record (short bytes /
    CRC failure / LSN discontinuity) and — with ``truncate=True`` —
    physically truncates the torn tail off the segment file and emits
    ``EV_JOURNAL_TRUNCATED`` on ``obs``.  A torn record is NEVER
    applied; the recovered state is bit-exact at the last durable LSN.
    """
    bases = glob.glob(os.path.join(directory, "base-*.c2qsnap"))
    if not bases:
        raise FileNotFoundError(f"no journal base snapshot in {directory}")

    def _base_key(p: str):
        stem = os.path.basename(p)[len("base-"):-len(".c2qsnap")]
        e, l = stem.split("-")
        return int(e), int(l)

    base_path = max(bases, key=_base_key)
    with open(base_path, "rb") as f:
        d = unpack(f.read())
    pol = policy_from_snapshot(d, obs=obs)
    epoch = int(d["meta"].get("journal_epoch", 0))
    applied_lsn = int(d["meta"].get("journal_lsn", 0))
    applied = 0
    torn = 0

    def _seg_key(p: str):
        stem = os.path.basename(p)[len("seg-"):-len(".c2qj")]
        _, start = stem.split("-")
        return int(start)

    segs = sorted(glob.glob(
        os.path.join(directory, f"seg-{epoch:08d}-*.c2qj")), key=_seg_key)
    for path in segs:
        with open(path, "rb") as f:
            buf = f.read()
        recs, good_end, hdr = _decode_segment(buf)
        for rec in recs:
            if rec.lsn <= applied_lsn:
                continue
            if rec.lsn != applied_lsn + 1:  # gap: a segment is missing
                good_end = _SEG_HDR_SIZE if hdr is not None else 0
                recs = []
                break
            apply_record(pol, rec, verify=verify)
            applied_lsn = rec.lsn
            applied += 1
        if good_end < len(buf):  # torn tail (or torn header: good_end=0)
            torn = len(buf) - good_end
            if truncate:
                os.truncate(path, good_end)
                _fsync_dir(directory)
            if obs is not None and obs.ring.enabled:
                obs.emit(EV_JOURNAL_TRUNCATED, shard=pol.shard_id,
                         a=applied_lsn, b=torn)
            break  # nothing after a torn tail is trustworthy
    return RecoveryResult(policy=pol, epoch=epoch, lsn=applied_lsn,
                          applied=applied, truncated_bytes=torn)
