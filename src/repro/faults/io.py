"""Host-block IO hardening: retries, backoff, deadlines, degraded mode.

This is the *recovery* half that IO faults demand.  Every host-tier
block copy (swap-in / swap-out) runs through ``HostIO.run``, which

  * consults the ``FaultPlan`` for an injected decision,
  * retries transient ``IO_ERROR`` with exponential backoff up to
    ``RetryPolicy.max_retries`` attempts, abandoning the op when the
    accumulated virtual time would blow the per-op ``deadline_ticks``,
  * serves ``IO_DELAY`` spikes by advancing the clock (never a real
    ``time.sleep`` — the chaos suite must be fast and deterministic),
  * feeds a ``CircuitBreaker`` that sheds the pool to read-through mode
    under sustained failure and probes its way back to healthy,
  * emits one typed obs event per injected fault / retry / giveup /
    degraded-mode flip, so ``tools/obsreport.py --incidents`` can render
    the incident timeline from the ring alone.

Time is virtual: a ``Clock`` counts ticks.  Backoff "sleeps" advance the
clock, making deadline math exact and replay bit-identical across runs.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.obs import (
    EV_DEGRADED, EV_FAULT, EV_IO_ERROR, EV_IO_RETRY, NullSink,
)
from repro.faults.plan import (
    IO_DELAY, IO_ERROR, PARTIAL_WRITE, SHARD_LOSS, FaultPlan, NullPlan,
)


class Clock:
    """Virtual monotonic clock: integer ticks, advanced explicitly.

    One tick is "one backoff quantum" — wall-clock-free so fault replays
    are deterministic and tests never sleep."""

    def __init__(self):
        self.now = 0

    def advance(self, ticks: int) -> None:
        """Advance time by ``ticks`` (the virtual sleep)."""
        self.now += int(ticks)


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Retry/backoff/deadline knobs for one host-block IO operation.

    ``backoff(attempt)`` returns ``base_backoff * factor**attempt``
    capped at ``max_backoff`` — classic bounded exponential backoff.
    ``deadline_ticks`` bounds the total virtual time (delays + backoffs)
    one logical op may consume before it is abandoned.
    """

    max_retries: int = 3
    base_backoff: int = 1
    factor: int = 2
    max_backoff: int = 64
    deadline_ticks: int = 256

    def backoff(self, attempt: int) -> int:
        """Backoff ticks before retry number ``attempt`` (0-based)."""
        return min(self.max_backoff,
                   self.base_backoff * self.factor ** attempt)


class CircuitBreaker:
    """Sheds host IO under sustained failure (degraded read-through).

    Closed (healthy) -> ``threshold`` consecutive failed ops open it ->
    while open, every host swap is skipped outright (the pool serves
    read-through: misses fill from the origin, evictions drop) -> after
    ``probe_after`` skipped ops one probe op is let through; success
    closes the breaker, failure re-opens it.  State flips emit
    ``EV_DEGRADED`` (a=1 enter, a=0 exit).
    """

    def __init__(self, threshold: int = 8, probe_after: int = 64,
                 obs=None):
        self.threshold = threshold
        self.probe_after = probe_after
        self.obs = NullSink(src="breaker") if obs is None else obs
        self.consecutive_failures = 0
        self.open = False
        self._skipped = 0
        self.trips = 0

    def allow(self) -> bool:
        """Should this op attempt real IO?  False = shed (degraded)."""
        if not self.open:
            return True
        self._skipped += 1
        if self._skipped >= self.probe_after:
            self._skipped = 0
            return True  # half-open probe
        return False

    def record(self, ok: bool) -> None:
        """Feed one op outcome; may flip degraded mode."""
        if ok:
            self.consecutive_failures = 0
            if self.open:
                self.open = False
                if self.obs.ring.enabled:
                    self.obs.emit(EV_DEGRADED, a=0)
            return
        self.consecutive_failures += 1
        if not self.open and self.consecutive_failures >= self.threshold:
            self.open = True
            self.trips += 1
            self._skipped = 0
            if self.obs.ring.enabled:
                self.obs.emit(EV_DEGRADED, a=1)


@dataclasses.dataclass
class IOResult:
    """Outcome of one hardened host-block IO operation."""

    ok: bool
    attempts: int = 1
    ticks: int = 0        # virtual time consumed (delays + backoffs)
    corrupt: bool = False  # PARTIAL_WRITE fired: payload is torn
    shed: bool = False     # breaker open: IO skipped, not attempted


class HostIO:
    """The hardened host-block IO path (fault check + retry + breaker).

    ``run(op, key, fn)`` executes ``fn`` under the plan's decisions for
    sequential op numbers.  ``fn`` is the actual copy (or None for a
    pure simulation); injected IO_ERROR faults consume an attempt and
    are retried with backoff until success, ``max_retries`` exhausted,
    or the deadline is blown.
    """

    def __init__(self, plan: Optional[FaultPlan] = None,
                 retry: Optional[RetryPolicy] = None,
                 breaker: Optional[CircuitBreaker] = None,
                 clock: Optional[Clock] = None, obs=None):
        self.plan = NullPlan() if plan is None else plan
        self.retry = RetryPolicy() if retry is None else retry
        self.obs = NullSink(src="hostio") if obs is None else obs
        self.breaker = CircuitBreaker(obs=self.obs) if breaker is None \
            else breaker
        self.clock = Clock() if clock is None else clock
        self._c_fault = self.obs.counter(
            "io_faults_injected_total", ("kind",),
            "faults the plan injected, by kind")
        self._c_retry = self.obs.counter(
            "io_retries_total", (), "host-IO retry attempts").labels()
        self._c_error = self.obs.counter(
            "io_errors_total", ("op",),
            "host-IO ops abandoned (retries/deadline exhausted)")
        self._c_shed = self.obs.counter(
            "io_shed_total", (), "ops skipped while degraded "
            "(read-through)").labels()
        self._h_ticks = self.obs.histogram(
            "io_op_ticks", (), "virtual ticks consumed per op "
            "(delays + backoffs)", base=1.0, n_buckets=16)
        # SHARD_LOSS faults are not IO outcomes: the op they fired on
        # proceeds normally and the fault queues here for the owner (the
        # pool drains it into recovery.failover at its next lookup)
        self.pending_shard_loss = []

    @property
    def degraded(self) -> bool:
        """True while the breaker has shed the pool to read-through."""
        return self.breaker.open

    def run(self, op: str, key: int,
            fn: Optional[Callable[[], None]] = None) -> IOResult:
        """Execute one host-block IO op under the fault plan.

        Returns an ``IOResult``; ``fn`` (the real copy) runs exactly
        once, and only when the op ultimately succeeds — a faulted
        attempt never half-applies the copy (crash consistency at the
        op level; PARTIAL_WRITE models the torn-write case explicitly
        via ``corrupt=True``, and the caller quarantines the copy).
        """
        if not self.breaker.allow():
            self._c_shed.value += 1
            return IOResult(ok=False, attempts=0, shed=True)
        ticks = 0
        attempt = 0
        while True:
            fault = self.plan.next_op(op)
            if fault is not None:
                self._c_fault.labels(fault.name).value += 1
                if self.obs.ring.enabled:
                    self.obs.emit(EV_FAULT, a=fault.kind, b=fault.op_seq)
            if fault is not None and fault.kind == SHARD_LOSS:
                self.pending_shard_loss.append(fault)
                fault = None  # the IO op itself is unaffected
            if fault is not None and fault.kind == IO_DELAY:
                ticks += fault.ticks
                self.clock.advance(fault.ticks)
                if ticks > self.retry.deadline_ticks:
                    # the spike blew the per-op deadline: handled as a
                    # retryable error from here on
                    fault = dataclasses.replace(fault, kind=IO_ERROR)
                else:
                    fault = None  # delayed but healthy: proceed below
            if fault is None:
                if fn is not None:
                    fn()
                self.breaker.record(True)
                self._h_ticks.labels().observe(float(ticks))
                return IOResult(ok=True, attempts=attempt + 1, ticks=ticks)
            if fault.kind == PARTIAL_WRITE:
                # the write "succeeds" but the payload is torn; the
                # caller stores the quarantine bit and detection happens
                # on the next read (digest mismatch path)
                if fn is not None:
                    fn()
                self.breaker.record(True)
                self._h_ticks.labels().observe(float(ticks))
                return IOResult(ok=True, attempts=attempt + 1, ticks=ticks,
                                corrupt=True)
            # IO_ERROR (or a deadline-blown delay): retry with backoff
            backoff = self.retry.backoff(attempt)
            attempt += 1
            if attempt > self.retry.max_retries or \
                    ticks + backoff > self.retry.deadline_ticks:
                self._c_error.labels(op).value += 1
                if self.obs.ring.enabled:
                    self.obs.emit(EV_IO_ERROR, a=key, b=attempt)
                self.breaker.record(False)
                self._h_ticks.labels().observe(float(ticks))
                return IOResult(ok=False, attempts=attempt, ticks=ticks)
            ticks += backoff
            self.clock.advance(backoff)
            self._c_retry.value += 1
            if self.obs.ring.enabled:
                self.obs.emit(EV_IO_RETRY, a=attempt, b=backoff)
