"""Fault injection & crash-consistent recovery for the serving cache.

Four pieces, layered bottom-up (each importable alone):

  * ``plan``     — deterministic seeded fault schedules (``FaultPlan`` /
    ``NullPlan``): which op faults, decided by a splitmix64 hash of
    (seed, op sequence) so every chaos run replays bit-identically;
  * ``io``       — the hardened host-block IO path (``HostIO``): retry /
    exponential backoff / per-op deadlines on a virtual ``Clock``, plus
    a ``CircuitBreaker`` that sheds the pool to degraded read-through
    under sustained failure;
  * ``snapshot`` — crash-consistent snapshot/restore of full engine
    state (layout arrays + ghost ring + correlation-window cursors),
    as an in-memory ``state_dict``, a versioned byte format
    (``pack``/``unpack``, magic ``C2QSNAP1``), and a ``SnapshotManager``
    riding the checkpoint store;
  * ``recovery`` — shard failover: a ``GhostJournal`` of per-shard key
    metadata rebuilds a lost shard's working set through the normal
    ghost-promotion path before it rejoins rebalancing;
  * ``journal``  — the append-only write-ahead delta journal
    (``ShardJournal``): CRC-per-record segments with monotonic LSNs,
    torn-tail truncating ``recover``, and base-snapshot compaction —
    every policy mutation is replayable bit-exactly;
  * ``replica``  — hot-standby replication over the journal
    (``ShardReplica`` / ``ShardReplicator``): bounded-staleness shard
    mirrors that ``promote()`` on shard loss instead of cold-rewarming,
    falling back to the ghost rewarm only past the lag threshold.

Layering: ``repro.faults`` sits beside the policy engines (layer 2) and
may import only ``repro.core`` and ``repro.obs``; the pool/serving
layers above thread it through their swap paths (``BlockPool(faults=...)``,
``ServingEngine(faults=...)``).  Everything here is numpy-only — no JAX —
so chaos tests run anywhere (``SnapshotManager`` lazily pulls in the
checkpoint store only when used).
"""

from repro.faults.io import (  # noqa: F401
    CircuitBreaker, Clock, HostIO, IOResult, RetryPolicy,
)
from repro.faults.journal import (  # noqa: F401
    JournalCrash, JRecord, RecoveryResult, ReplayDivergence, ShardJournal,
    apply_record, recover,
)
from repro.faults.plan import (  # noqa: F401
    CRASH, FAULT_NAMES, IO_DELAY, IO_ERROR, OP_ANY, OP_JOURNAL_APPEND,
    OP_SWAP_IN, OP_SWAP_OUT, PARTIAL_WRITE, SHARD_LOSS, Fault, FaultPlan,
    FaultSpec, NullPlan, splitmix64,
)
from repro.faults.recovery import GhostJournal, failover  # noqa: F401
from repro.faults.replica import (  # noqa: F401
    PromoteResult, ShardReplica, ShardReplicator,
)
from repro.faults.snapshot import (  # noqa: F401
    MAGIC, VERSION, SnapshotManager, load_state_dict, pack,
    policy_from_snapshot, read_snapshot, state_dict, unpack,
    write_snapshot,
)

__all__ = [
    "CircuitBreaker", "Clock", "HostIO", "IOResult", "RetryPolicy",
    "CRASH", "FAULT_NAMES", "IO_DELAY", "IO_ERROR", "OP_ANY",
    "OP_JOURNAL_APPEND", "OP_SWAP_IN", "OP_SWAP_OUT", "PARTIAL_WRITE",
    "SHARD_LOSS", "Fault", "FaultPlan", "FaultSpec", "NullPlan",
    "splitmix64",
    "GhostJournal", "failover",
    "JournalCrash", "JRecord", "RecoveryResult", "ReplayDivergence",
    "ShardJournal", "apply_record", "recover",
    "PromoteResult", "ShardReplica", "ShardReplicator",
    "MAGIC", "VERSION", "SnapshotManager", "load_state_dict", "pack",
    "policy_from_snapshot", "read_snapshot", "state_dict", "unpack",
    "write_snapshot",
]
