"""Deterministic seeded fault schedules (``FaultPlan``).

A plan is the *injection* half of the fault subsystem: a pure function
from (operation sequence number, op kind, key) to "which fault, if any,
fires here".  Determinism is the load-bearing property — a chaos run is
only debuggable if the same seed replays the same faults at the same
operations, bit for bit — so decisions come from a splitmix64 hash of
``(seed, op_seq)``, never from stateful RNG draws whose order could
drift with unrelated code motion.

Two trigger styles compose in one plan:

  * probabilistic — ``FaultSpec(kind, ops, prob=p)``: each matching
    operation independently faults with probability ``p`` (hash-derived
    uniform, so the decision stream is a pure function of the seed and
    the op sequence);
  * scheduled — ``FaultSpec(kind, ops, at=(100, 2048))``: fires exactly
    at those op sequence numbers (shard-loss drills, reproducing a
    specific incident).

``NullPlan`` is the production default: ``enabled`` is False and
``check`` always returns None, so the instrumented swap path costs one
attribute test per operation (gated <= 1.05x by ``perf_fault_overhead``).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

# fault kinds (int codes; also the `a` payload of EV_FAULT events)
IO_ERROR = 1        # the host-block IO fails (retryable)
IO_DELAY = 2        # latency spike: the op stalls for `ticks`
PARTIAL_WRITE = 3   # swap-out persists a torn block (detected on read)
SHARD_LOSS = 4      # a whole shard's state vanishes (process/node death)
CRASH = 5           # the process dies mid-write (journal crash-point
                    # fuzzing: `ticks` is reused as the byte offset into
                    # the record that made it to disk before the kill)

FAULT_NAMES = {
    IO_ERROR: "io_error",
    IO_DELAY: "io_delay",
    PARTIAL_WRITE: "partial_write",
    SHARD_LOSS: "shard_loss",
    CRASH: "crash",
}

# op kinds a spec can target (the pool's host-block IO surface, plus the
# journal's append stream for CRASH_AT crash-point specs)
OP_SWAP_IN = "swap_in"
OP_SWAP_OUT = "swap_out"
OP_JOURNAL_APPEND = "journal_append"
OP_ANY = "*"

_MASK64 = 0xFFFFFFFFFFFFFFFF


def splitmix64(x: int) -> int:
    """One splitmix64 round — the hash behind every fault decision."""
    x = (x + 0x9E3779B97F4A7C15) & _MASK64
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _MASK64
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _MASK64
    return x ^ (x >> 31)


def _uniform(seed: int, op_seq: int, salt: int) -> float:
    """Deterministic uniform in [0, 1) for one (plan, op, spec) triple."""
    h = splitmix64((seed ^ (salt * 0xD1B54A32D192ED03)) & _MASK64)
    return splitmix64((h ^ op_seq) & _MASK64) / float(1 << 64)


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One fault source inside a plan.

    ``kind``   — IO_ERROR / IO_DELAY / PARTIAL_WRITE / SHARD_LOSS.
    ``ops``    — which operation kinds it targets (OP_ANY = all).
    ``prob``   — per-matching-op firing probability (hash-derived).
    ``at``     — exact op sequence numbers that fire (overrides prob).
    ``ticks``  — stall length for IO_DELAY (virtual clock ticks).
    ``shard``  — target shard for SHARD_LOSS (-1 = hash-picked).
    """

    kind: int
    ops: Tuple[str, ...] = (OP_ANY,)
    prob: float = 0.0
    at: Tuple[int, ...] = ()
    ticks: int = 1
    shard: int = -1

    def __post_init__(self):
        if self.kind not in FAULT_NAMES:
            raise ValueError(f"unknown fault kind {self.kind}")
        if not (0.0 <= self.prob <= 1.0):
            raise ValueError(f"prob {self.prob} not in [0, 1]")


@dataclasses.dataclass(frozen=True)
class Fault:
    """A fault decision for one concrete operation (what ``check`` returns)."""

    kind: int
    op_seq: int
    spec_index: int
    ticks: int = 0
    shard: int = -1

    @property
    def name(self) -> str:
        """Human-readable kind name (event/report rendering)."""
        return FAULT_NAMES[self.kind]


class FaultPlan:
    """Seeded deterministic fault schedule over an operation stream.

    The plan owns the operation sequence counter: callers route every
    host-block IO through ``next_op(op, key)`` and act on the returned
    ``Fault`` (or None).  Two plans with the same seed and specs served
    the same op sequence return the same decisions — the chaos suite
    asserts this bit-for-bit.
    """

    enabled = True

    def __init__(self, seed: int, specs: Sequence[FaultSpec] = ()):
        self.seed = int(seed) & _MASK64
        self.specs = tuple(specs)
        self.op_seq = 0  # ops examined so far == next sequence number
        self.injected = 0

    def _match(self, spec: FaultSpec, op: str, op_seq: int,
               idx: int) -> bool:
        if OP_ANY not in spec.ops and op not in spec.ops:
            return False
        if spec.at:
            return op_seq in spec.at
        return spec.prob > 0.0 and \
            _uniform(self.seed, op_seq, idx) < spec.prob

    def check(self, op: str, op_seq: int) -> Optional[Fault]:
        """Pure decision for a given (op kind, sequence number) — does
        NOT advance the counter (replay/inspection path).  First
        matching spec wins, in declaration order."""
        for idx, spec in enumerate(self.specs):
            if self._match(spec, op, op_seq, idx):
                return Fault(kind=spec.kind, op_seq=op_seq, spec_index=idx,
                             ticks=spec.ticks, shard=spec.shard)
        return None

    def next_op(self, op: str) -> Optional[Fault]:
        """Consume one operation slot and return its fault decision."""
        f = self.check(op, self.op_seq)
        self.op_seq += 1
        if f is not None:
            self.injected += 1
        return f

    def schedule(self, op: str, n_ops: int) -> list:
        """The full decision sequence for ``n_ops`` hypothetical ops of
        one kind, without consuming the counter — the chaos suite uses
        this to assert per-seed determinism directly."""
        return [self.check(op, i) for i in range(n_ops)]


class NullPlan(FaultPlan):
    """No faults, ever — the production default for the instrumented
    swap path.  ``enabled`` lets hot paths skip decision work entirely;
    ``next_op`` still advances the op counter so swapping a real plan in
    mid-run keeps sequence numbers meaningful."""

    enabled = False

    def __init__(self):
        super().__init__(seed=0, specs=())

    def check(self, op: str, op_seq: int) -> Optional[Fault]:
        """Always None (no specs can match)."""
        return None

    def next_op(self, op: str) -> Optional[Fault]:
        """Advance the op counter; never faults."""
        self.op_seq += 1
        return None
