"""Shard failover: rebuild a lost shard's working set from ghost entries.

The Ghost ring is the key asymmetry this module exploits: it is *pure
metadata* (keys only, no payloads — §4.1), small enough to journal
continuously at negligible cost, while the resident payloads are exactly
what a crashed shard loses.  So recovery works like this:

  * a ``GhostJournal`` periodically captures, under each shard's lock,
    the shard's resident keys (coldest first) and ghost-ring keys — a
    few KB per shard;
  * on shard loss (``ShardedClock2QPlus.lose_shard`` swaps in a fresh
    empty shard), ``failover`` seeds the replacement's Ghost ring from
    the journal and then *re-admits* the journaled working set through
    the normal ghost-promotion path — each key ghost-hits straight into
    the Main Clock, precisely the paper's readmission machinery, so the
    rebuilt shard has the same structure organic traffic would produce;
  * keys whose payloads survive elsewhere (the pool's host tier) are
    refilled via the ``fill`` callback; the rest stay seeded in the
    Ghost ring, where their next touch readmits them with a single
    fill miss.

The shard then rejoins cross-shard rebalancing with a clean miss mark.
The chaos suite asserts recovery lands within 1pp of an uninjured run's
miss ratio on three SUITE traces.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

from repro.obs import EV_SHARD_REWARM


class GhostJournal:
    """Per-shard metadata journal (resident + ghost keys) for failover.

    ``capture`` refreshes the journal from the live service; how often
    to call it is a durability/staleness trade the operator makes (see
    docs/operations.md).  The journal never references payloads, so a
    capture is a few microseconds of key copying per shard.
    """

    def __init__(self, svc=None):
        self.meta: Dict[int, Dict[str, List[int]]] = {}
        self.captures = 0
        if svc is not None:
            self.capture(svc)

    def capture(self, svc, sid: Optional[int] = None) -> None:
        """Record the current working-set metadata of every shard (or
        one shard), each captured atomically under its shard lock."""
        sids = range(svc.n_shards) if sid is None else (sid,)
        for i in sids:
            with svc.locks[i]:
                sh = svc.shards[i]
                self.meta[i] = {"resident": sh.resident_keys(),
                                "ghost": sh.ghost_keys()}
        self.captures += 1

    def rewarm(self, svc, sid: int,
               fill: Optional[Callable[[int], Optional[Callable[[int], None]]]]
               = None) -> Tuple[int, int]:
        """Warm the (fresh) shard ``sid`` from the last captured journal.

        Ghost keys are re-seeded oldest-first; journaled resident keys
        are pushed into the Ghost ring and immediately re-accessed, so
        they readmit to the Main Clock through the normal ghost-
        promotion path.  ``fill(key)`` (optional) returns a
        ``filler(local_slot)`` callback when the key's payload can be
        recovered (e.g. from the pool's host tier) or None when it
        cannot — unrecoverable keys stay seeded in the Ghost ring and
        readmit with one fill miss on their next organic touch.

        Returns ``(residents_readmitted, ghosts_seeded)``.
        """
        meta = self.meta.get(sid)
        if meta is None:
            return (0, 0)
        sh = svc.shards[sid]
        n_res = 0
        n_ghost = 0
        with svc.locks[sid]:
            # residents first: each is pushed into the ghost ring and
            # immediately re-accessed, so its ghost entry is consumed on
            # the spot and the ring is free for the journaled ghosts below
            unfilled = []
            for k in meta["resident"]:
                k = int(k)
                filler = None
                if fill is not None:
                    filler = fill(k)
                    if filler is None:
                        # payload unrecoverable: defer to the ghost
                        # seeding below, so the next organic touch
                        # readmits it with one fill miss
                        unfilled.append(k)
                        continue
                sh._ghost_push(k)
                r = sh.access(k)
                if filler is not None:
                    filler(r.block)
                sh.io_done(k)
                n_res += 1
            # then the ghost seeds: journaled ghosts oldest first, then
            # unfillable residents (warmer — they were resident at
            # capture), so the warmest keys land farthest from the
            # overwrite cursor.  A consistent capture has disjoint
            # resident/ghost sets, so none of these can shadow an entry
            # readmitted above.
            for k in meta["ghost"] + unfilled:
                sh._ghost_push(int(k))
                n_ghost += 1
        return (n_res, n_ghost)


def failover(svc, sid: int, journal: GhostJournal,
             fill: Optional[Callable] = None) -> Tuple[int, int]:
    """Full shard failover: drop the dead shard, rewarm its replacement
    from the journal, and let it rejoin rebalancing.

    ``svc.lose_shard(sid)`` swaps in an empty shard with identical
    preallocation (payload handles stay valid for the backing arrays)
    and resets the shard's rebalance miss mark; the journal then
    rebuilds the working set as described on ``GhostJournal.rewarm``.
    Emits ``EV_SHARD_REWARM`` with the readmission counts.
    """
    svc.lose_shard(sid)
    n_res, n_ghost = journal.rewarm(svc, sid, fill=fill)
    if svc.obs.ring.enabled:
        svc.obs.emit(EV_SHARD_REWARM, shard=sid, a=n_res, b=n_ghost)
    return (n_res, n_ghost)
