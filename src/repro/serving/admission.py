"""Admission control for the continuous-batching serving scheduler.

This module is the *policy* half of the serving front door, and it is
deliberately JAX-free: the deterministic simulation-test harness
(tests/test_scheduler.py) and the SLO benchmark drive it with a pure
Python executor, so every admit/displace/shed/age decision here must be
a function of (request fields, virtual tick, seed) only.

Pieces:

  * ``SchedRequest`` — the scheduler's view of a request: token counts,
    priority class, absolute-tick SLO deadline, owning tenant, and an
    opaque ``payload`` the executor understands (the engine's ``Request``
    on the real path, anything on the sim path).
  * ``AdmissionConfig`` — the runbook knobs: queue bound, class count,
    tenant weights, anti-starvation aging, backpressure watermarks.
  * ``AdmissionQueue`` — a bounded priority queue with displacement
    (a full queue sheds its lowest-priority tail to admit a stricter
    class, never the other way around), deadline-based shedding (a
    request that can no longer meet its SLO is shed *before* the miss),
    waiting-time aging (sustained overload cannot starve the batch
    class), and deficit-style multi-tenant fair share (among equals,
    the least-served tenant per weight goes first).

Tie-breaks hash ``(seed, req_id)`` through splitmix64, so a schedule is
bit-reproducible per seed — the property the whole test harness of this
PR hangs on.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.faults.plan import splitmix64

# priority classes, strongest SLO first.  ``priority`` is an index into
# this tuple: 0 = interactive (tight deadline), 1 = standard, 2 = batch
# (deadline-free backfill).  Configs may use fewer classes; labels for
# out-of-range indices degrade to "p<N>".
CLASS_NAMES: Tuple[str, ...] = ("interactive", "standard", "batch")

# terminal request states — every submitted request ends as exactly one
# of these (the hypothesis suite asserts the trichotomy)
ST_COMPLETED = "completed"
ST_SHED = "shed"
ST_REJECTED = "rejected"

# shed/reject reason codes (the `b` payload of EV_SHED / EV_REJECT)
R_QUEUE_FULL = 1    # bounded queue full of equal-or-better work
R_OVERSIZE = 2      # prompt + decode tail can never fit the pool
R_DEADLINE = 3      # SLO can no longer be met: shed before the miss
R_DISPLACED = 4     # pushed out of a full queue by a stricter class
R_DEGRADED = 5      # backpressure: pool in read-through, lowest class shed

SHED_REASONS: Dict[int, str] = {
    R_QUEUE_FULL: "queue_full",
    R_OVERSIZE: "oversize",
    R_DEADLINE: "deadline",
    R_DISPLACED: "displaced",
    R_DEGRADED: "degraded",
}


def class_label(priority: int) -> str:
    """Stable label for a priority class (metrics / event rendering)."""
    if 0 <= priority < len(CLASS_NAMES):
        return CLASS_NAMES[priority]
    return f"p{priority}"


@dataclasses.dataclass
class SchedRequest:
    """One request as the scheduler sees it.

    ``deadline`` is an *absolute* virtual tick (0 = no SLO).  ``payload``
    is opaque to the scheduler — the executor interprets it (the real
    engine stashes its ``Request`` there; the sim executor needs nothing).
    ``arrival`` is stamped by the scheduler at submit time.
    """

    req_id: int
    prompt_len: int
    max_new: int = 16
    priority: int = 1
    deadline: int = 0
    tenant: str = "default"
    payload: object = None
    arrival: int = 0

    def service_ticks(self) -> int:
        """Ticks from the prefill tick to the completion tick: the
        prefill tick yields the first token, then one decode tick per
        further token — exact, so deadline feasibility is not
        conservative (virtual time makes this arithmetic, not an
        estimate)."""
        return max(0, self.max_new - 1)


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """Admission/backpressure knobs (see docs/operations.md "Serving").

    ``queue_bound``     — pending requests the front door will hold.
    ``n_classes``       — priority classes in use (0 is strictest).
    ``tenant_weights``  — fair-share weights; absent tenants weigh 1.0.
    ``age_ticks``       — waiting this long promotes a request one class
                          for *ordering* purposes (anti-starvation); 0
                          disables aging.
    ``low_watermark``   — free-block fraction below which only class-0
                          prefills are admitted (backpressure).
    ``shed_margin``     — extra slack ticks required on top of the
                          service estimate before a deadline is
                          considered met (0 = exact).
    """

    queue_bound: int = 64
    n_classes: int = 3
    tenant_weights: Optional[Dict[str, float]] = None
    age_ticks: int = 64
    low_watermark: float = 0.125
    shed_margin: int = 0

    def weight(self, tenant: str) -> float:
        if self.tenant_weights and tenant in self.tenant_weights:
            return max(1e-9, float(self.tenant_weights[tenant]))
        return 1.0


class AdmissionQueue:
    """Bounded multi-class admission queue with fair-share ordering.

    The queue never reorders storage (one insertion-ordered list); the
    *selection* order is computed per pop from the sort key

        (effective class, served-tokens/weight of tenant, arrival,
         splitmix64(seed ^ req_id))

    so admission is priority-first, then least-served-tenant-first, then
    FIFO, with a seeded deterministic tie-break.  ``served`` charges a
    tenant the full committed cost (prompt + max_new tokens) the moment
    its request is *dispatched*, which is what makes the fairness test's
    band assertion hold under saturating equal demand.
    """

    def __init__(self, config: AdmissionConfig, seed: int = 0):
        self.cfg = config
        self.seed = int(seed)
        self._q: List[SchedRequest] = []
        self.served: Dict[str, float] = {}

    def __len__(self) -> int:
        return len(self._q)

    def __iter__(self):
        return iter(self._q)

    def depth_by_class(self) -> Dict[int, int]:
        d: Dict[int, int] = {}
        for r in self._q:
            d[r.priority] = d.get(r.priority, 0) + 1
        return d

    # -- ordering -----------------------------------------------------------
    def effective_class(self, r: SchedRequest, now: int) -> int:
        """Priority class after anti-starvation aging: every
        ``age_ticks`` of waiting promotes one class (ordering only —
        metrics/labels keep the declared class)."""
        if self.cfg.age_ticks <= 0:
            return r.priority
        return max(0, r.priority - (now - r.arrival) // self.cfg.age_ticks)

    def _key(self, r: SchedRequest, now: int):
        return (self.effective_class(r, now),
                self.served.get(r.tenant, 0.0) / self.cfg.weight(r.tenant),
                r.arrival,
                splitmix64(self.seed ^ (r.req_id & 0xFFFFFFFFFFFFFFFF)))

    # -- admission ----------------------------------------------------------
    def offer(self, r: SchedRequest,
              now: int) -> Tuple[bool, int, Optional[SchedRequest]]:
        """Try to enqueue ``r``.  Returns (admitted, reason, displaced):
        a full queue displaces its worst strictly-lower-priority entry
        (returned so the scheduler can record the shed); if none exists
        the offer is rejected with ``R_QUEUE_FULL``."""
        if len(self._q) < self.cfg.queue_bound:
            self._q.append(r)
            return True, 0, None
        worst = None
        for q in self._q:
            if q.priority <= r.priority:
                continue  # equal-or-better work is never displaced
            if worst is None or self._key(q, now) > self._key(worst, now):
                worst = q
        if worst is None:
            return False, R_QUEUE_FULL, None
        self._q.remove(worst)
        self._q.append(r)
        return True, 0, worst

    def shed_expired(self, now: int) -> List[SchedRequest]:
        """Remove every queued request whose SLO can no longer be met
        even if dispatched *this* tick — shed-before-deadline-miss."""
        margin = self.cfg.shed_margin
        expired = [r for r in self._q
                   if r.deadline and now + r.service_ticks() + margin
                   > r.deadline]
        for r in expired:
            self._q.remove(r)
        return expired

    def shed_class(self, priority: int) -> List[SchedRequest]:
        """Remove every queued request of one declared class (degraded-
        mode backpressure sheds the lowest class first)."""
        victims = [r for r in self._q if r.priority == priority]
        for r in victims:
            self._q.remove(r)
        return victims

    def peek_best(self, now: int, *,
                  max_class: Optional[int] = None) -> Optional[SchedRequest]:
        """The next request by selection order, without dequeuing it
        (the scheduler peeks, checks budget/blocks, then ``remove``s);
        ``max_class`` restricts eligibility by *effective* class
        (backpressure admits only the strict classes)."""
        best = None
        for r in self._q:
            if max_class is not None and \
                    self.effective_class(r, now) > max_class:
                continue
            if best is None or self._key(r, now) < self._key(best, now):
                best = r
        return best

    def remove(self, r: SchedRequest) -> None:
        """Dequeue a specific request (after ``peek_best``)."""
        self._q.remove(r)

    def charge(self, r: SchedRequest) -> None:
        """Charge ``r``'s tenant the committed token cost (at dispatch)."""
        self.served[r.tenant] = self.served.get(r.tenant, 0.0) \
            + r.prompt_len + r.max_new
