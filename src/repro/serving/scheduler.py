"""Continuous-batching serving scheduler on a virtual integer-tick clock.

The scheduler closes the gap between the paper's claim (Clock2Q+ keeps
the hit path cheap enough to sit under a high-throughput serving stack)
and the engine's old single synchronous ``run(requests)`` loop: it adds
the front door a real serving system needs — bounded admission, priority
classes, per-request SLO deadlines with shed-before-miss, token-budgeted
batch formation (prefill/decode interleaving), multi-tenant fair share,
and backpressure tied to the KV pool's free-block watermark and the
faults layer's ``degraded`` flag.

Time is the ``repro.faults.io.Clock``: one tick = one batched decode
step.  Nothing here reads a wall clock or an unseeded RNG, so for a
fixed (requests, arrivals, seed, executor) the full decision stream —
``schedule_log`` and the EV_ADMIT/EV_SHED/EV_BATCH event ring — is
bit-identical across runs.  That property is what the deterministic
simulation-test harness (tests/test_scheduler.py) and the
``fig_sched_slo`` benchmark pin.

The scheduler drives an *executor* — anything with the small duck-typed
surface below — so the same decision code runs the real JAX engine
(``repro.serving.engine.EngineExecutor``) and the model-free
``SimExecutor`` the tests and SLO benchmark use:

    n_blocks, block_size      # capacity surface (oversize rejection)
    free_fraction() -> float  # evictable-block fraction (backpressure)
    degraded -> bool          # faults breaker open (read-through mode)
    prefill(req) -> int       # admit + prefill; returns the first token
    decode(ids) -> {id: tok}  # one batched decode step
    release(req_id)           # sequence finished; free its blocks
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro import obs as obs_mod
from repro.faults.io import Clock
from repro.faults.plan import splitmix64
from repro.serving.admission import (
    R_DEADLINE, R_DEGRADED, R_DISPLACED, R_OVERSIZE, ST_COMPLETED,
    ST_REJECTED, ST_SHED, AdmissionConfig, AdmissionQueue, SchedRequest,
    class_label,
)


@dataclasses.dataclass(frozen=True)
class SchedConfig:
    """Scheduler knobs on top of the admission policy.

    ``token_budget`` — tokens one tick may commit (decode = 1/sequence,
    prefill = the full prompt).  Decodes are never throttled (an active
    sequence always advances — the no-starvation half of the SLO story);
    the budget gates how much *prefill* work may pile into one tick.  A
    prompt longer than the whole budget is still admitted when the tick
    is otherwise empty, so oversized-but-feasible prompts cannot
    livelock.
    ``max_batch`` — concurrent sequences (decode slots).
    """

    token_budget: int = 512
    max_batch: int = 8
    admission: AdmissionConfig = dataclasses.field(
        default_factory=AdmissionConfig)


@dataclasses.dataclass
class Outcome:
    """Terminal record for one submitted request (exactly one per
    request: completed, shed, or rejected)."""

    req_id: int
    status: str
    tokens: List[int] = dataclasses.field(default_factory=list)
    finish: int = 0        # tick the terminal state was reached
    reason: int = 0        # shed/reject reason code (admission.SHED_REASONS)
    tenant: str = "default"
    priority: int = 1


class Scheduler:
    """Token-budgeted continuous batching with admission control."""

    def __init__(self, executor, *, config: Optional[SchedConfig] = None,
                 clock: Optional[Clock] = None, seed: int = 0, obs=None):
        self.x = executor
        self.cfg = config or SchedConfig()
        self.clock = Clock() if clock is None else clock
        self.seed = int(seed)
        self.queue = AdmissionQueue(self.cfg.admission, seed=seed)
        self.active: Dict[int, SchedRequest] = {}
        self.tokens: Dict[int, List[int]] = {}
        self.outcomes: Dict[int, Outcome] = {}
        self.order: List[int] = []  # req_ids in termination order
        # the full decision stream — the bit-reproducibility fixture
        self.schedule_log: List[Tuple] = []
        self.obs = obs_mod.NullSink(src="sched") if obs is None else obs
        self._c_admit = self.obs.counter(
            "sched_admitted_total", ("tenant", "class"),
            "requests admitted to the bounded queue")
        self._c_reject = self.obs.counter(
            "sched_rejected_total", ("tenant", "class", "reason"),
            "requests refused at the front door")
        self._c_shed = self.obs.counter(
            "sched_shed_total", ("tenant", "class", "reason"),
            "queued requests shed (deadline / displaced / degraded)")
        self._c_done = self.obs.counter(
            "sched_completed_total", ("tenant", "class"),
            "requests that ran to completion")
        self._c_batch = self.obs.counter(
            "sched_batches_total", (), "scheduler ticks that dispatched "
            "work").labels()
        self._c_tok = self.obs.counter(
            "sched_tokens_total", ("kind",),
            "tokens committed to batches, prefill vs decode")
        depth = self.obs.gauge("sched_queue_depth", ("class",),
                               "queued requests per priority class")
        self._g_depth = [depth.labels(class_label(p))
                         for p in range(self.cfg.admission.n_classes)]
        self._g_occ = self.obs.gauge(
            "sched_batch_occupancy", (),
            "active sequences / max_batch").labels()
        self._g_free = self.obs.gauge(
            "sched_free_frac", (), "executor free-block fraction seen at "
            "the last tick").labels()
        self._h_wait = self.obs.histogram(
            "sched_wait_ticks", (), "queue wait (submit -> prefill), "
            "virtual ticks", base=1.0, n_buckets=16).labels()

    # -- bookkeeping ----------------------------------------------------------
    def _terminal(self, r: SchedRequest, status: str, reason: int = 0,
                  toks: Optional[List[int]] = None) -> Outcome:
        out = Outcome(r.req_id, status, toks if toks is not None else [],
                      finish=self.clock.now, reason=reason,
                      tenant=r.tenant, priority=r.priority)
        self.outcomes[r.req_id] = out
        self.order.append(r.req_id)
        return out

    def _shed(self, r: SchedRequest, reason: int) -> None:
        self._c_shed.labels(r.tenant, class_label(r.priority),
                            str(reason)).value += 1
        if self.obs.ring.enabled:
            self.obs.emit(obs_mod.EV_SHED, shard=self.clock.now,
                          a=r.req_id, b=reason)
        self.schedule_log.append(("shed", self.clock.now, r.req_id, reason))
        self._terminal(r, ST_SHED, reason)

    # -- admission (the front door) -------------------------------------------
    def submit(self, r: SchedRequest) -> bool:
        """Offer one request.  Stamps the arrival tick; returns True if
        it entered the queue (it may still be shed later), False if it
        was rejected outright (queue full of equal-or-better work, or
        the prompt + decode tail can never fit the pool)."""
        now = self.clock.now
        r.arrival = now
        bs = getattr(self.x, "block_size", 0)
        if bs and -(-(r.prompt_len + r.max_new) // bs) > self.x.n_blocks:
            return self._reject(r, R_OVERSIZE)
        admitted, reason, displaced = self.queue.offer(r, now)
        if not admitted:
            return self._reject(r, reason)
        if displaced is not None:
            self._shed(displaced, R_DISPLACED)
        self._c_admit.labels(r.tenant, class_label(r.priority)).value += 1
        if self.obs.ring.enabled:
            self.obs.emit(obs_mod.EV_ADMIT, shard=now, a=r.req_id,
                          b=r.priority)
        self.schedule_log.append(("admit", now, r.req_id))
        return True

    def _reject(self, r: SchedRequest, reason: int) -> bool:
        self._c_reject.labels(r.tenant, class_label(r.priority),
                              str(reason)).value += 1
        if self.obs.ring.enabled:
            self.obs.emit(obs_mod.EV_REJECT, shard=self.clock.now,
                          a=r.req_id, b=reason)
        self.schedule_log.append(("reject", self.clock.now, r.req_id,
                                  reason))
        self._terminal(r, ST_REJECTED, reason)
        return False

    # -- one scheduling round -------------------------------------------------
    def tick(self) -> int:
        """One virtual tick: shed expired SLOs, apply backpressure, form
        a token-budgeted batch (prefills + one decode step for the
        previously-active sequences), dispatch it, advance the clock.
        Returns the number of sequences that completed this tick."""
        now = self.clock.now
        adm = self.cfg.admission
        # 1. SLO shedding: anything that cannot finish in time anymore
        #    is shed now, before it burns batch slots and misses anyway
        for r in self.queue.shed_expired(now):
            self._shed(r, R_DEADLINE)
        # 2. backpressure: degraded mode sheds the lowest class outright
        #    and narrows admission to class 0; a low free-block watermark
        #    narrows admission without shedding
        degraded = bool(self.x.degraded)
        if degraded and adm.n_classes > 1:
            for r in self.queue.shed_class(adm.n_classes - 1):
                self._shed(r, R_DEGRADED)
        free = float(self.x.free_fraction())
        max_class = 0 if (degraded or free < adm.low_watermark) else None
        # 3. batch formation under the token budget: decodes first (one
        #    token per active sequence, never throttled), then prefills
        #    from the queue while budget, decode slots, and blocks last
        budget = self.cfg.token_budget - len(self.active)
        decode_ids = sorted(self.active)
        n_blocks = max(1, getattr(self.x, "n_blocks", 1))
        bs = getattr(self.x, "block_size", 0)
        free_est = free
        prefills: List[SchedRequest] = []
        while len(self.active) + len(prefills) < self.cfg.max_batch:
            r = self.queue.peek_best(now, max_class=max_class)
            if r is None:
                break
            if r.prompt_len > budget and (prefills or decode_ids):
                break  # interleave: leftover prefill work waits a tick
            need = -(-(r.prompt_len + r.max_new) // bs) / n_blocks \
                if bs else 0.0
            if free_est - need < adm.low_watermark and \
                    (prefills or decode_ids):
                break  # block watermark: don't over-pin the pool
            self.queue.remove(r)
            self.queue.charge(r)
            prefills.append(r)
            budget -= r.prompt_len
            free_est -= need
        # 4. dispatch
        done = 0
        for r in prefills:
            self._h_wait.observe(float(now - r.arrival))
            self.schedule_log.append(("start", now, r.req_id))
            first = self.x.prefill(r)
            self.tokens[r.req_id] = [int(first)]
            if r.max_new <= 1:
                done += self._complete(r)
            else:
                self.active[r.req_id] = r
        if decode_ids:
            out = self.x.decode(decode_ids)
            for rid in decode_ids:
                self.tokens[rid].append(int(out[rid]))
                r = self.active[rid]
                if len(self.tokens[rid]) >= r.max_new:
                    del self.active[rid]
                    done += self._complete(r)
        if prefills or decode_ids:
            self._c_batch.value += 1
            used = sum(r.prompt_len for r in prefills) + len(decode_ids)
            self._c_tok.labels("prefill").value += \
                sum(r.prompt_len for r in prefills)
            self._c_tok.labels("decode").value += len(decode_ids)
            if self.obs.ring.enabled:
                self.obs.emit(obs_mod.EV_BATCH, shard=now, a=len(prefills),
                              b=len(decode_ids), c=float(used))
            self.schedule_log.append(("batch", now, len(prefills),
                                      len(decode_ids), used))
        # 5. gauges + clock
        depth = self.queue.depth_by_class()
        for p, g in enumerate(self._g_depth):
            g.set(float(depth.get(p, 0)))
        self._g_occ.set(len(self.active) / max(1, self.cfg.max_batch))
        self._g_free.set(free)
        self.clock.advance(1)
        return done

    def _complete(self, r: SchedRequest) -> int:
        self.x.release(r.req_id)
        self._c_done.labels(r.tenant, class_label(r.priority)).value += 1
        self.schedule_log.append(("done", self.clock.now, r.req_id))
        self._terminal(r, ST_COMPLETED, toks=self.tokens.pop(r.req_id))
        return 1

    # -- whole-trace driver ---------------------------------------------------
    def run(self, requests: Sequence[SchedRequest],
            arrivals: Optional[Sequence[int]] = None,
            max_idle_ticks: int = 10_000) -> List[Outcome]:
        """Replay a request stream to completion.  ``arrivals[i]`` is the
        absolute tick request i is submitted at (omitted = everything
        arrives at the current tick); requests sharing a tick are
        submitted in input order.  Returns outcomes in termination
        order.  ``max_idle_ticks`` guards the driver against a
        configuration that can never drain (e.g. aging disabled while
        permanently degraded)."""
        if arrivals is None:
            arrivals = [self.clock.now] * len(requests)
        if len(arrivals) != len(requests):
            raise ValueError("arrivals and requests length mismatch")
        pending = sorted(range(len(requests)),
                         key=lambda i: (int(arrivals[i]), i))
        pos, idle = 0, 0
        while pos < len(pending) or self.queue or self.active:
            while pos < len(pending) and \
                    int(arrivals[pending[pos]]) <= self.clock.now:
                self.submit(requests[pending[pos]])
                pos += 1
            before = len(self.order)
            self.tick()
            idle = idle + 1 if len(self.order) == before else 0
            if idle > max_idle_ticks:
                raise RuntimeError(
                    f"scheduler made no progress for {max_idle_ticks} "
                    f"ticks (queue={len(self.queue)}, "
                    f"active={len(self.active)})")
        return [self.outcomes[rid] for rid in self.order]


class SimExecutor:
    """Deterministic model-free executor for the simulation harness.

    Tokens are a pure hash of (req_id, position) — two runs, or the
    scheduler vs the synchronous reference below, produce identical
    "greedy" tokens for a request no matter how it was batched, which is
    exactly the property the real engine has (greedy decoding depends
    only on the sequence's own KV).  Block accounting mirrors the paged
    pool: a sequence reserves ceil((prompt+max_new)/block_size) blocks
    from prefill to release.  ``degraded`` is a plain attribute the
    chaos tests flip (or a ``degraded_ticks`` range drives from the
    clock).
    """

    def __init__(self, n_blocks: int = 256, block_size: int = 16,
                 vocab: int = 50_000, clock: Optional[Clock] = None,
                 degraded_ticks: Optional[range] = None):
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.vocab = vocab
        self.clock = clock
        self.degraded_ticks = degraded_ticks
        self._degraded = False
        self.used = 0
        self._blocks: Dict[int, int] = {}
        self._counts: Dict[int, int] = {}
        self.prefills = 0
        self.decode_steps = 0

    @property
    def degraded(self) -> bool:
        if self.degraded_ticks is not None and self.clock is not None:
            return self.clock.now in self.degraded_ticks
        return self._degraded

    @degraded.setter
    def degraded(self, v: bool) -> None:
        self._degraded = bool(v)

    def free_fraction(self) -> float:
        return 1.0 - self.used / max(1, self.n_blocks)

    def token(self, req_id: int, i: int) -> int:
        return splitmix64(req_id * 0x9E3779B1 + i) % self.vocab

    def prefill(self, r: SchedRequest) -> int:
        nb = -(-(r.prompt_len + r.max_new) // self.block_size)
        self._blocks[r.req_id] = nb
        self._counts[r.req_id] = 1
        self.used += nb
        self.prefills += 1
        return self.token(r.req_id, 0)

    def decode(self, ids: List[int]) -> Dict[int, int]:
        self.decode_steps += 1
        out = {}
        for rid in ids:
            i = self._counts[rid]
            self._counts[rid] = i + 1
            out[rid] = self.token(rid, i)
        return out

    def release(self, req_id: int) -> None:
        self.used -= self._blocks.pop(req_id)
        self._counts.pop(req_id, None)


def simulate_sync(requests: Sequence[SchedRequest],
                  arrivals: Sequence[int], *, max_batch: int = 8,
                  executor: Optional[SimExecutor] = None) -> Dict[int, int]:
    """Tick-level model of the OLD synchronous ``ServingEngine.run``
    loop: FIFO admission up to ``max_batch``, no priorities, no
    deadlines, no shedding — the baseline ``fig_sched_slo`` compares
    the scheduler against.  Returns {req_id: completion tick}."""
    x = executor or SimExecutor(n_blocks=1 << 30, block_size=16)
    order = sorted(range(len(requests)),
                   key=lambda i: (int(arrivals[i]), i))
    finish: Dict[int, int] = {}
    pending: List[SchedRequest] = []
    active: Dict[int, SchedRequest] = {}
    produced: Dict[int, int] = {}
    now, pos = 0, 0
    while pos < len(order) or pending or active:
        while pos < len(order) and int(arrivals[order[pos]]) <= now:
            pending.append(requests[order[pos]])
            pos += 1
        decode_ids = sorted(active)
        while pending and len(active) < max_batch:
            r = pending.pop(0)  # FIFO: head-of-line blocking and all
            x.prefill(r)
            produced[r.req_id] = 1
            if r.max_new <= 1:
                finish[r.req_id] = now
                x.release(r.req_id)
            else:
                active[r.req_id] = r
        for rid in decode_ids:
            produced[rid] += 1
            if produced[rid] >= active[rid].max_new:
                finish[rid] = now
                x.release(rid)
                del active[rid]
        now += 1
    return finish
