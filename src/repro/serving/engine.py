"""Serving runtime: continuous batching over the Clock2Q+-paged KV pool.

Flow per request:
  admit -> prefix-cache lookup (shared full blocks hit; correlated
  references!) -> prefill only the blocks that missed -> decode loop with
  paged attention (block-table gather) -> release (blocks stay cached,
  unpinned, for future prefix hits).

Under HBM pressure the Clock2Q+ policy evicts cold blocks to the host
tier; dirty (HBM-only) blocks are flushed by the watermark flusher before
they become evictable, exactly as §4.1.3 prescribes.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as obs_mod
from repro.kvcache.manager import PagedKVManager
from repro.kvcache.pool import BlockPool
from repro.models import transformer as T
from repro.models.model import ModelAPI


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: List[int]
    max_new: int = 16


@dataclasses.dataclass
class Completion:
    req_id: int
    tokens: List[int]


class ServingEngine:
    """Single-host engine for the dense/vlm/moe families (the paged-KV
    families); greedy sampling."""

    def __init__(self, api: ModelAPI, params, *, block_size: int = 16,
                 hbm_blocks: int = 64, max_batch: int = 8,
                 max_blocks_per_seq: int = 64, n_shards: int = 0,
                 max_hbm_blocks: int = 0, rebalance_headroom: float = 1.0,
                 autotune=False, faults=None, io_retry=None, obs=None):
        assert api.cfg.family in ("dense", "vlm", "moe"), \
            "paged serving targets the attention-KV families"
        self.api = api
        self.cfg = api.cfg
        self.params = params
        # rebalance_headroom > 1 (or max_hbm_blocks slack) is what lets
        # the sharded policy actually move capacity between shards — at
        # the cost of preallocating that many more HBM blocks
        # autotune=True/dict turns on the OnlineTuner backend: the block
        # pool's replacement knobs (correlation window, queue fractions)
        # then track the serving workload online (repro.tuning).
        # faults= threads a repro.faults FaultPlan through the pool's
        # host-IO swap path; under sustained IO failure the pool sheds to
        # read-through and the engine keeps answering (misses refill from
        # prefill), with queue depth still bounded by max_batch.
        self.pool = BlockPool(api.cfg, hbm_blocks, block_size,
                              dtype=jnp.dtype(api.cfg.dtype),
                              n_shards=n_shards,
                              max_hbm_blocks=max_hbm_blocks,
                              rebalance_headroom=rebalance_headroom,
                              autotune=autotune, faults=faults,
                              io_retry=io_retry)
        self.mgr = PagedKVManager(api.cfg, self.pool)
        self.max_batch = max_batch
        self.max_blocks = max_blocks_per_seq
        # engine-tier telemetry (pool/policy/tuner keep their own sinks;
        # obs_snapshot() merges the whole stack)
        self.obs = obs_mod.ObsSink(src="serving") if obs is None else obs
        self._c_requests = self.obs.counter(
            "serve_requests_total", (), "requests completed").labels()
        self._c_tokens = self.obs.counter(
            "serve_tokens_total", (), "tokens generated (incl. the "
            "prefill token)").labels()
        self._h_latency = self.obs.histogram(
            "serve_request_latency_seconds", (),
            "admit -> completion wall time per request").labels()
        self._h_decode = self.obs.histogram(
            "serve_decode_step_seconds", (),
            "one batched decode step, wall time").labels()
        depth_fam = self.obs.gauge(
            "serve_queue_depth", ("stage",),
            "requests pending admission / actively decoding")
        self._g_pending = depth_fam.labels("pending")
        self._g_active = depth_fam.labels("active")
        self._admit_ts: Dict[int, float] = {}
        self._decode_fn = jax.jit(
            lambda params, toks, kp, vp, bt, lens, sid, soff:
            T.forward_decode_paged(api.cfg, params, toks, kp, vp, bt, lens,
                                   sid, soff))
        # prompts are padded to block_size buckets so prefill compiles
        # once per bucket, not once per prompt length
        self._prefill_fn = jax.jit(
            lambda params, batch: T.forward_prefill(api.cfg, params, batch,
                                                    full_logits=True))

    # -- prefill ------------------------------------------------------------------
    def _prefill_into_pool(self, st, fill_blocks: List[int]) -> int:
        """Run the dense prefill, write the missing blocks' KV, and return
        the first generated token (greedy).  NOTE: prefix-cache hits avoid
        block WRITES and deduplicate HBM (two sequences share physical
        blocks); logits still require the full forward here — suffix-only
        chunked prefill is future work."""
        n_real = len(st.tokens)
        pad = (-n_real) % self.pool.bs  # length bucketing (one compile
        toks = list(st.tokens) + [0] * pad  # per bucket, not per length)
        toks = jnp.asarray(toks, jnp.int32)[None]
        logits, cache = self._prefill_fn(self.params, {"tokens": toks})
        bs = self.pool.bs
        k = cache.k[:, 0]  # (L, S, H, hd)
        v = cache.v[:, 0]
        for b in fill_blocks:
            lo, hi = b * bs, min((b + 1) * bs, len(st.tokens))
            kb = jnp.zeros((self.cfg.n_layers, bs, self.cfg.n_kv_heads,
                            self.cfg.hd), k.dtype)
            kb = kb.at[:, :hi - lo].set(k[:, lo:hi])
            vb = jnp.zeros_like(kb)
            vb = vb.at[:, :hi - lo].set(v[:, lo:hi])
            self.pool.write_block(st.slots[b], kb, vb, key=st.block_keys[b])
        return int(jnp.argmax(logits[0, n_real - 1]))

    # -- main loop ------------------------------------------------------------------
    def run(self, requests: List[Request]) -> List[Completion]:
        pending = list(requests)
        active: Dict[int, Request] = {}
        done: List[Completion] = []
        while pending or active:
            # admit
            while pending and len(active) < self.max_batch:
                r = pending.pop(0)
                self._admit_ts[r.req_id] = time.perf_counter()
                st, fill = self.mgr.admit(r.req_id, r.prompt)
                first = self._prefill_into_pool(st, fill)
                st.out_tokens.append(first)  # from prefill logits
                active[r.req_id] = r
            for rid in [rid for rid, r in active.items()
                        if len(self.mgr.seqs[rid].out_tokens) >= r.max_new]:
                st = self.mgr.seqs[rid]
                done.append(Completion(rid, list(st.out_tokens)))
                self._h_latency.observe(
                    time.perf_counter() - self._admit_ts.pop(rid))
                self._c_requests.value += 1
                self._c_tokens.value += len(st.out_tokens)
                self.mgr.release(rid)
                del active[rid]
            self._g_pending.set(float(len(pending)))
            self._g_active.set(float(len(active)))
            if not active:
                continue
            # one decode step for the whole active batch: each sequence's
            # newest token (at position pos) writes its KV at pos and
            # attends to [0, pos].
            ids = sorted(active)
            toks, poss, bts, sids, soffs = [], [], [], [], []
            for rid in ids:
                st = self.mgr.seqs[rid]
                pos = st.length - 1       # position of the token processed
                toks.append(st.out_tokens[-1])
                poss.append(pos)
                slot, off = self.mgr.slot_for_pos(rid, pos)
                sids.append(slot)
                soffs.append(off)
                bts.append(self.mgr.block_table(rid, self.max_blocks))
            # pad to max_batch (one compile for all batch sizes); padded
            # rows duplicate the last row — they rewrite identical values
            while len(toks) < self.max_batch:
                toks.append(toks[-1])
                poss.append(poss[-1])
                sids.append(sids[-1])
                soffs.append(soffs[-1])
                bts.append(bts[-1])
            t_step = time.perf_counter()
            logits, kp, vp = self._decode_fn(
                self.params, jnp.asarray(toks, jnp.int32)[:, None],
                self.pool.kpool, self.pool.vpool,
                jnp.asarray(np.stack(bts)), jnp.asarray(poss, jnp.int32),
                jnp.asarray(sids, jnp.int32), jnp.asarray(soffs, jnp.int32))
            self.pool.kpool, self.pool.vpool = kp, vp
            nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
            self._h_decode.observe(time.perf_counter() - t_step)
            for i, rid in enumerate(ids):
                self.mgr.seqs[rid].out_tokens.append(int(nxt[i]))
            self.mgr.maintenance()
        return done

    def cache_mrc(self, capacities=None, **kw):
        """What-if MRC of the KV block pool at alternative HBM budgets
        (requires ``autotune=``) — see ``BlockPool.estimate_mrc``."""
        return self.pool.estimate_mrc(capacities, **kw)

    def obs_snapshot(self) -> "obs_mod.Snapshot":
        """One merged snapshot of the whole serving stack: engine
        latencies/queue depths + pool swaps + policy hit/flow counters
        (+ tuner, when autotuning)."""
        return obs_mod.merge([self.obs.snapshot(), self.pool.obs_snapshot()])

    @property
    def stats(self):
        return self.pool.stats, dict(self.pool.policy.flows)

    @property
    def degraded(self) -> bool:
        """True while the pool serves read-through (host IO shed by the
        circuit breaker under sustained injected/real failure)."""
        return self.pool.degraded
