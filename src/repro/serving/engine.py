"""Serving runtime: continuous batching over the Clock2Q+-paged KV pool.

Flow per request:
  submit -> admission control (bounded queue, priority classes, SLO
  deadlines — repro.serving.scheduler) -> prefix-cache lookup (shared
  full blocks hit; correlated references!) -> prefill only the blocks
  that missed -> decode loop with paged attention (block-table gather)
  -> release (blocks stay cached, unpinned, for future prefix hits).

``run()`` is a thin client of the ``Scheduler``: batch formation,
backpressure (free-block watermarks + the faults ``degraded`` flag) and
shedding all live there; this module only knows how to execute a
prefill/decode/release against the model (``EngineExecutor``).  The old
synchronous loop survives as ``run_sync`` — a compat shim and the
reference the scheduler's greedy tokens are locked against.

Under HBM pressure the Clock2Q+ policy evicts cold blocks to the host
tier; dirty (HBM-only) blocks are flushed by the watermark flusher before
they become evictable, exactly as §4.1.3 prescribes.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as obs_mod
from repro.kvcache.manager import PagedKVManager
from repro.kvcache.pool import BlockPool
from repro.models import transformer as T
from repro.models.model import ModelAPI
from repro.serving.admission import ST_COMPLETED, SchedRequest
from repro.serving.scheduler import SchedConfig, Scheduler


@dataclasses.dataclass
class Request:
    """One serving request.  ``priority``/``deadline``/``tenant`` feed
    the scheduler (deadline in virtual ticks from submission; 0 = no
    SLO); the defaults reproduce the pre-scheduler behaviour."""

    req_id: int
    prompt: List[int]
    max_new: int = 16
    priority: int = 1
    deadline: int = 0
    tenant: str = "default"


@dataclasses.dataclass
class Completion:
    """Terminal record: ``status`` is completed / shed / rejected (only
    completed carries tokens).  Oversized prompts — more blocks than the
    pool could ever pin — are now an explicit ``rejected`` completion
    instead of a silent drop."""

    req_id: int
    tokens: List[int]
    status: str = ST_COMPLETED


class ServingEngine:
    """Single-host engine for the dense/vlm/moe families (the paged-KV
    families); greedy sampling."""

    def __init__(self, api: ModelAPI, params, *, block_size: int = 16,
                 hbm_blocks: int = 64, max_batch: int = 8,
                 max_blocks_per_seq: int = 64, n_shards: int = 0,
                 max_hbm_blocks: int = 0, rebalance_headroom: float = 1.0,
                 autotune=False, faults=None, io_retry=None,
                 replicate: bool = False, journal_dir=None, obs=None):
        assert api.cfg.family in ("dense", "vlm", "moe"), \
            "paged serving targets the attention-KV families"
        self.api = api
        self.cfg = api.cfg
        self.params = params
        # rebalance_headroom > 1 (or max_hbm_blocks slack) is what lets
        # the sharded policy actually move capacity between shards — at
        # the cost of preallocating that many more HBM blocks
        # autotune=True/dict turns on the OnlineTuner backend: the block
        # pool's replacement knobs (correlation window, queue fractions)
        # then track the serving workload online (repro.tuning).
        # faults= threads a repro.faults FaultPlan through the pool's
        # host-IO swap path; under sustained IO failure the pool sheds to
        # read-through and the engine keeps answering (misses refill from
        # prefill), with queue depth still bounded by max_batch.
        # replicate= arms per-shard write-ahead journaling + hot-standby
        # replication (journal_dir=None keeps it in memory): shard loss
        # then promotes the standby instead of cold-rewarming.
        self.pool = BlockPool(api.cfg, hbm_blocks, block_size,
                              dtype=jnp.dtype(api.cfg.dtype),
                              n_shards=n_shards,
                              max_hbm_blocks=max_hbm_blocks,
                              rebalance_headroom=rebalance_headroom,
                              autotune=autotune, faults=faults,
                              io_retry=io_retry, replicate=replicate,
                              journal_dir=journal_dir)
        self.mgr = PagedKVManager(api.cfg, self.pool)
        self.max_batch = max_batch
        self.max_blocks = max_blocks_per_seq
        # engine-tier telemetry (pool/policy/tuner keep their own sinks;
        # obs_snapshot() merges the whole stack)
        self.obs = obs_mod.ObsSink(src="serving") if obs is None else obs
        self._c_requests = self.obs.counter(
            "serve_requests_total", (), "requests completed").labels()
        self._c_tokens = self.obs.counter(
            "serve_tokens_total", (), "tokens generated (incl. the "
            "prefill token)").labels()
        self._h_latency = self.obs.histogram(
            "serve_request_latency_seconds", (),
            "admit -> completion wall time per request").labels()
        self._h_decode = self.obs.histogram(
            "serve_decode_step_seconds", (),
            "one batched decode step, wall time").labels()
        depth_fam = self.obs.gauge(
            "serve_queue_depth", ("stage",),
            "requests pending admission / actively decoding")
        self._g_pending = depth_fam.labels("pending")
        self._g_active = depth_fam.labels("active")
        self._admit_ts: Dict[int, float] = {}
        self._decode_fn = jax.jit(
            lambda params, toks, kp, vp, bt, lens, sid, soff:
            T.forward_decode_paged(api.cfg, params, toks, kp, vp, bt, lens,
                                   sid, soff))
        # prompts are padded to block_size buckets so prefill compiles
        # once per bucket, not once per prompt length
        self._prefill_fn = jax.jit(
            lambda params, batch: T.forward_prefill(api.cfg, params, batch,
                                                    full_logits=True))

    # -- prefill ------------------------------------------------------------------
    def _prefill_into_pool(self, st, fill_blocks: List[int]) -> int:
        """Run the dense prefill, write the missing blocks' KV, and return
        the first generated token (greedy).  NOTE: prefix-cache hits avoid
        block WRITES and deduplicate HBM (two sequences share physical
        blocks); logits still require the full forward here — suffix-only
        chunked prefill is future work."""
        n_real = len(st.tokens)
        pad = (-n_real) % self.pool.bs  # length bucketing (one compile
        toks = list(st.tokens) + [0] * pad  # per bucket, not per length)
        toks = jnp.asarray(toks, jnp.int32)[None]
        logits, cache = self._prefill_fn(self.params, {"tokens": toks})
        bs = self.pool.bs
        k = cache.k[:, 0]  # (L, S, H, hd)
        v = cache.v[:, 0]
        for b in fill_blocks:
            lo, hi = b * bs, min((b + 1) * bs, len(st.tokens))
            kb = jnp.zeros((self.cfg.n_layers, bs, self.cfg.n_kv_heads,
                            self.cfg.hd), k.dtype)
            kb = kb.at[:, :hi - lo].set(k[:, lo:hi])
            vb = jnp.zeros_like(kb)
            vb = vb.at[:, :hi - lo].set(v[:, lo:hi])
            self.pool.write_block(st.slots[b], kb, vb, key=st.block_keys[b])
        return int(jnp.argmax(logits[0, n_real - 1]))

    # -- execution primitives (what the scheduler drives) ------------------------
    def _max_seq_blocks(self) -> int:
        """Blocks one sequence may ever hold: pool capacity, bounded by
        the block-table width the decode kernel was compiled for."""
        return min(self.pool.n_blocks, self.max_blocks)

    def _oversize(self, r: Request) -> bool:
        """A prompt + decode tail needing more blocks than the pool can
        pin can never be served — the old loop silently wedged on these;
        they are now rejected explicitly."""
        need = -(-(len(r.prompt) + r.max_new) // self.pool.bs)
        return need > self._max_seq_blocks()

    def _start(self, r: Request, tenant: str = "default") -> int:
        """Admit + prefill one request; returns its first token."""
        self._admit_ts[r.req_id] = time.perf_counter()
        st, fill = self.mgr.admit(r.req_id, r.prompt, tenant=tenant)
        first = self._prefill_into_pool(st, fill)
        st.out_tokens.append(first)  # from prefill logits
        return first

    def _decode_step(self, ids: List[int]) -> Dict[int, int]:
        """One decode step for the sequences in ``ids`` (<= max_batch):
        each sequence's newest token (at position pos) writes its KV at
        pos and attends to [0, pos].  Returns {req_id: next token}."""
        toks, poss, bts, sids, soffs = [], [], [], [], []
        for rid in ids:
            st = self.mgr.seqs[rid]
            pos = st.length - 1           # position of the token processed
            toks.append(st.out_tokens[-1])
            poss.append(pos)
            slot, off = self.mgr.slot_for_pos(rid, pos)
            sids.append(slot)
            soffs.append(off)
            bts.append(self.mgr.block_table(rid, self.max_blocks))
        # pad to max_batch (one compile for all batch sizes); padded
        # rows duplicate the last row — they rewrite identical values
        while len(toks) < self.max_batch:
            toks.append(toks[-1])
            poss.append(poss[-1])
            sids.append(sids[-1])
            soffs.append(soffs[-1])
            bts.append(bts[-1])
        t_step = time.perf_counter()
        logits, kp, vp = self._decode_fn(
            self.params, jnp.asarray(toks, jnp.int32)[:, None],
            self.pool.kpool, self.pool.vpool,
            jnp.asarray(np.stack(bts)), jnp.asarray(poss, jnp.int32),
            jnp.asarray(sids, jnp.int32), jnp.asarray(soffs, jnp.int32))
        self.pool.kpool, self.pool.vpool = kp, vp
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        self._h_decode.observe(time.perf_counter() - t_step)
        out = {}
        for i, rid in enumerate(ids):
            tok = int(nxt[i])
            self.mgr.seqs[rid].out_tokens.append(tok)
            out[rid] = tok
        self.mgr.maintenance()
        return out

    def _finish(self, rid: int) -> Completion:
        """Release a completed sequence + engine-tier telemetry."""
        st = self.mgr.seqs[rid]
        done = Completion(rid, list(st.out_tokens))
        self._h_latency.observe(
            time.perf_counter() - self._admit_ts.pop(rid))
        self._c_requests.value += 1
        self._c_tokens.value += len(st.out_tokens)
        self.mgr.release(rid)
        return done

    # -- main loop: thin client of the continuous-batching scheduler -------------
    def run(self, requests: List[Request],
            arrivals: Optional[List[int]] = None, *,
            config: Optional[SchedConfig] = None,
            seed: int = 0) -> List[Completion]:
        """Serve ``requests`` through the admission-controlled scheduler
        (repro.serving.scheduler).  ``arrivals[i]`` staggers submission
        over virtual ticks (default: everything at once — the historical
        call shape); ``Request.deadline`` is interpreted relative to
        submission.  Greedy tokens are batch-composition-independent, so
        completed outputs are identical to ``run_sync`` on the same
        request set.  Returns one Completion per request — completed,
        shed, or rejected — in termination order."""
        sched = self.make_scheduler(config=config, seed=seed)
        base = sched.clock.now
        sreqs = [SchedRequest(
            req_id=r.req_id, prompt_len=len(r.prompt), max_new=r.max_new,
            priority=r.priority,
            deadline=(base + int(a or 0) + r.deadline) if r.deadline else 0,
            tenant=r.tenant, payload=r)
            for r, a in zip(requests,
                            arrivals or [0] * len(requests))]
        abs_arrivals = None if arrivals is None \
            else [base + int(a) for a in arrivals]
        outs = sched.run(sreqs, abs_arrivals)
        self._g_pending.set(float(len(sched.queue)))
        self._g_active.set(float(len(sched.active)))
        self._last_scheduler = sched
        return [Completion(o.req_id, o.tokens, status=o.status)
                for o in outs]

    def make_scheduler(self, *, config: Optional[SchedConfig] = None,
                       seed: int = 0) -> Scheduler:
        """A scheduler wired to this engine: executes on the model,
        reads backpressure from the pool (free-block watermark + the
        faults ``degraded`` flag), shares the pool's virtual IO clock
        when fault injection is armed, and reports into the engine's obs
        sink (one merged stack snapshot)."""
        cfg = config or SchedConfig(max_batch=self.max_batch)
        clock = self.pool.io_clock()
        return Scheduler(EngineExecutor(self), config=cfg, clock=clock,
                         seed=seed, obs=self.obs)

    # -- compat shim: the pre-scheduler synchronous loop --------------------------
    def run_sync(self, requests: List[Request]) -> List[Completion]:
        """The old synchronous loop: FIFO admission up to ``max_batch``,
        no priorities, no deadlines, no backpressure.  Kept as the
        reference path — the conformance tests lock the scheduler's
        greedy tokens against it — and for callers that want the
        historical semantics."""
        pending, done = [], []
        for r in requests:
            if self._oversize(r):
                done.append(Completion(r.req_id, [], status="rejected"))
            else:
                pending.append(r)
        active: Dict[int, Request] = {}
        while pending or active:
            while pending and len(active) < self.max_batch:
                r = pending.pop(0)
                self._start(r)
                active[r.req_id] = r
            for rid in [rid for rid, r in active.items()
                        if len(self.mgr.seqs[rid].out_tokens) >= r.max_new]:
                done.append(self._finish(rid))
                del active[rid]
            self._g_pending.set(float(len(pending)))
            self._g_active.set(float(len(active)))
            if not active:
                continue
            self._decode_step(sorted(active))
        return done

    def cache_mrc(self, capacities=None, **kw):
        """What-if MRC of the KV block pool at alternative HBM budgets
        (requires ``autotune=``) — see ``BlockPool.estimate_mrc``."""
        return self.pool.estimate_mrc(capacities, **kw)

    def obs_snapshot(self) -> "obs_mod.Snapshot":
        """One merged snapshot of the whole serving stack: engine
        latencies/queue depths + pool swaps + policy hit/flow counters
        (+ tuner, when autotuning)."""
        return obs_mod.merge([self.obs.snapshot(), self.pool.obs_snapshot()])

    @property
    def stats(self):
        return self.pool.stats, dict(self.pool.policy.flows)

    @property
    def degraded(self) -> bool:
        """True while the pool serves read-through (host IO shed by the
        circuit breaker under sustained injected/real failure)."""
        return self.pool.degraded


class EngineExecutor:
    """The ``Scheduler``'s executor surface over a ``ServingEngine``:
    prefill/decode/release run the model against the paged pool, and the
    capacity/backpressure reads come straight from the pool (pinned-
    block watermark, faults ``degraded`` flag)."""

    def __init__(self, eng: ServingEngine):
        self.eng = eng
        self.block_size = eng.pool.bs
        self.n_blocks = eng._max_seq_blocks()

    @property
    def degraded(self) -> bool:
        return self.eng.pool.degraded

    def free_fraction(self) -> float:
        return self.eng.pool.free_fraction()

    def prefill(self, r: SchedRequest) -> int:
        return self.eng._start(r.payload, tenant=r.tenant)

    def decode(self, ids: List[int]) -> Dict[int, int]:
        return self.eng._decode_step(ids)

    def release(self, rid: int) -> None:
        self.eng._finish(rid)
