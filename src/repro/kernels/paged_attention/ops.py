"""jit'd public wrapper for the paged-attention decode kernel."""

from __future__ import annotations

import functools

import jax

from repro.kernels.paged_attention.paged_attention import paged_attention_raw


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, kpool, vpool, block_tables, lengths, *,
                    interpret: bool = False):
    """q: (B, H, d); kpool/vpool: (N, bs, Hkv, d); block_tables: (B, nb)
    int32; lengths: (B,) int32 -> (B, H, d)."""
    return paged_attention_raw(q, kpool, vpool, block_tables, lengths,
                               interpret=interpret)
