"""Pure-jnp oracle for paged-attention decode: gather blocks through the
block table, mask by length, exact softmax (mirrors
repro.models.transformer.forward_decode_paged semantics)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def paged_attention_ref(q, kpool, vpool, block_tables, lengths):
    """q: (B, H, d); kpool/vpool: (N, bs, Hkv, d); block_tables: (B, nb);
    lengths: (B,) -> (B, H, d)."""
    B, H, d = q.shape
    _, bs, Hkv, _ = kpool.shape
    k = kpool[block_tables].reshape(B, -1, Hkv, d)   # (B, nb*bs, Hkv, d)
    v = vpool[block_tables].reshape(B, -1, Hkv, d)
    if Hkv != H:
        k = jnp.repeat(k, H // Hkv, axis=2)
        v = jnp.repeat(v, H // Hkv, axis=2)
    s = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (d ** 0.5)
    pos = jnp.arange(k.shape[1], dtype=jnp.int32)
    s = jnp.where((pos[None] < lengths[:, None])[:, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bkhd->bhd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
