"""Pallas TPU paged-attention decode kernel.

One query token per sequence attends to its KV scattered across pool
blocks, addressed through a block table.  The block table and per-
sequence lengths ride in scalar-prefetch (SMEM) so the K/V BlockSpec
index_map can dereference physical block ids while the grid walks logical
block indices — the TPU-idiomatic replacement for vLLM's gather (the pool
never moves; only block-table metadata, which is exactly the structure
Clock2Q+ manages, changes).

Shapes: q (B, H, d); kpool/vpool (N, bs, Hkv, d); block_tables (B, nb);
lengths (B,).  GQA handled by reshaping q to (Hkv, G, d) inside the
kernel.  Online softmax across the nb (arbitrary) grid dimension.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pltpu_compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            acc_ref, m_ref, l_ref, *, bs: int, n_q: int, n_kv: int,
            scale: float):
    b = pl.program_id(0)
    j = pl.program_id(1)
    nb = pl.num_programs(1)
    g = n_q // n_kv

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[b]
    run = j * bs < length  # skip blocks past this sequence's length

    @pl.when(run)
    def _compute():
        d = q_ref.shape[-1]
        q = q_ref[0].astype(jnp.float32)                  # (H, d)
        k = k_ref[0].astype(jnp.float32)                  # (bs, Hkv, d)
        v = v_ref[0].astype(jnp.float32)
        pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (bs, 1), 0)
        valid = pos < length
        k = jnp.where(valid[:, :, None] if k.ndim == 3 else valid, k, 0.0)
        v = jnp.where(valid[:, :, None], v, 0.0)
        qg = q.reshape(n_kv, g, d)
        # scores: (Hkv, G, bs)
        s = jax.lax.dot_general(
            qg, k.transpose(1, 2, 0), (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32) * scale
        s = jnp.where(valid[:, 0][None, None, :], s, NEG_INF)
        m_prev = m_ref[...]                               # (Hkv, G)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=2))
        p = jnp.exp(s - m_new[..., None])                 # (Hkv, G, bs)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=2)
        pv = jax.lax.dot_general(
            p, v.transpose(1, 0, 2), (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)           # (Hkv, G, d)
        acc_ref[...] = acc_ref[...] * alpha[..., None] + pv
        m_ref[...] = m_new

    @pl.when(j == nb - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[..., None]
        o_ref[0] = (acc_ref[...] / denom).reshape(n_q, d_of(o_ref)) \
            .astype(o_ref.dtype)


def d_of(ref):
    return ref.shape[-1]


def paged_attention_raw(q, kpool, vpool, block_tables, lengths, *,
                        interpret: bool = False):
    """q: (B, H, d); kpool/vpool: (N, bs, Hkv, d);
    block_tables: (B, nb) int32; lengths: (B,) int32 -> (B, H, d)."""
    B, H, d = q.shape
    N, bs, Hkv, _ = kpool.shape
    nb = block_tables.shape[1]
    scale = 1.0 / (d ** 0.5)
    kern = functools.partial(_kernel, bs=bs, n_q=H, n_kv=Hkv, scale=scale)

    def kv_map(b, j, bt_ref, len_ref):
        return (bt_ref[b, j], 0, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, nb),
        in_specs=[
            pl.BlockSpec((1, H, d), lambda b, j, bt, ln: (b, 0, 0)),
            pl.BlockSpec((1, bs, Hkv, d), kv_map),
            pl.BlockSpec((1, bs, Hkv, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, H, d), lambda b, j, bt, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hkv, H // Hkv, d), jnp.float32),
            pltpu.VMEM((Hkv, H // Hkv), jnp.float32),
            pltpu.VMEM((Hkv, H // Hkv), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, d), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables, lengths, q, kpool, vpool)
