"""Version-portability shims for the Pallas TPU API.

jax < 0.5 spells the compiler-params dataclass ``TPUCompilerParams``;
newer releases renamed it ``CompilerParams``.  Kernels import the name
from here so the next rename lands in one place.
"""

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams
