"""Pure-jnp oracle for flash attention (exact softmax)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True):
    """q/k/v: (BH, S, d)."""
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (d ** 0.5)
    if causal:
        Sq, Skv = q.shape[1], k.shape[1]
        qpos = jax.lax.broadcasted_iota(jnp.int32, (Sq, Skv), 0)
        kpos = jax.lax.broadcasted_iota(jnp.int32, (Sq, Skv), 1)
        s = jnp.where((kpos <= qpos)[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
