"""jit'd public wrapper: (B, S, H, hd) GQA attention via the Pallas flash
kernel, with head-dim padding to the 128-lane MXU boundary and KV-head
repetition for grouped queries."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_bh


@functools.partial(jax.jit, static_argnames=("causal", "block_q",
                                             "block_kv", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_kv: int = 128, interpret: bool = False):
    """q: (B, Sq, H, hd); k/v: (B, Skv, Hkv, hd) -> (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    Hkv = k.shape[2]
    if H != Hkv:
        rep = H // Hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    pad = (-hd) % 128
    if pad:
        q = jnp.pad(q, [(0, 0), (0, 0), (0, 0), (0, pad)])
        k = jnp.pad(k, [(0, 0), (0, 0), (0, 0), (0, pad)])
        v = jnp.pad(v, [(0, 0), (0, 0), (0, 0), (0, pad)])
    # scale uses the PADDED dim inside the kernel; compensate so softmax
    # temperature matches the true head_dim.
    scale_fix = ((hd + pad) / hd) ** 0.5
    qb = (q * scale_fix).transpose(0, 2, 1, 3).reshape(B * H, Sq, hd + pad)
    kb = k.transpose(0, 2, 1, 3).reshape(B * H, -1, hd + pad)
    vb = v.transpose(0, 2, 1, 3).reshape(B * H, -1, hd + pad)
    o = flash_attention_bh(qb, kb, vb, causal=causal, block_q=block_q,
                           block_kv=block_kv, interpret=interpret)
    o = o.reshape(B, H, Sq, hd + pad).transpose(0, 2, 1, 3)
    return o[..., :hd]
