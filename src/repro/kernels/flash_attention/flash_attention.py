"""Pallas TPU flash attention (causal / full), online-softmax over KV
blocks.

Grid: (batch*heads, n_q_blocks, n_kv_blocks) with the KV dimension
"arbitrary" (sequential) so the f32 accumulator/max/sum scratch persists
across KV blocks in VMEM.  Block shapes are (block_q, head_dim) /
(block_kv, head_dim); head_dim is MXU-lane aligned by the ops.py wrapper.
Causal q-blocks skip fully-masked KV blocks via @pl.when.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pltpu_compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            causal: bool, block_q: int, block_kv: int, scale: float,
            kv_seq_len: int):
    i = pl.program_id(1)
    j = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    run = (~causal) | (j * block_kv <= i * block_q + (block_q - 1))

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0].astype(jnp.float32)          # (bkv, d)
        v = v_ref[0].astype(jnp.float32)
        # ragged tail: zero padded kv rows (OOB block reads are undefined)
        krow = j * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, (block_kv, 1), 0)
        kvalid = krow < kv_seq_len
        k = jnp.where(kvalid, k, 0.0)
        v = jnp.where(kvalid, v, 0.0)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = i * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 0)
        kpos = j * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 1)
        mask = kpos < kv_seq_len
        if causal:
            mask = mask & (kpos <= qpos)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_bh(q, k, v, *, causal: bool = True, block_q: int = 128,
                       block_kv: int = 128, interpret: bool = False):
    """q/k/v: (BH, S, d) with BH = batch*heads (kv already repeated)."""
    BH, Sq, d = q.shape
    Skv = k.shape[1]
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    nq = pl.cdiv(Sq, block_q)
    nk = pl.cdiv(Skv, block_kv)
    scale = 1.0 / (d ** 0.5)
    kern = functools.partial(_kernel, causal=causal, block_q=block_q,
                             block_kv=block_kv, scale=scale,
                             kv_seq_len=Skv)
    return pl.pallas_call(
        kern,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
