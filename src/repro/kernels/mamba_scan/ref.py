"""Pure-jnp oracle: direct sequential selective-scan recurrence."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def mamba_scan_ref(u, dt, Bc, Cc, A_log):
    """u/dt: (B, S, din); Bc/Cc: (B, S, N); A_log: (din, N) -> (B, S, din)."""
    A = -jnp.exp(A_log.astype(jnp.float32))
    B_, S, din = u.shape

    def step(h, inp):
        ut, dtt, bt, ct = inp
        dA = jnp.exp(dtt[..., None] * A)                      # (B, din, N)
        h = dA * h + (dtt * ut)[..., None] * bt[:, None, :]
        y = jnp.sum(h * ct[:, None, :], axis=-1)
        return h, y

    h0 = jnp.zeros((B_, din, A.shape[-1]), jnp.float32)
    sw = lambda t: jnp.swapaxes(t.astype(jnp.float32), 0, 1)
    _, ys = jax.lax.scan(step, h0, (sw(u), sw(dt), sw(Bc), sw(Cc)))
    return jnp.swapaxes(ys, 0, 1).astype(u.dtype)
