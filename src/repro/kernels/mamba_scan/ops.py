"""jit'd public wrapper for the selective-scan kernel."""

from __future__ import annotations

import functools

import jax

from repro.kernels.mamba_scan.mamba_scan import mamba_scan_raw


@functools.partial(jax.jit, static_argnames=("d_block", "chunk",
                                             "interpret"))
def mamba_scan(u, dt, Bc, Cc, A_log, *, d_block: int = 512,
               chunk: int = 64, interpret: bool = False):
    return mamba_scan_raw(u, dt, Bc, Cc, A_log, d_block=d_block,
                          chunk=chunk, interpret=interpret)
