"""Pallas TPU selective-scan (Mamba1 core) kernel.

Grid: (batch, d_inner blocks, chunks); the chunk dimension is sequential
("arbitrary") and carries the recurrent state h (d_blk, N) in VMEM
scratch — the TPU-native replacement for the CUDA parallel-scan kernel:
HBM traffic is one read of (u, dt, B, C) and one write of y per element,
with the state never leaving VMEM.  Inside a chunk the recurrence runs as
a fori_loop of VPU vector ops over (d_blk, N) tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pltpu_compat import CompilerParams as _CompilerParams


def _kernel(u_ref, dt_ref, b_ref, c_ref, alog_ref, y_ref, h_ref, *,
            chunk: int, seq_len: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    A = -jnp.exp(alog_ref[...].astype(jnp.float32))      # (d_blk, N)

    def step(t, h):
        # global position for ragged tails: identity update when past end
        valid = (c * chunk + t) < seq_len
        dt = dt_ref[0, t].astype(jnp.float32)            # (d_blk,)
        dt = jnp.where(valid, dt, 0.0)
        u = u_ref[0, t].astype(jnp.float32)              # (d_blk,)
        bb = b_ref[0, t].astype(jnp.float32)             # (N,)
        cc = c_ref[0, t].astype(jnp.float32)             # (N,)
        dA = jnp.exp(dt[:, None] * A)                    # (d_blk, N)
        h = dA * h + (dt * u)[:, None] * bb[None, :]
        y = jnp.sum(h * cc[None, :], axis=1)             # (d_blk,)
        # dslice(0, 1) rather than int 0: older pallas interpret-mode
        # discharge rules reject scalar int indices in store()
        pl.store(y_ref, (pl.dslice(0, 1), pl.dslice(t, 1), slice(None)),
                 y.astype(y_ref.dtype)[None, None, :])
        return h

    h_ref[...] = jax.lax.fori_loop(0, chunk, step, h_ref[...])


def mamba_scan_raw(u, dt, Bc, Cc, A_log, *, d_block: int = 512,
                   chunk: int = 64, interpret: bool = False):
    """u/dt: (B, S, din); Bc/Cc: (B, S, N); A_log: (din, N) -> y (B, S, din)."""
    B, S, din = u.shape
    N = Bc.shape[-1]
    d_block = min(d_block, din)
    chunk = min(chunk, S)
    nd = pl.cdiv(din, d_block)
    nc = pl.cdiv(S, chunk)
    kern = functools.partial(_kernel, chunk=chunk, seq_len=S)
    return pl.pallas_call(
        kern,
        grid=(B, nd, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, d_block), lambda b, i, c: (b, c, i)),
            pl.BlockSpec((1, chunk, d_block), lambda b, i, c: (b, c, i)),
            pl.BlockSpec((1, chunk, N), lambda b, i, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, i, c: (b, c, 0)),
            pl.BlockSpec((d_block, N), lambda b, i, c: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, d_block), lambda b, i, c: (b, c, i)),
        out_shape=jax.ShapeDtypeStruct((B, S, din), u.dtype),
        scratch_shapes=[pltpu.VMEM((d_block, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(u, dt, Bc, Cc, A_log)
