"""Pallas TPU Clock2Q+ trace-replay kernel (lane-parallel simulation).

The paper's hot path — per-access hash lookup + ref-bit update — is
pointer-chasing on CPU.  The TPU adaptation (DESIGN.md §3): many
independent simulations run as VPU lanes, and lookup is a brute-force
vector compare of the requested key against the resident-key arrays held
entirely in VMEM (for the parameter sweeps cache research needs, C <= a
few thousand, compare-all beats emulating a hash).  Eviction clock sweeps
are bounded masked fori_loops (<= 2M iterations), so the kernel has no
data-dependent control flow — fully TPU-lowerable.

State layout per lane block (LANES x slots, int32):
  skey/sref/sseq + spos/seqctr   — Small FIFO ring + correlation window
  mkey/mref + hand               — Main Clock
  gkey + gpos                    — Ghost ring
Trace: (LANES, T) int32; output: hits (LANES, T) int32 + final state
(aliased).  Semantics bit-match repro.core.jax_engine c2qp (skip_limit=0)
and therefore the pure-Python reference zoo.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _onehot_put(arr, rows_mask, col_idx, values):
    """arr: (L, C); write values (L,) at [l, col_idx[l]] where rows_mask."""
    L, C = arr.shape
    cols = jax.lax.broadcasted_iota(jnp.int32, (L, C), 1)
    sel = rows_mask[:, None] & (cols == col_idx[:, None])
    if values.ndim == 1:
        values = values[:, None]
    return jnp.where(sel, values, arr)


def _lookup(keys, key):
    """keys: (L, C), key: (L,) -> (found (L,), slot (L,))."""
    eq = keys == key[:, None]
    return jnp.any(eq, axis=1), jnp.argmax(eq, axis=1).astype(jnp.int32)


def _kernel(trace_ref, skey_ref, sref_ref, sseq_ref, mkey_ref, mref_ref,
            gkey_ref, scal_ref, hits_ref, skey_o, sref_o, sseq_o, mkey_o,
            mref_o, gkey_o, scal_o, *, T: int, window: int):
    Lb, S = skey_ref.shape
    M = mkey_ref.shape[1]
    G = gkey_ref.shape[1]

    def sweep_insert(mkey, mref, hand, ins_key, active):
        """Masked clock sweep + insert for lanes with active; returns
        updated (mkey, mref, hand)."""
        def body(_, carry):
            mkey, mref, hand, done = carry
            cur_key = jnp.take_along_axis(mkey, hand[:, None], axis=1)[:, 0]
            cur_ref = jnp.take_along_axis(mref, hand[:, None], axis=1)[:, 0]
            skip = active & ~done & (cur_key >= 0) & (cur_ref > 0)
            take = active & ~done & ~skip
            mref = _onehot_put(mref, skip, hand, jnp.zeros((Lb,), jnp.int32))
            # take: write new key at hand, clear ref
            mkey = _onehot_put(mkey, take, hand, ins_key)
            mref = _onehot_put(mref, take, hand, jnp.zeros((Lb,), jnp.int32))
            hand = jnp.where(active & ~done, (hand + 1) % M, hand)
            done = done | take
            return mkey, mref, hand, done

        done0 = ~active
        mkey, mref, hand, _ = jax.lax.fori_loop(
            0, 2 * M + 1, body, (mkey, mref, hand, done0))
        return mkey, mref, hand

    def step(t, carry):
        (skey, sref, sseq, mkey, mref, gkey,
         spos, seqctr, hand, gpos) = carry
        key = trace_ref[:, t]

        in_s, s_slot = _lookup(skey, key)
        in_m, m_slot = _lookup(mkey, key)
        in_g, g_slot = _lookup(gkey, key)
        hit = in_s | in_m
        pl.store(hits_ref, (slice(None), pl.dslice(t, 1)),
                 hit.astype(jnp.int32)[:, None])

        # case small-hit: set ref if aged past the correlation window
        age = seqctr - jnp.take_along_axis(sseq, s_slot[:, None], axis=1)[:, 0]
        sref = _onehot_put(sref, in_s & (age >= window), s_slot,
                           jnp.ones((Lb,), jnp.int32))
        # case main-hit: set ref
        mref = _onehot_put(mref, in_m, m_slot, jnp.ones((Lb,), jnp.int32))

        # case ghost-hit: tombstone + insert straight into Main Clock
        ghost_case = ~hit & in_g
        gkey = _onehot_put(gkey, ghost_case, g_slot,
                           jnp.full((Lb,), -1, jnp.int32))

        # case new: displace the small-ring slot at the cursor
        new_case = ~hit & ~in_g
        displaced = jnp.take_along_axis(skey, spos[:, None], axis=1)[:, 0]
        disp_ref = jnp.take_along_axis(sref, spos[:, None], axis=1)[:, 0]
        has_disp = new_case & (displaced >= 0)
        promote = has_disp & (disp_ref > 0)
        demote = has_disp & (disp_ref == 0)

        # one main insert per lane (ghost-hit XOR promotion)
        ins_active = ghost_case | promote
        ins_key = jnp.where(ghost_case, key, displaced)
        mkey, mref, hand = sweep_insert(mkey, mref, hand, ins_key,
                                        ins_active)

        # ghost ring push for demotions
        old_g = jnp.take_along_axis(gkey, gpos[:, None], axis=1)[:, 0]
        gkey = _onehot_put(gkey, demote, gpos, displaced)
        gpos = jnp.where(demote, (gpos + 1) % G, gpos)

        # write the new key into the small ring
        skey = _onehot_put(skey, new_case, spos, key)
        sref = _onehot_put(sref, new_case, spos, jnp.zeros((Lb,), jnp.int32))
        sseq = _onehot_put(sseq, new_case, spos, seqctr)
        spos = jnp.where(new_case, (spos + 1) % S, spos)
        seqctr = jnp.where(new_case, seqctr + 1, seqctr)

        return (skey, sref, sseq, mkey, mref, gkey,
                spos, seqctr, hand, gpos)

    spos = scal_ref[:, 0]
    seqctr = scal_ref[:, 1]
    hand = scal_ref[:, 2]
    gpos = scal_ref[:, 3]
    carry = (skey_ref[...], sref_ref[...], sseq_ref[...], mkey_ref[...],
             mref_ref[...], gkey_ref[...], spos, seqctr, hand, gpos)
    carry = jax.lax.fori_loop(0, T, step, carry)
    (skey, sref, sseq, mkey, mref, gkey, spos, seqctr, hand, gpos) = carry
    skey_o[...] = skey
    sref_o[...] = sref
    sseq_o[...] = sseq
    mkey_o[...] = mkey
    mref_o[...] = mref
    gkey_o[...] = gkey
    scal_o[...] = jnp.stack([spos, seqctr, hand, gpos], axis=1)


def cache_sim_raw(trace, skey, sref, sseq, mkey, mref, gkey, scal, *,
                  window: int, interpret: bool = False):
    """All state (LANES, ·) int32; trace (LANES, T).  Returns
    (hits (LANES, T) int32, skey, sref, sseq, mkey, mref, gkey, scal)."""
    L, T = trace.shape
    kern = functools.partial(_kernel, T=T, window=window)
    state = (skey, sref, sseq, mkey, mref, gkey, scal)
    blk = lambda shape: pl.BlockSpec(shape, lambda i: (0,) * len(shape))
    outs = pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[blk(trace.shape)] + [blk(a.shape) for a in state],
        out_specs=[blk((L, T))] + [blk(a.shape) for a in state],
        out_shape=[jax.ShapeDtypeStruct((L, T), jnp.int32)]
        + [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in state],
        interpret=interpret,
    )(trace, *state)
    return outs
