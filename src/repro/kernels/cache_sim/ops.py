"""jit'd public wrapper: lane-parallel Clock2Q+ trace replay.

``simulate_lanes(traces, capacity, ...)`` builds fresh state, replays all
lanes in one kernel launch, and returns per-lane miss ratios + hits.
Sizing follows the paper: Small = 10%, Main = 90%, Ghost = 50%, window =
50% of the Small FIFO.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.engine.layout import c2qp_sizes
from repro.kernels.cache_sim.cache_sim import cache_sim_raw


def init_state(n_lanes: int, capacity: int, *, small_frac: float = 0.1,
               ghost_frac: float = 0.5):
    S, M, G, _ = c2qp_sizes(capacity, small_frac, ghost_frac)
    z = lambda c: jnp.zeros((n_lanes, c), jnp.int32)
    e = lambda c: jnp.full((n_lanes, c), -1, jnp.int32)
    return dict(skey=e(S), sref=z(S), sseq=z(S), mkey=e(M), mref=z(M),
                gkey=e(G), scal=z(4))


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def replay(trace, state, *, window: int, interpret: bool = False):
    outs = cache_sim_raw(trace, state["skey"], state["sref"], state["sseq"],
                         state["mkey"], state["mref"], state["gkey"],
                         state["scal"], window=window, interpret=interpret)
    hits = outs[0]
    new_state = dict(zip(("skey", "sref", "sseq", "mkey", "mref", "gkey",
                          "scal"), outs[1:]))
    return hits, new_state


def simulate_lanes(traces, capacity: int, *, window_frac: float = 0.5,
                   small_frac: float = 0.1, ghost_frac: float = 0.5,
                   interpret: bool = True):
    """traces: (LANES, T) int32 -> (miss_ratios (LANES,), hits (LANES, T))."""
    traces = jnp.asarray(traces, jnp.int32)
    L = traces.shape[0]
    _, _, _, window = c2qp_sizes(capacity, small_frac, ghost_frac,
                                 window_frac)
    state = init_state(L, capacity, small_frac=small_frac,
                       ghost_frac=ghost_frac)
    hits, _ = replay(traces, state, window=window, interpret=interpret)
    mr = 1.0 - jnp.mean(hits.astype(jnp.float32), axis=1)
    return mr, hits
