"""Oracle for the cache_sim kernel: the location-table JAX engine
(repro.core.jax_engine), itself bit-verified against the pure-Python
reference zoo."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import jax_engine as je


def cache_sim_ref(traces: np.ndarray, capacity: int, *,
                  window_frac: float = 0.5, small_frac: float = 0.1,
                  ghost_frac: float = 0.5):
    """traces: (LANES, T) -> hits (LANES, T) bool."""
    traces = np.asarray(traces)
    universe = int(traces.max()) + 1
    out = []
    for lane in traces:
        st = je.init_state("clock2q+", capacity, universe,
                           small_frac=small_frac, ghost_frac=ghost_frac,
                           window_frac=window_frac)
        _, hits = je.replay("clock2q+", st, jnp.asarray(lane, jnp.int32))
        out.append(np.asarray(hits))
    return np.stack(out)
