"""Oracle for the cache_sim kernel: the capacity-masked policy core
(repro.core.engine), itself bit-verified against the pure-Python
reference zoo."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.engine import get_engine


def cache_sim_ref(traces: np.ndarray, capacity: int, *,
                  window_frac: float = 0.5, small_frac: float = 0.1,
                  ghost_frac: float = 0.5):
    """traces: (LANES, T) -> hits (LANES, T) bool."""
    traces = np.asarray(traces)
    universe = int(traces.max()) + 1
    eng = get_engine("clock2q+")
    out = []
    for lane in traces:
        st = eng.init(capacity, universe, small_frac=small_frac,
                      ghost_frac=ghost_frac, window_frac=window_frac)
        _, hits = eng.replay(st, jnp.asarray(lane, jnp.int32))
        out.append(np.asarray(hits))
    return np.stack(out)
