"""Clock2Q+ cache substrate — the paper's contribution.

Reference policy zoo (pure Python, the correctness oracles), trace
generation/derivation, the vectorized JAX simulation engine, and the
production-style array implementation with live resizing.
"""

from repro.core.policy import (  # noqa: F401
    CachePolicy, SimResult, make_policy, policy_names, register,
)
import repro.core.policies  # noqa: F401  (registers the zoo)
from repro.core import stats, traces  # noqa: F401
