"""The masked-scatter primitive shared by every capacity-masked step.

Batched grid lanes diverge, so the steps avoid ``lax.switch``/``cond``
(which would SELECT whole state arrays — copying each lane's
(universe,)-sized location tables several times per request) and are
written as straight-line code over mutually-exclusive case masks, with
``mset`` as the single write primitive.
"""

from __future__ import annotations

import jax.numpy as jnp


def mset(arr: jnp.ndarray, i, val, mask) -> jnp.ndarray:
    """Masked single-slot scatter: ``arr[i] = val`` where ``mask``, else
    unchanged (the False branch rewrites ``arr[i]`` to itself, so a
    garbage/negative ``i`` under a False mask is harmless)."""
    return arr.at[i].set(jnp.where(mask, val, arr[i]))
