"""Capacity-masked S3-FIFO step (faithful: FIFO-with-reinsertion main,
saturating freq counters, ghost tombstone ring).

Same masked-layout discipline as ``engine.clock2qplus``.  The main
ring's evict-from-head-with-reinsertion walk is computed in closed form
instead of a ``lax.while_loop`` (which would lock-step vmap lanes):

With a full ring, a slot at cyclic distance ``d(i) = (i - mhead) mod
mcap`` holding freq ``f(i)`` is visited at walk positions ``d, d +
mcap, d + 2*mcap, ...``; each visit with freq >= 1 reinserts (rotating
in place — the popleft+append of the deque reference reuses the slot)
and decrements, so the slot first presents freq 0 at position ``d(i) +
f(i)*mcap``.  The walk evicts at the FIRST position whose slot presents
freq 0, i.e. ``p = min_i(d(i) + f(i)*mcap)`` — capped by ``skip_limit``
reinsertions when one is set (0 = unlimited).  Every visit before ``p``
was a reinsertion, so slot ``i`` loses ``ceil((p - d(i)) / mcap)``
freq; the victim is ``(mhead + p) % mcap`` and the head advances past
it.  Eviction then insertion at the tail lands the new key in the
victim's slot, exactly like the loop it replaces.

Hit/miss parity (1- and 2-bit) with the pure-Python zoo is asserted in
tests/test_jax_engine.py and fuzzed in tests/test_engine_fuzz.py.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax.numpy as jnp

from repro.core.engine.layout import (
    EMPTY, W_GHOST, W_MAIN, W_NONE, W_SMALL, SweepConfig, sq_sizes,
)
from repro.core.engine.masked import mset as _mset

_BIG = 2**30  # above any reachable walk position; far from int32 overflow


def sizes(cfg: SweepConfig) -> Tuple[int, int, int]:
    """Physical (small, main, ghost) ring sizes for ``cfg``."""
    return sq_sizes(cfg.capacity, cfg.small_frac, cfg.ghost_frac)


def init(cfg: SweepConfig, universe: int,
         phys: Optional[Tuple[int, int, int]] = None) -> Dict:
    """Masked S3-FIFO state (``phys`` pads the rings to grid maxima)."""
    S, M, G = sizes(cfg)
    pS, pM, pG = phys if phys is not None else (S, M, G)
    return dict(
        skey=jnp.full((pS,), EMPTY), sfreq=jnp.zeros((pS,), jnp.int32),
        spos=jnp.int32(0),
        mkey=jnp.full((pM,), EMPTY), mfreq=jnp.zeros((pM,), jnp.int32),
        mhead=jnp.int32(0), mcount=jnp.int32(0),
        gkey=jnp.full((pG,), EMPTY), gpos=jnp.int32(0),
        loc_w=jnp.zeros((universe,), jnp.int8),
        loc_s=jnp.zeros((universe,), jnp.int32),
        freq_cap=jnp.int32(1 if cfg.bits == 1 else 3),
        promote_at=jnp.int32(1 if cfg.bits == 1 else 2),
        scap=jnp.int32(S), mcap=jnp.int32(M), gcap=jnp.int32(G),
        skip_limit=jnp.int32(cfg.skip_limit),
    )


def step(st: Dict, key) -> Tuple[Dict, jnp.ndarray]:
    """One S3-FIFO transition: ``(state, key) -> (state, hit)``."""
    active = key >= 0  # key < 0: padding sentinel, whole step is a no-op
    key = jnp.maximum(key, 0)
    where = st["loc_w"][key]
    slot = st["loc_s"][key]
    is_small = active & (where == W_SMALL)
    is_main = active & (where == W_MAIN)
    is_ghost = active & (where == W_GHOST)
    is_none = active & (where == W_NONE)
    hit = is_small | is_main

    # -- hits: saturating freq bumps ------------------------------------------
    sfreq = _mset(st["sfreq"], slot,
                  jnp.minimum(st["freq_cap"], st["sfreq"][slot] + 1), is_small)
    mfreq = _mset(st["mfreq"], slot,
                  jnp.minimum(st["freq_cap"], st["mfreq"][slot] + 1), is_main)

    # -- ghost hit: leave the ghost ring, then insert into main ---------------
    gkey = _mset(st["gkey"], slot, EMPTY, is_ghost)
    loc_w = _mset(st["loc_w"], key, W_NONE, is_ghost)
    loc_s = st["loc_s"]

    # -- miss: displace the small-FIFO cursor slot ----------------------------
    spos = st["spos"]
    displaced = st["skey"][spos]
    disp = is_none & (displaced >= 0)
    disp_promote = disp & (sfreq[spos] >= st["promote_at"])
    disp_demote = disp & ~(sfreq[spos] >= st["promote_at"])
    loc_w = _mset(loc_w, displaced, W_NONE, disp)

    # demote path: ghost-push the displaced key
    g = st["gpos"]
    gold = gkey[g]
    loc_w = _mset(loc_w, gold, W_NONE, disp_demote & (gold >= 0))
    gkey = _mset(gkey, g, displaced, disp_demote)
    loc_w = _mset(loc_w, displaced, W_GHOST, disp_demote)
    loc_s = _mset(loc_s, displaced, g, disp_demote)
    gpos = jnp.where(disp_demote, (g + 1) % st["gcap"], g)

    # -- main insert: closed-form FIFO-with-reinsertion (see module doc) ------
    do_ins = is_ghost | disp_promote
    ins_key = jnp.where(is_ghost, key, displaced)
    M = st["mkey"].shape[-1]  # physical ring size — static
    mcap, mhead, mcount = st["mcap"], st["mhead"], st["mcount"]
    idx = jnp.arange(M)
    valid = idx < mcap
    full = mcount >= mcap
    need_evict = do_ins & full
    d = jnp.where(valid, (idx - mhead) % mcap, 0)
    # first walk position at which slot i presents freq 0 (freq <= 3, so
    # at most freq_cap full laps; scores stay far below int32 range)
    big = jnp.int32(_BIG)
    score = jnp.where(valid, d + mfreq * mcap, big)
    p = jnp.min(score)
    p = jnp.where(st["skip_limit"] > 0,
                  jnp.minimum(p, st["skip_limit"]), p)
    # every visit before position p was a reinsertion: decrement its slot
    visits = jnp.where(valid, jnp.maximum(0, -((d - p) // mcap)), 0)
    mfreq = jnp.where(need_evict, mfreq - visits, mfreq)
    ms = jnp.where(full, (mhead + p) % mcap,
                   (mhead + mcount) % mcap)  # tail slot when not full
    victim = st["mkey"][ms]
    loc_w = _mset(loc_w, victim, W_NONE, need_evict & (victim >= 0))
    loc_w = _mset(loc_w, ins_key, W_MAIN, do_ins)
    loc_s = _mset(loc_s, ins_key, ms, do_ins)
    mkey = _mset(st["mkey"], ms, ins_key, do_ins)
    mfreq = _mset(mfreq, ms, 0, do_ins)
    mhead = jnp.where(need_evict, (mhead + p + 1) % mcap, mhead)
    mcount = jnp.where(do_ins & ~full, mcount + 1, mcount)

    # -- miss: the new key enters the small FIFO ------------------------------
    skey = _mset(st["skey"], spos, key, is_none)
    sfreq = _mset(sfreq, spos, 0, is_none)
    loc_w = _mset(loc_w, key, W_SMALL, is_none)
    loc_s = _mset(loc_s, key, spos, is_none)
    spos = jnp.where(is_none, (spos + 1) % st["scap"], spos)

    st = dict(st, skey=skey, sfreq=sfreq, spos=spos,
              mkey=mkey, mfreq=mfreq, mhead=mhead, mcount=mcount,
              gkey=gkey, gpos=gpos, loc_w=loc_w, loc_s=loc_s)
    return st, hit
