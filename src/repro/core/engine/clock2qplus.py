"""THE canonical capacity-masked Clock2Q+ step (paper §3).

This is the single implementation of the Clock2Q+ state machine on the
JAX lane: the serial ``core.jax_engine`` replay, the batched MRC sweep
(``tuning.sweep``) and the conformance suite all call this exact
function.  A fixed-size single configuration is just the degenerate
mask (physical sizes == logical sizes).

The step is masked, not branched — two deliberate structural choices,
both semantics-preserving (locked hit-for-hit against the pure-Python
reference zoo and ``ProdClock2QPlus`` by tests/test_conformance.py) and
both essential for grid throughput under vmap:

  1. No lax.switch/cond.  Batched lanes diverge, so a switch executes
     every branch and SELECTS whole state arrays — copying each lane's
     (universe,)-sized location tables several times per request.  The
     four cases are mutually exclusive per lane, so the step is written
     as straight-line code with masked single-slot scatters (a False
     mask rewrites the current value — a no-op).
  2. No lax.while_loop for the clock sweep.  Lanes would advance in
     lock-step.  The sweep is deterministic, so the victim is computed
     in closed form: with cyclic distance ``d(slot) = (slot - hand)
     mod mcap`` and ``skippable = occupied & ref``, the hand stops at
     ``vd = min(first non-skippable d, skip_limit)`` (a full fruitless
     lap clears every ref and takes the hand slot, ``vd = mcap``),
     clearing the refs of exactly the ``d < vd`` slots it walked over.

State layout: queue arrays at PHYSICAL (padded) sizes, logical segment
sizes (``scap``/``mcap``/``gcap``) as scalars in the state, cursors
wrapped modulo the logical sizes.  Padded slots start EMPTY and no
cursor ever reaches them.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax.numpy as jnp

from repro.core.engine.layout import (
    EMPTY, W_GHOST, W_MAIN, W_NONE, W_SMALL, SweepConfig, c2qp_sizes,
)
from repro.core.engine.masked import mset as _mset


def sizes(cfg: SweepConfig) -> Tuple[int, int, int]:
    """Logical queue-array sizes (small, main, ghost) for one config."""
    S, M, G, _ = c2qp_sizes(cfg.capacity, cfg.small_frac, cfg.ghost_frac,
                            cfg.window_frac)
    return S, M, G


def init(cfg: SweepConfig, universe: int,
         phys: Optional[Tuple[int, int, int]] = None) -> Dict:
    """Masked state for one configuration.  ``phys`` pads the queue
    arrays to grid-wide maxima (vmap lanes must share shapes); None
    means the degenerate mask (physical == logical)."""
    S, M, G, W = c2qp_sizes(cfg.capacity, cfg.small_frac, cfg.ghost_frac,
                            cfg.window_frac)
    pS, pM, pG = phys if phys is not None else (S, M, G)
    return dict(
        skey=jnp.full((pS,), EMPTY), sref=jnp.zeros((pS,), jnp.bool_),
        sseq=jnp.zeros((pS,), jnp.int32), spos=jnp.int32(0),
        seqctr=jnp.int32(0),
        mkey=jnp.full((pM,), EMPTY), mref=jnp.zeros((pM,), jnp.bool_),
        hand=jnp.int32(0),
        gkey=jnp.full((pG,), EMPTY), gpos=jnp.int32(0),
        loc_w=jnp.zeros((universe,), jnp.int8),
        loc_s=jnp.zeros((universe,), jnp.int32),
        scap=jnp.int32(S), mcap=jnp.int32(M), gcap=jnp.int32(G),
        window=jnp.int32(W), skip_limit=jnp.int32(cfg.skip_limit),
    )


def step(st: Dict, key: jnp.ndarray) -> Tuple[Dict, jnp.ndarray]:
    """One Clock2Q+ transition: ``(state, key) -> (state, hit)``."""
    # key < 0 is a padding sentinel: every case mask goes False, so the
    # step is a no-op and the (non-)hit never counts.  Lets callers pad
    # traces to a bucketed length and reuse the compiled sweep.
    active = key >= 0
    key = jnp.maximum(key, 0)
    where = st["loc_w"][key]
    slot = st["loc_s"][key]
    is_small = active & (where == W_SMALL)
    is_main = active & (where == W_MAIN)
    is_ghost = active & (where == W_GHOST)
    is_none = active & (where == W_NONE)
    hit = is_small | is_main

    # -- hits: ref-bit updates (small obeys the correlation window) -----------
    age_ok = (st["seqctr"] - st["sseq"][slot]) >= st["window"]
    sref = _mset(st["sref"], slot, st["sref"][slot] | age_ok, is_small)
    mref = _mset(st["mref"], slot, True, is_main)

    # -- ghost hit: leave the ghost ring, then insert into main ---------------
    gkey = _mset(st["gkey"], slot, EMPTY, is_ghost)
    loc_w = _mset(st["loc_w"], key, W_NONE, is_ghost)
    loc_s = st["loc_s"]

    # -- miss: displace the small-FIFO cursor slot ----------------------------
    spos = st["spos"]
    displaced = st["skey"][spos]
    disp = is_none & (displaced >= 0)
    disp_promote = disp & sref[spos]
    disp_demote = disp & ~sref[spos]
    loc_w = _mset(loc_w, displaced, W_NONE, disp)

    # demote path: ghost-push the displaced key
    g = st["gpos"]
    gold = gkey[g]
    loc_w = _mset(loc_w, gold, W_NONE, disp_demote & (gold >= 0))
    gkey = _mset(gkey, g, displaced, disp_demote)
    loc_w = _mset(loc_w, displaced, W_GHOST, disp_demote)
    loc_s = _mset(loc_s, displaced, g, disp_demote)
    gpos = jnp.where(disp_demote, (g + 1) % st["gcap"], g)

    # -- main insert (ghost hit or promoted displacee): closed-form clock -----
    do_ins = is_ghost | disp_promote
    ins_key = jnp.where(is_ghost, key, displaced)
    M = st["mkey"].shape[-1]  # physical (padded) ring size — static
    mcap, hand = st["mcap"], st["hand"]
    idx = jnp.arange(M)
    valid = idx < mcap
    d = jnp.where(valid, (idx - hand) % mcap, M + 1)
    skippable = (st["mkey"] >= 0) & mref
    k = jnp.min(jnp.where(valid & ~skippable, d, M + 1))
    k = jnp.minimum(k, mcap)  # no non-skippable slot: full lap
    vd = jnp.where(st["skip_limit"] > 0,
                   jnp.minimum(k, st["skip_limit"]), k)
    ms = (hand + vd) % mcap
    mref = jnp.where(do_ins, mref & ~(valid & (d < vd)), mref)
    victim = st["mkey"][ms]
    loc_w = _mset(loc_w, victim, W_NONE, do_ins & (victim >= 0))
    loc_w = _mset(loc_w, ins_key, W_MAIN, do_ins)
    loc_s = _mset(loc_s, ins_key, ms, do_ins)
    mkey = _mset(st["mkey"], ms, ins_key, do_ins)
    mref = _mset(mref, ms, False, do_ins)
    hand = jnp.where(do_ins, (ms + 1) % mcap, hand)

    # -- miss: the new key enters the small FIFO ------------------------------
    skey = _mset(st["skey"], spos, key, is_none)
    sref = _mset(sref, spos, False, is_none)
    sseq = _mset(st["sseq"], spos, st["seqctr"], is_none)
    loc_w = _mset(loc_w, key, W_SMALL, is_none)
    loc_s = _mset(loc_s, key, spos, is_none)
    spos = jnp.where(is_none, (spos + 1) % st["scap"], spos)
    seqctr = jnp.where(is_none, st["seqctr"] + 1, st["seqctr"])

    st = dict(st, skey=skey, sref=sref, sseq=sseq, spos=spos, seqctr=seqctr,
              mkey=mkey, mref=mref, hand=hand, gkey=gkey, gpos=gpos,
              loc_w=loc_w, loc_s=loc_s)
    return st, hit
