"""Unified state layout for the capacity-masked policy core.

Single source of the constants and sizing formulas that were previously
declared independently in ``core/jax_engine.py``, ``tuning/sweep.py``
and ``core/prodcache.py`` (``_WHERE_*``).  Deliberately numpy/JAX-free:
the production numpy cache (``ProdClock2QPlus``) and the threaded shard
service import these constants without pulling a JAX backend into their
process.

``SweepConfig`` (one grid point: a full policy parameterization) also
lives here — it is pure data shared by every layer above, and keeping it
below the step modules avoids an import cycle with the engine registry.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

# Sentinel for an empty slot in every key array (queue rings, location
# tables, payload handles).  A plain int: usable in numpy and JAX alike.
EMPTY = -1

# Location-table "where" codes: which segment a key currently lives in.
W_NONE, W_SMALL, W_MAIN, W_GHOST = 0, 1, 2, 3


def seg(capacity: int, frac: float) -> int:
    """Segment size for a fraction of ``capacity`` (at least one slot)."""
    return max(1, int(round(capacity * frac)))


def c2qp_sizes(capacity: int, small_frac: float = 0.1,
               ghost_frac: float = 0.5,
               window_frac: float = 0.5) -> Tuple[int, int, int, int]:
    """(small, main, ghost, window) segment sizes for one Clock2Q+
    configuration — the single source of the sizing formulas.  Every
    engine (serial replay, batched sweep lane, Pallas kernel oracle)
    derives its sizes here; their exact-parity guarantees depend on it."""
    S = min(capacity, seg(capacity, small_frac))
    M = max(1, capacity - S)
    G = seg(capacity, ghost_frac)
    W = int(round(window_frac * S))
    return S, M, G, W


def sq_sizes(capacity: int, small_frac: float = 0.1,
             ghost_frac: float = 1.0) -> Tuple[int, int, int]:
    """(small, main, ghost) sizes for the S3-FIFO family (no correlation
    window; ghost defaults to a FULL capacity's worth of tombstones)."""
    S = min(capacity, seg(capacity, small_frac))
    M = max(1, capacity - S)
    G = seg(capacity, ghost_frac)
    return S, M, G


@dataclasses.dataclass(frozen=True)
class SweepConfig:
    """One grid point: a full policy parameterization.

    ``skip_limit`` uses the sweep convention: 0 = unlimited (the paper
    default); ``ProdClock2QPlus`` uses None for unlimited — the tuner
    translates.  ``policy`` selects the registered lane engine; fields a
    policy does not read (see ``PolicyEngine.knobs``) are ignored by it.
    ``bits`` is only read by the s3fifo family (1- vs 2-bit counters).

    Note the field DEFAULTS are the Clock2Q+ paper defaults; when
    building configs for another policy go through
    ``get_engine(name).config(capacity, ...)``, which applies that
    engine's own preset (e.g. s3fifo's full-capacity ghost ring).
    """
    capacity: int
    window_frac: float = 0.5
    small_frac: float = 0.1
    ghost_frac: float = 0.5
    skip_limit: int = 0
    policy: str = "clock2q+"
    bits: int = 2

    def sizes(self) -> Tuple[int, int, int, int]:
        """Clock2Q+ (small, main, ghost, window) sizes — compat helper;
        engines size themselves via their own ``sizes_fn``."""
        return c2qp_sizes(self.capacity, self.small_frac, self.ghost_frac,
                          self.window_frac)
