"""EngineCache — a host-side cache facade over any registered lane
engine.

``ProdClock2QPlus`` is the production-shaped Clock2Q+ (chained hash,
pin/IO states, live resize); this is the *thin* counterpart for every
OTHER registered policy: a stateful object with hit/miss counters and
the small tuning surface the ``OnlineTuner`` speaks (``capacity`` /
``tuning`` / ``retune`` / ``engine_policy``), backed by the exact
masked step the MRC sweep simulates.  That closes the tuning loop for
non-Clock2Q+ policies — the tuner's estimates describe precisely the
machine serving the traffic, because they ARE the same machine.

Keys must be dense int ids in ``[0, universe)`` (relabel first, like
every lane consumer).  ``retune`` of the correlation window is a live
in-place update (the window is a scalar in the engine state); changing
queue FRACTIONS re-inits the state cold — this facade has no live
resize protocol, and a cold restart is the honest semantics for a
simulation-backed cache (documented here so nobody mistakes it for the
§4.2 migration).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np

import repro.core.engine as engine
from repro.core.engine import _FRAC_KNOBS
from repro.core.engine.layout import SweepConfig, c2qp_sizes


class EngineCache:
    """A live cache running a registered lane engine on the host."""

    def __init__(self, policy: str, capacity: int, universe: int, **knobs):
        self.engine = engine.get_engine(policy)
        self.engine_policy = policy
        self.universe = int(universe)
        self.config: SweepConfig = self.engine.config(capacity, **knobs)
        self.state: Dict = self.engine.init_config(self.config, self.universe)
        self.hits = 0
        self.misses = 0

    # -- identity / tuning surface (what OnlineTuner consumes) -----------------
    @property
    def capacity(self) -> int:
        """Current logical capacity (live-retunable)."""
        return self.config.capacity

    @property
    def tuning(self) -> Dict[str, float]:
        """Current fraction knobs — only the ones this engine reads."""
        return {k: getattr(self.config, k) for k in _FRAC_KNOBS
                if k in self.engine.knobs}

    @property
    def lane_skip_limit(self) -> int:
        """skip_limit already in the SweepConfig convention (0=unlimited)."""
        return int(self.config.skip_limit)

    # -- serving ---------------------------------------------------------------
    def access(self, key: int) -> bool:
        """Serve one access; returns hit?"""
        return bool(self.access_many(np.asarray([key]))[0])

    def access_many(self, keys) -> np.ndarray:
        """Serve a batch of accesses in order; returns the bool hit array.
        One jitted scan per call — amortize by batching."""
        arr = np.ascontiguousarray(keys, dtype=np.int32)
        if arr.size and (int(arr.max()) >= self.universe
                         or int(arr.min()) < 0):
            raise ValueError(
                f"key outside [0, {self.universe}); relabel the trace first")
        self.state, h = self.engine.replay(self.state,
                                           jnp.asarray(arr, jnp.int32))
        h = np.asarray(h).astype(bool)
        nh = int(h.sum())
        self.hits += nh
        self.misses += int(arr.size) - nh
        return h

    @property
    def miss_ratio(self) -> float:
        """Lifetime miss ratio (1.0 before any access)."""
        n = self.hits + self.misses
        return 1.0 if n == 0 else self.misses / n

    # -- retuning --------------------------------------------------------------
    def retune(self, *, small_frac: Optional[float] = None,
               ghost_frac: Optional[float] = None,
               window_frac: Optional[float] = None) -> None:
        """Retarget the knobs.  Window-only changes apply LIVE (the
        correlation window is a per-lane scalar in the masked state);
        any queue-fraction change re-inits the state cold (no live
        resize here — see the module docstring)."""
        changes = {k: float(v) for k, v in (("small_frac", small_frac),
                                            ("ghost_frac", ghost_frac),
                                            ("window_frac", window_frac))
                   if v is not None and k in self.engine.knobs
                   and float(v) != getattr(self.config, k)}
        if not changes:
            return
        new_cfg = dataclasses.replace(self.config, **changes)
        if set(changes) == {"window_frac"} and "window" in self.state:
            _, _, _, W = c2qp_sizes(new_cfg.capacity, new_cfg.small_frac,
                                    new_cfg.ghost_frac, new_cfg.window_frac)
            self.state["window"] = jnp.int32(W)
        else:
            self.state = self.engine.init_config(new_cfg, self.universe)
        self.config = new_cfg
