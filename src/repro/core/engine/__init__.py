"""The capacity-masked policy core: one masked ``step`` per policy
family behind a ``PolicyEngine`` protocol.

This package is the BOTTOM layer of the repo (enforced by
tools/check_layering.py): it may import nothing above itself.  Every
JAX-lane consumer — the serial replay drivers (``core.jax_engine``),
the batched MRC sweep (``tuning.sweep``), the profiler/tuner, the
shard-replay baselines, the Pallas oracle — resolves a registered
engine here and calls the SAME step function:

  * a single fixed-size simulation is the degenerate mask
    (physical array sizes == logical sizes);
  * a batched tuning grid pads every lane's arrays to the grid maxima
    (``grid_init``) and vmaps the identical step.

``PolicyEngine`` is a frozen dataclass (the protocol's concrete
carrier): ``init`` / ``step`` / ``replay`` / ``replay_chunked`` /
``lane_hits`` plus the family's config surface (``knobs``, ``preset``).
Register new policies with ``register_engine`` — see the README's
"adding a policy to the JAX lane" walkthrough.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import clock2qplus, s3fifo, simple
from repro.core.engine.layout import (  # noqa: F401  (package API)
    EMPTY, W_GHOST, W_MAIN, W_NONE, W_SMALL, SweepConfig, c2qp_sizes,
    seg, sq_sizes,
)
from repro.core.engine.masked import mset  # noqa: F401  (package API)

_FRAC_KNOBS = ("window_frac", "small_frac", "ghost_frac")


@dataclasses.dataclass(frozen=True)
class PolicyEngine:
    """One registered policy family on the JAX lane.

    ``knobs`` are the ``SweepConfig`` fields this family actually reads
    (capacity is always read); the tuner collapses grid dimensions the
    engine ignores.  ``preset`` overrides the SweepConfig defaults when
    a config is built through ``config()`` — e.g. s3fifo's full-capacity
    ghost ring, or clock2q's 2Q sizing on the clock2q+ core.
    """
    name: str
    knobs: Tuple[str, ...]
    sizes_fn: Callable[[SweepConfig], Tuple[int, ...]]
    init_fn: Callable[..., Dict]
    step_fn: Callable[[Dict, jnp.ndarray], Tuple[Dict, jnp.ndarray]]
    preset: Mapping[str, object] = dataclasses.field(default_factory=dict)

    # -- config / state construction ------------------------------------------
    def config(self, capacity: int, **kw) -> SweepConfig:
        """A ``SweepConfig`` for this policy with the engine's own
        defaults applied (explicit kwargs win over the preset)."""
        return SweepConfig(int(capacity), policy=self.name,
                           **{**dict(self.preset), **kw})

    def init_config(self, cfg: SweepConfig, universe: int,
                    phys: Optional[Tuple[int, ...]] = None) -> Dict:
        """Masked state for ``cfg``; ``phys`` pads to grid maxima."""
        return self.init_fn(cfg, int(universe), phys)

    def init(self, capacity: int, universe: int, **kw) -> Dict:
        """Degenerate-mask state for a single configuration."""
        return self.init_config(self.config(capacity, **kw), universe)

    # -- replay ---------------------------------------------------------------
    def step(self, state: Dict, key) -> Tuple[Dict, jnp.ndarray]:
        """One masked transition: ``(state, key) -> (state, hit)``."""
        return self.step_fn(state, key)

    def replay(self, state: Dict, trace) -> Tuple[Dict, jnp.ndarray]:
        """Jitted ``lax.scan`` replay of ``trace`` from ``state``."""
        return replay(self.name, state, trace)

    def replay_chunked(self, chunks, capacity: int, universe: int,
                       state: Optional[Dict] = None, **kw):
        """State-carry replay over an iterable of trace chunks
        (bit-identical to the single-shot ``replay``)."""
        return replay_chunked(self.name, chunks, capacity, universe,
                              state=state, **kw)

    def lane_hits(self, trace, config: Optional[SweepConfig] = None,
                  universe: Optional[int] = None, **kw) -> np.ndarray:
        """Per-access hit array for one configuration (one vmap lane)."""
        if config is None:
            config = self.config(**kw)
        return lane_hits(trace, config, universe)


# -- registry ------------------------------------------------------------------

_REGISTRY: Dict[str, PolicyEngine] = {}


def register_engine(engine: PolicyEngine) -> PolicyEngine:
    """Register (or replace) a lane policy family by name."""
    _REGISTRY[engine.name] = engine
    return engine


def get_engine(name: str) -> PolicyEngine:
    """Look up a registered lane engine by policy name."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"no registered lane engine {name!r}; "
            f"known: {sorted(_REGISTRY)}") from None


def engine_names() -> List[str]:
    """Sorted names of every registered lane engine."""
    return sorted(_REGISTRY)


register_engine(PolicyEngine(
    "clock2q+",
    knobs=("window_frac", "small_frac", "ghost_frac", "skip_limit"),
    sizes_fn=clock2qplus.sizes, init_fn=clock2qplus.init,
    step_fn=clock2qplus.step))
# Clock2Q == Clock2Q+ with 2Q sizing and the window covering the whole
# Small FIFO (the ref bit is never set while resident there, §3.2) —
# the same masked step, preset-sized.
register_engine(PolicyEngine(
    "clock2q",
    knobs=("window_frac", "small_frac", "ghost_frac", "skip_limit"),
    sizes_fn=clock2qplus.sizes, init_fn=clock2qplus.init,
    step_fn=clock2qplus.step,
    preset=dict(small_frac=0.25, window_frac=10.0)))
register_engine(PolicyEngine(
    "s3fifo",
    knobs=("small_frac", "ghost_frac", "skip_limit", "bits"),
    sizes_fn=s3fifo.sizes, init_fn=s3fifo.init, step_fn=s3fifo.step,
    preset=dict(ghost_frac=1.0)))
register_engine(PolicyEngine(
    "fifo", knobs=(), sizes_fn=simple.sizes, init_fn=simple.fifo_init,
    step_fn=simple.fifo_step))
register_engine(PolicyEngine(
    "clock", knobs=(), sizes_fn=simple.sizes, init_fn=simple.clock_init,
    step_fn=simple.clock_step))
register_engine(PolicyEngine(
    "lru", knobs=(), sizes_fn=simple.sizes, init_fn=simple.lru_init,
    step_fn=simple.lru_step))


# -- generic replay drivers ----------------------------------------------------

@functools.partial(jax.jit, static_argnames=("policy",))
def replay(policy: str, state: Dict, trace: jnp.ndarray):
    """Replay one trace; returns (final_state, hits[bool per request])."""
    return jax.lax.scan(get_engine(policy).step_fn, state, trace)


@functools.lru_cache(maxsize=1)
def _replay_carry():
    """Resolved lazily so importing this package never initializes a JAX
    backend (device probing can hang minutes in hermetic environments).
    Donating the carried state lets XLA reuse its buffers across chunk
    calls (the state never needs two live copies); the CPU backend
    ignores donation with a warning, so only request it where it's
    implemented."""
    if jax.default_backend() == "cpu":
        return replay
    return jax.jit(
        lambda policy, state, trace: jax.lax.scan(
            get_engine(policy).step_fn, state, trace),
        static_argnums=(0,), donate_argnums=(1,))


def replay_chunked(policy: str, chunks, capacity: int, universe: int,
                   state: Optional[Dict] = None, on_chunk=None, **kw):
    """Replay an iterable of key chunks, threading the scan state across
    chunk boundaries.  ``lax.scan`` is sequential, so splitting a trace
    at ANY boundary and carrying the state is bit-identical to the
    single-shot ``replay`` of the concatenated trace (asserted in
    tests/test_chunked.py) — but peak memory holds one chunk, not the
    trace.  Chunks of equal length share one compiled executable; only a
    ragged tail chunk triggers a second compile.

    Returns ``(hits, n_requests, final_state)`` — pass ``state`` back in
    to continue a stream across calls.  ``on_chunk(n, hits)`` (running
    totals) fires after each chunk — the progress hook drivers hang
    telemetry on without this package importing any.
    """
    universe = int(universe)
    if not (0 < universe <= np.iinfo(np.int32).max):
        # Keys are int32 ids with dense (universe,)-sized location tables:
        # raw production obj_ids (sparse/hashed 64-bit) must be relabelled
        # first — tuning.sweep.relabel in memory, or once on disk with
        # `python -m repro.traceio.convert --relabel`.
        raise ValueError(
            f"universe {universe} does not fit the engine's dense int32 id "
            "space; relabel the trace to [0, n_unique) first "
            "(repro.tuning.sweep.relabel or convert --relabel)")
    st = get_engine(policy).init(capacity, universe, **kw) \
        if state is None else state
    carry = _replay_carry()
    hits = 0
    n = 0
    for chunk in chunks:
        arr = np.ascontiguousarray(chunk)
        # negative keys appear when hashed obj_ids >= 2**63 wrap through
        # the oracleGeneral uint64->int64 load — reject those too, or they
        # would wrap-index the dense tables instead of erroring
        if arr.size and (int(arr.max()) >= universe or int(arr.min()) < 0):
            bad = int(arr.max()) if int(arr.max()) >= universe \
                else int(arr.min())
            raise ValueError(
                f"chunk contains key {bad} outside [0, {universe}); "
                "relabel the trace (convert --relabel) or pass a larger "
                "universe")
        st, h = carry(policy, st, jnp.asarray(arr, jnp.int32))
        hits += int(np.asarray(jnp.sum(h)))
        n += int(arr.shape[0])
        if on_chunk is not None:
            on_chunk(n, hits)
    return hits, n, st


# -- batched grids (the MRC sweep substrate) -----------------------------------

def grid_init(configs: Sequence[SweepConfig], universe: int) -> Dict:
    """Batched masked state: leading axis = len(configs); queue arrays
    padded to the grid maxima, logical sizes as per-lane scalars.  All
    configs must name the same policy (vmap lanes share a pytree
    structure) — ``tuning.sweep`` partitions mixed grids by policy."""
    if len(configs) == 0:
        raise ValueError("empty sweep grid")
    policies = {c.policy for c in configs}
    if len(policies) != 1:
        raise ValueError(
            f"one grid_init call batches ONE policy, got {sorted(policies)}"
            " — partition the grid by config.policy first")
    eng = get_engine(configs[0].policy)
    sizes = np.asarray([eng.sizes_fn(c) for c in configs], dtype=np.int64)
    phys = tuple(int(x) for x in sizes.max(axis=0))
    states = [eng.init_config(c, universe, phys) for c in configs]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)


@functools.partial(jax.jit, static_argnames=("policy",))
def grid_hit_counts(policy: str, states: Dict,
                    trace: jnp.ndarray) -> jnp.ndarray:
    """All lanes x the whole trace in one compiled call; per-lane hit
    counts (the full hit arrays are reduced on-device, so long traces
    never materialize a lanes x T matrix on the host)."""
    step = get_engine(policy).step_fn

    def lane(st):
        _, hits = jax.lax.scan(step, st, trace)
        return jnp.sum(hits.astype(jnp.int32))

    return jax.vmap(lane)(states)


@functools.partial(jax.jit, static_argnames=("policy",))
def grid_hit_arrays(policy: str, states: Dict,
                    trace: jnp.ndarray) -> jnp.ndarray:
    """Per-access hit arrays for every lane (lanes x T on device)."""
    step = get_engine(policy).step_fn

    def lane(st):
        _, hits = jax.lax.scan(step, st, trace)
        return hits

    return jax.vmap(lane)(states)


def lane_hits(trace: np.ndarray, config: SweepConfig,
              universe: Optional[int] = None) -> np.ndarray:
    """Per-request bool hit array for ONE grid configuration — the
    conformance hook: lets tests/test_conformance.py compare the sweep
    engine hit-for-hit against the other implementations
    (``grid_hit_counts`` only exposes per-lane counts).  ``trace`` must
    already be dense int ids in [0, universe)."""
    trace = np.asarray(trace)
    if universe is None:
        universe = int(trace.max()) + 1
    states = grid_init([config], int(universe))
    hits = grid_hit_arrays(config.policy, states,
                           jnp.asarray(trace, jnp.int32))
    return np.asarray(hits)[0].astype(bool)
