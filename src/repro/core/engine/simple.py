"""Capacity-masked FIFO / Clock / LRU steps.

Same layout discipline as the Clock2Q+ core: queue arrays at physical
(padded) sizes, the logical capacity as a ``cap`` scalar in the state,
cursors wrapped modulo ``cap``, straight-line masked scatters instead of
``lax.cond`` branches (see ``engine.clock2qplus`` for why), and ``key <
0`` as the no-op padding sentinel.  Clock's victim search is the same
closed-form sweep as the Clock2Q+ main clock (skip_limit-free).

Hit/miss parity with the pure-Python zoo is asserted in
tests/test_jax_engine.py and fuzzed in tests/test_engine_fuzz.py.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax.numpy as jnp

from repro.core.engine.layout import EMPTY, SweepConfig
from repro.core.engine.masked import mset as _mset

_I32_MAX = 2**31 - 1


def sizes(cfg: SweepConfig) -> Tuple[int]:
    """Physical array sizes for a simple single-ring policy."""
    return (max(1, cfg.capacity),)


# -- FIFO ----------------------------------------------------------------------

def fifo_init(cfg: SweepConfig, universe: int,
              phys: Optional[Tuple[int]] = None) -> Dict:
    """Masked FIFO state (``phys`` pads the ring to grid maxima)."""
    (C,) = sizes(cfg)
    (pC,) = phys if phys is not None else (C,)
    return dict(keys=jnp.full((pC,), EMPTY), pos=jnp.int32(0),
                resident=jnp.zeros((universe,), jnp.bool_),
                cap=jnp.int32(C))


def fifo_step(st: Dict, key) -> Tuple[Dict, jnp.ndarray]:
    """One FIFO transition: ``(state, key) -> (state, hit)``."""
    active = key >= 0
    key = jnp.maximum(key, 0)
    hit = active & st["resident"][key]
    miss = active & ~hit
    s = st["pos"]
    old = st["keys"][s]
    resident = _mset(st["resident"], old, False, miss & (old >= 0))
    resident = _mset(resident, key, True, miss)
    keys = _mset(st["keys"], s, key, miss)
    pos = jnp.where(miss, (s + 1) % st["cap"], s)
    return dict(st, keys=keys, pos=pos, resident=resident), hit


# -- Clock (second chance) -----------------------------------------------------

def clock_init(cfg: SweepConfig, universe: int,
               phys: Optional[Tuple[int]] = None) -> Dict:
    """Masked second-chance Clock state."""
    (C,) = sizes(cfg)
    (pC,) = phys if phys is not None else (C,)
    return dict(keys=jnp.full((pC,), EMPTY),
                ref=jnp.zeros((pC,), jnp.bool_), hand=jnp.int32(0),
                loc=jnp.full((universe,), EMPTY), cap=jnp.int32(C))


def clock_step(st: Dict, key) -> Tuple[Dict, jnp.ndarray]:
    """One Clock transition: ``(state, key) -> (state, hit)``."""
    active = key >= 0
    key = jnp.maximum(key, 0)
    slot = st["loc"][key]
    hit = active & (slot >= 0)
    miss = active & ~hit
    ref = _mset(st["ref"], slot, True, hit)

    # closed-form sweep: first slot (cyclic from hand) not occupied&ref'd;
    # a full fruitless lap clears every ref and takes the hand slot
    C = st["keys"].shape[-1]  # physical ring size — static
    cap, hand = st["cap"], st["hand"]
    idx = jnp.arange(C)
    valid = idx < cap
    d = jnp.where(valid, (idx - hand) % cap, C + 1)
    skippable = (st["keys"] >= 0) & ref
    vd = jnp.min(jnp.where(valid & ~skippable, d, C + 1))
    vd = jnp.minimum(vd, cap)
    ms = (hand + vd) % cap
    ref = jnp.where(miss, ref & ~(valid & (d < vd)), ref)
    victim = st["keys"][ms]
    loc = _mset(st["loc"], victim, EMPTY, miss & (victim >= 0))
    loc = _mset(loc, key, ms, miss)
    keys = _mset(st["keys"], ms, key, miss)
    ref = _mset(ref, ms, False, miss)
    hand = jnp.where(miss, (ms + 1) % cap, hand)
    return dict(st, keys=keys, ref=ref, hand=hand, loc=loc), hit


# -- LRU -----------------------------------------------------------------------

def lru_init(cfg: SweepConfig, universe: int,
             phys: Optional[Tuple[int]] = None) -> Dict:
    """Masked LRU state (exact, timestamp-argmin victim)."""
    (C,) = sizes(cfg)
    (pC,) = phys if phys is not None else (C,)
    return dict(keys=jnp.full((pC,), EMPTY),
                last=jnp.full((pC,), jnp.int32(-1)),
                t=jnp.int32(0), loc=jnp.full((universe,), EMPTY),
                cap=jnp.int32(C))


def lru_step(st: Dict, key) -> Tuple[Dict, jnp.ndarray]:
    """One LRU transition: ``(state, key) -> (state, hit)``."""
    active = key >= 0
    key = jnp.maximum(key, 0)
    slot = st["loc"][key]
    hit = active & (slot >= 0)
    miss = active & ~hit
    C = st["keys"].shape[-1]
    # empty logical slots have last=-1 -> picked first; padded slots are
    # masked to +inf so the argmin can never land on them (ties keep
    # argmin's first-index rule, matching the unmasked engine)
    valid = jnp.arange(C) < st["cap"]
    s = jnp.argmin(jnp.where(valid, st["last"], _I32_MAX))
    victim = st["keys"][s]
    loc = _mset(st["loc"], victim, EMPTY, miss & (victim >= 0))
    keys = _mset(st["keys"], s, key, miss)
    tslot = jnp.where(hit, slot, s)
    last = _mset(st["last"], tslot, st["t"], active)
    t = st["t"] + active.astype(jnp.int32)
    loc = _mset(loc, key, tslot, miss)
    return dict(st, keys=keys, last=last, t=t, loc=loc), hit
