"""Vectorized JAX cache-simulation engine — the TPU-native adaptation of the
paper's evaluation substrate (a batched libCacheSim).

Each policy is a *functional state machine*: a pytree of fixed-shape arrays
plus a pure ``step(state, key) -> (state, hit)``.  Traces are replayed with
``jax.lax.scan``; independent simulations (traces × cache sizes × window
sizes × policies) run as ``jax.vmap`` lanes.  This replaces the paper's
multi-thread scalability story with lane parallelism (DESIGN.md §3).

The state machines themselves live in ``repro.core.engine`` — ONE
capacity-masked step per policy family behind the ``PolicyEngine``
registry, shared verbatim with the batched MRC sweep
(``repro.tuning.sweep``); a single fixed-size simulation here is the
degenerate mask.  This module is the serial/chunked/sharded replay
driver layer on top, plus compat re-exports of the layout constants.

Keys must be int32 ids in ``[0, universe)``.  Lookup uses a dense location
table (``where[key]``, ``slot[key]``) — the TPU-friendly replacement for
the production chained hash (gather beats pointer chasing).

Policies: fifo, clock, lru, s3fifo (1/2-bit), clock2q, clock2q+ (clock2q
is clock2q+ with the 2Q sizing and a full-size correlation window, §3.2).

Exact hit/miss parity with the pure-Python reference zoo is asserted in
tests/test_jax_engine.py and fuzzed in tests/test_engine_fuzz.py.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import (  # noqa: F401  (compat re-exports)
    EMPTY, W_GHOST, W_MAIN, W_NONE, W_SMALL, c2qp_sizes, engine_names,
    get_engine, replay, replay_chunked,
)


def jax_policy_names():
    return engine_names()


def init_state(policy: str, capacity: int, universe: int, **kw) -> Dict:
    return get_engine(policy).init(capacity, int(universe), **kw)


def replay_np(policy: str, trace: np.ndarray, capacity: int,
              universe: int | None = None, **kw):
    """Convenience host-side wrapper returning a hit-count + miss ratio."""
    trace = np.asarray(trace)
    if universe is None:
        universe = int(trace.max()) + 1
    st = init_state(policy, capacity, int(universe), **kw)
    _, hits = replay(policy, st, jnp.asarray(trace, jnp.int32))
    h = int(np.sum(np.asarray(hits)))
    return h, 1.0 - h / max(1, len(trace))


def replay_store(policy: str, store, capacity: int,
                 universe: int | None = None,
                 chunk_size: int = 1 << 20, obs=None, **kw):
    """``replay_np`` for an on-disk trace: stream a ``TraceStore`` (or
    anything ``repro.traceio.iter_chunks`` accepts) in ``chunk_size``
    pieces.  Returns (hit count, miss ratio), bit-identical to loading
    the whole trace and calling ``replay_np``.

    With an ``obs`` sink, each chunk leaves a periodic snapshot row:
    progress gauges (accesses so far, running miss ratio) plus one
    ``EV_SNAPSHOT`` event, via the engine's ``on_chunk`` hook — the
    engine package itself stays telemetry-free."""
    from repro.traceio.store import TraceStore, iter_chunks

    if universe is None:
        if isinstance(store, TraceStore):
            universe = store.universe(chunk_size)
        elif isinstance(store, np.ndarray):
            universe = int(store.max()) + 1
        else:
            raise ValueError("pass universe= explicitly when streaming "
                             "from a one-shot chunk iterable")
    if obs is not None:
        from repro.obs import EV_SNAPSHOT
        g_n = obs.gauge("replay_accesses", (),
                        "accesses replayed so far").labels()
        g_mr = obs.gauge("replay_miss_ratio", (),
                         "running miss ratio").labels()

        def on_chunk(n_done, hits_done):
            mr = 1.0 - hits_done / max(1, n_done)
            g_n.set(float(n_done))
            g_mr.set(mr)
            obs.emit(EV_SNAPSHOT, a=n_done, b=hits_done, c=mr)

        kw["on_chunk"] = on_chunk
    h, n, _ = replay_chunked(policy, iter_chunks(store, chunk_size),
                             capacity, int(universe), **kw)
    return h, 1.0 - h / max(1, n)


def replay_batch(policy: str, states: Dict, traces: jnp.ndarray):
    """vmap over leading lane axis of both states and traces."""
    step = get_engine(policy).step_fn

    def one(state, tr):
        return jax.lax.scan(step, state, tr)

    return jax.vmap(one)(states, traces)


# =============================================================================
# sharded simulation (repro.shardcache's partitioning, vmap-ed)
# =============================================================================

def sharded_replay(policy: str, trace: np.ndarray, capacity: int,
                   n_shards: int, universe: int | None = None, **kw):
    """Simulate the hash-sharded service: partition ``trace`` by the
    shardcache key hash into ``n_shards`` subtraces, replay them as vmap
    lanes at ``round(capacity / n_shards)`` each, and merge the per-lane
    hit arrays back into request order.

    Returns a bool hit array aligned with ``trace``.  Lanes are padded to
    equal length; the pad accesses run *after* every real access in their
    lane, so they cannot perturb real hits.

    vmap lanes must share state shapes, so every shard gets the SAME
    capacity ``round(capacity / n_shards)`` — the total simulated capacity
    can differ from ``capacity`` by up to ``n_shards // 2`` slots in either
    direction.  Pass a capacity divisible by ``n_shards`` for an exact
    equal-total comparison with the unsharded baseline (the benchmarks and
    parity tests do).
    """
    from repro.shardcache.hashing import shard_of_np

    trace = np.asarray(trace)
    if universe is None:
        universe = int(trace.max()) + 1
    cap_shard = int(round(capacity / n_shards))
    if cap_shard < 2:
        raise ValueError(f"capacity {capacity} too small for {n_shards} shards")
    sid = shard_of_np(trace, n_shards)
    idx = [np.nonzero(sid == s)[0] for s in range(n_shards)]
    lane_len = max((len(ix) for ix in idx), default=1) or 1
    lanes = np.zeros((n_shards, lane_len), dtype=np.int32)
    for s, ix in enumerate(idx):
        lanes[s, :len(ix)] = trace[ix]
    states = jax.vmap(
        lambda _: init_state(policy, cap_shard, int(universe), **kw))(
        jnp.arange(n_shards))
    _, hits = replay_batch(policy, states, jnp.asarray(lanes))
    hits = np.asarray(hits)
    merged = np.zeros(trace.shape[0], dtype=bool)
    for s, ix in enumerate(idx):
        merged[ix] = hits[s, :len(ix)]
    return merged


def sharded_replay_np(policy: str, trace: np.ndarray, capacity: int,
                      n_shards: int, universe: int | None = None, **kw):
    """Host-side convenience wrapper: (hit count, miss ratio)."""
    merged = sharded_replay(policy, trace, capacity, n_shards,
                            universe=universe, **kw)
    h = int(merged.sum())
    return h, 1.0 - h / max(1, merged.shape[0])
