"""Vectorized JAX cache-simulation engine — the TPU-native adaptation of the
paper's evaluation substrate (a batched libCacheSim).

Each policy is a *functional state machine*: a pytree of fixed-shape arrays
plus a pure ``step(state, key) -> (state, hit)``.  Traces are replayed with
``jax.lax.scan``; independent simulations (traces × cache sizes × window
sizes × policies) run as ``jax.vmap`` lanes.  This replaces the paper's
multi-thread scalability story with lane parallelism (DESIGN.md §3).

Keys must be int32 ids in ``[0, universe)``.  Lookup uses a dense location
table (``where[key]``, ``slot[key]``) — the TPU-friendly replacement for
the production chained hash (gather beats pointer chasing).

Policies: fifo, clock, lru, s3fifo (1/2-bit), clock2q, clock2q+ (clock2q
is clock2q+ with the 2Q sizing and a full-size correlation window, §3.2).

Exact hit/miss parity with the pure-Python reference zoo is asserted in
tests/test_jax_engine.py.
"""

from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

EMPTY = jnp.int32(-1)
W_NONE, W_SMALL, W_MAIN, W_GHOST = 0, 1, 2, 3


def _seg(capacity: int, frac: float) -> int:
    return max(1, int(round(capacity * frac)))


def c2qp_sizes(capacity: int, small_frac: float = 0.1,
               ghost_frac: float = 0.5,
               window_frac: float = 0.5) -> Tuple[int, int, int, int]:
    """(small, main, ghost, window) segment sizes for one configuration —
    the single source of the sizing formulas, shared by ``c2qp_init`` and
    the batched grid engine (repro.tuning.sweep), whose exact-parity
    guarantee depends on both deriving identical sizes."""
    S = min(capacity, _seg(capacity, small_frac))
    M = max(1, capacity - S)
    G = _seg(capacity, ghost_frac)
    W = int(round(window_frac * S))
    return S, M, G, W


# =============================================================================
# Clock2Q+ family (covers clock2q via sizing, s3fifo-1bit via window=0 with
# a clock main; the faithful s3fifo uses the FIFO-reinsert main below)
# =============================================================================

def c2qp_init(capacity: int, universe: int, *, small_frac: float = 0.1,
              ghost_frac: float = 0.5, window_frac: float = 0.5,
              skip_limit: int = 0) -> Dict[str, jnp.ndarray]:
    """skip_limit=0 means unlimited (paper default)."""
    S, M, G, W = c2qp_sizes(capacity, small_frac, ghost_frac, window_frac)
    return dict(
        skey=jnp.full((S,), EMPTY), sref=jnp.zeros((S,), jnp.bool_),
        sseq=jnp.zeros((S,), jnp.int32), spos=jnp.int32(0),
        seqctr=jnp.int32(0),
        mkey=jnp.full((M,), EMPTY), mref=jnp.zeros((M,), jnp.bool_),
        hand=jnp.int32(0),
        gkey=jnp.full((G,), EMPTY), gpos=jnp.int32(0),
        loc_w=jnp.zeros((universe,), jnp.int8),
        loc_s=jnp.zeros((universe,), jnp.int32),
        window=jnp.int32(W), skip_limit=jnp.int32(skip_limit),
    )


def _c2qp_insert_main(st: Dict, key: jnp.ndarray) -> Dict:
    """Clock sweep for a victim slot, then place ``key`` there."""
    M = st["mkey"].shape[0]

    def cond(c):
        return ~c["done"]

    def body(c):
        s = c["hand"]
        occupied = st["mkey"][s] >= 0  # keys don't change during the sweep
        ref = c["mref"][s]
        skippable = occupied & ref & ~c["forced"]
        # clear ref & advance, or take the slot
        new_skips = c["skips"] + skippable.astype(jnp.int32)
        forced = jnp.where(
            st["skip_limit"] > 0,
            c["forced"] | (new_skips >= st["skip_limit"]), c["forced"])
        take = ~skippable
        mref = c["mref"].at[s].set(jnp.where(skippable, False, c["mref"][s]))
        return dict(
            hand=jnp.where(take, s, (s + 1) % M),
            mref=mref, skips=new_skips, forced=forced,
            done=take, slot=jnp.where(take, s, c["slot"]))

    out = jax.lax.while_loop(cond, body, dict(
        hand=st["hand"], mref=st["mref"], skips=jnp.int32(0),
        forced=jnp.bool_(False), done=jnp.bool_(False), slot=jnp.int32(0)))
    s = out["slot"]
    victim = st["mkey"][s]
    has_victim = victim >= 0
    loc_w = jnp.where(
        has_victim, st["loc_w"].at[victim].set(W_NONE), st["loc_w"])
    loc_w = loc_w.at[key].set(W_MAIN)
    loc_s = st["loc_s"].at[key].set(s)
    return dict(st, mkey=st["mkey"].at[s].set(key),
                mref=out["mref"].at[s].set(False),
                hand=(s + 1) % M, loc_w=loc_w, loc_s=loc_s)


def _c2qp_ghost_push(st: Dict, key: jnp.ndarray) -> Dict:
    G = st["gkey"].shape[0]
    g = st["gpos"]
    old = st["gkey"][g]
    loc_w = jnp.where(old >= 0, st["loc_w"].at[old].set(W_NONE), st["loc_w"])
    loc_w = loc_w.at[key].set(W_GHOST)
    loc_s = st["loc_s"].at[key].set(g)
    return dict(st, gkey=st["gkey"].at[g].set(key), gpos=(g + 1) % G,
                loc_w=loc_w, loc_s=loc_s)


def c2qp_step(st: Dict, key: jnp.ndarray) -> Tuple[Dict, jnp.ndarray]:
    where = st["loc_w"][key]
    slot = st["loc_s"][key]
    hit = (where == W_SMALL) | (where == W_MAIN)

    def case_small(st):
        age = st["seqctr"] - st["sseq"][slot]
        setref = age >= st["window"]
        return dict(st, sref=st["sref"].at[slot].set(st["sref"][slot] | setref))

    def case_main(st):
        return dict(st, mref=st["mref"].at[slot].set(True))

    def case_ghost(st):
        st = dict(st, gkey=st["gkey"].at[slot].set(EMPTY),
                  loc_w=st["loc_w"].at[key].set(W_NONE))
        return _c2qp_insert_main(st, key)

    def case_none(st):
        S = st["skey"].shape[0]
        s = st["spos"]
        displaced = st["skey"][s]
        dref = st["sref"][s]

        def promote(st):
            return _c2qp_insert_main(
                dict(st, loc_w=st["loc_w"].at[displaced].set(W_NONE)), displaced)

        def demote(st):
            return _c2qp_ghost_push(
                dict(st, loc_w=st["loc_w"].at[displaced].set(W_NONE)), displaced)

        st = jax.lax.cond(
            displaced >= 0,
            lambda st: jax.lax.cond(dref, promote, demote, st),
            lambda st: st, st)
        return dict(
            st,
            skey=st["skey"].at[s].set(key),
            sref=st["sref"].at[s].set(False),
            sseq=st["sseq"].at[s].set(st["seqctr"]),
            spos=(s + 1) % S,
            seqctr=st["seqctr"] + 1,
            loc_w=st["loc_w"].at[key].set(W_SMALL),
            loc_s=st["loc_s"].at[key].set(s))

    st = jax.lax.switch(where.astype(jnp.int32),
                        [case_none, case_small, case_main, case_ghost], st)
    return st, hit


# =============================================================================
# FIFO / Clock / LRU
# =============================================================================

def fifo_init(capacity: int, universe: int) -> Dict:
    return dict(keys=jnp.full((capacity,), EMPTY), pos=jnp.int32(0),
                resident=jnp.zeros((universe,), jnp.bool_))


def fifo_step(st: Dict, key) -> Tuple[Dict, jnp.ndarray]:
    hit = st["resident"][key]

    def miss(st):
        C = st["keys"].shape[0]
        s = st["pos"]
        old = st["keys"][s]
        res = jnp.where(old >= 0, st["resident"].at[old].set(False),
                        st["resident"])
        return dict(keys=st["keys"].at[s].set(key), pos=(s + 1) % C,
                    resident=res.at[key].set(True))

    return jax.lax.cond(hit, lambda st: st, miss, st), hit


def clock_init(capacity: int, universe: int) -> Dict:
    return dict(keys=jnp.full((capacity,), EMPTY),
                ref=jnp.zeros((capacity,), jnp.bool_), hand=jnp.int32(0),
                loc=jnp.full((universe,), EMPTY),)


def clock_step(st: Dict, key) -> Tuple[Dict, jnp.ndarray]:
    slot = st["loc"][key]
    hit = slot >= 0

    def on_hit(st):
        return dict(st, ref=st["ref"].at[slot].set(True))

    def on_miss(st):
        C = st["keys"].shape[0]

        def body(c):
            s = c["hand"]
            skip = (c["keys"][s] >= 0) & c["ref"][s]
            return dict(hand=jnp.where(skip, (s + 1) % C, s),
                        ref=c["ref"].at[s].set(False),
                        keys=c["keys"], done=~skip,
                        slot=jnp.where(skip, c["slot"], s))

        out = jax.lax.while_loop(
            lambda c: ~c["done"], body,
            dict(hand=st["hand"], ref=st["ref"], keys=st["keys"],
                 done=jnp.bool_(False), slot=jnp.int32(0)))
        s = out["slot"]
        victim = st["keys"][s]
        loc = jnp.where(victim >= 0, st["loc"].at[victim].set(EMPTY), st["loc"])
        C = st["keys"].shape[0]
        return dict(keys=st["keys"].at[s].set(key),
                    ref=out["ref"].at[s].set(False),
                    hand=(s + 1) % C, loc=loc.at[key].set(s))

    return jax.lax.cond(hit, on_hit, on_miss, st), hit


def lru_init(capacity: int, universe: int) -> Dict:
    return dict(keys=jnp.full((capacity,), EMPTY),
                last=jnp.full((capacity,), jnp.int32(-1)),
                t=jnp.int32(0), loc=jnp.full((universe,), EMPTY))


def lru_step(st: Dict, key) -> Tuple[Dict, jnp.ndarray]:
    slot = st["loc"][key]
    hit = slot >= 0

    def on_hit(st):
        return dict(st, last=st["last"].at[slot].set(st["t"]), t=st["t"] + 1)

    def on_miss(st):
        s = jnp.argmin(st["last"])  # empty slots have last=-1 -> picked first
        victim = st["keys"][s]
        loc = jnp.where(victim >= 0, st["loc"].at[victim].set(EMPTY), st["loc"])
        return dict(keys=st["keys"].at[s].set(key),
                    last=st["last"].at[s].set(st["t"]), t=st["t"] + 1,
                    loc=loc.at[key].set(s))

    return jax.lax.cond(hit, on_hit, on_miss, st), hit


# =============================================================================
# S3-FIFO (faithful: FIFO-with-reinsertion main, freq counters, ghost ring)
# =============================================================================

def s3fifo_init(capacity: int, universe: int, *, small_frac: float = 0.1,
                ghost_frac: float = 1.0, bits: int = 2,
                skip_limit: int = 0) -> Dict:
    S = min(capacity, _seg(capacity, small_frac))
    M = max(1, capacity - S)
    G = _seg(capacity, ghost_frac)
    return dict(
        skey=jnp.full((S,), EMPTY), sfreq=jnp.zeros((S,), jnp.int32),
        spos=jnp.int32(0),
        mkey=jnp.full((M,), EMPTY), mfreq=jnp.zeros((M,), jnp.int32),
        mhead=jnp.int32(0), mcount=jnp.int32(0),
        gkey=jnp.full((G,), EMPTY), gpos=jnp.int32(0),
        loc_w=jnp.zeros((universe,), jnp.int8),
        loc_s=jnp.zeros((universe,), jnp.int32),
        freq_cap=jnp.int32(1 if bits == 1 else 3),
        promote_at=jnp.int32(1 if bits == 1 else 2),
        skip_limit=jnp.int32(skip_limit),
    )


def _s3_insert_main(st: Dict, key: jnp.ndarray) -> Dict:
    """Main ring: evict-from-head-with-reinsertion if full, insert at tail."""
    M = st["mkey"].shape[0]

    def evict(st):
        # With a full ring, evict-head + append-tail reuses the head slot as
        # the new tail slot: reinserted entries "rotate in place" (the head
        # cursor advances past them) with their freq decremented — exactly
        # the deque popleft+append of the reference implementation.
        def cond(c):
            return ~c["done"]

        def body(c):
            h = c["mhead"]
            k = c["mkey"][h]
            freq = c["mfreq"][h]
            reinsert = (freq >= 1) & ((st["skip_limit"] == 0)
                                      | (c["skips"] < st["skip_limit"]))
            mfreq = jnp.where(reinsert, c["mfreq"].at[h].set(freq - 1),
                              c["mfreq"])
            done = ~reinsert
            mkey = jnp.where(done, c["mkey"].at[h].set(EMPTY), c["mkey"])
            loc_w = jnp.where(done & (k >= 0), c["loc_w"].at[k].set(W_NONE),
                              c["loc_w"])
            return dict(mhead=(h + 1) % M, mkey=mkey, mfreq=mfreq,
                        skips=c["skips"] + reinsert.astype(jnp.int32),
                        done=done, slot=jnp.where(done, h, c["slot"]),
                        loc_w=loc_w)

        out = jax.lax.while_loop(cond, body, dict(
            mhead=st["mhead"], mkey=st["mkey"], mfreq=st["mfreq"],
            skips=jnp.int32(0), done=jnp.bool_(False), slot=jnp.int32(0),
            loc_w=st["loc_w"]))
        return dict(st, mhead=out["mhead"], mkey=out["mkey"],
                    mfreq=out["mfreq"], loc_w=out["loc_w"],
                    mcount=st["mcount"] - 1, _slot=out["slot"])

    def no_evict(st):
        # free slot at tail
        return dict(st, _slot=(st["mhead"] + st["mcount"]) % M)

    st = dict(st, _slot=jnp.int32(0))
    st = jax.lax.cond(st["mcount"] >= M, evict, no_evict, st)
    s = st.pop("_slot")
    return dict(st, mkey=st["mkey"].at[s].set(key),
                mfreq=st["mfreq"].at[s].set(0), mcount=st["mcount"] + 1,
                loc_w=st["loc_w"].at[key].set(W_MAIN),
                loc_s=st["loc_s"].at[key].set(s))


def _s3_ghost_push(st: Dict, key: jnp.ndarray) -> Dict:
    G = st["gkey"].shape[0]
    g = st["gpos"]
    old = st["gkey"][g]
    loc_w = jnp.where(old >= 0, st["loc_w"].at[old].set(W_NONE), st["loc_w"])
    return dict(st, gkey=st["gkey"].at[g].set(key), gpos=(g + 1) % G,
                loc_w=loc_w.at[key].set(W_GHOST),
                loc_s=st["loc_s"].at[key].set(g))


def s3fifo_step(st: Dict, key) -> Tuple[Dict, jnp.ndarray]:
    where = st["loc_w"][key]
    slot = st["loc_s"][key]
    hit = (where == W_SMALL) | (where == W_MAIN)

    def case_small(st):
        f = jnp.minimum(st["freq_cap"], st["sfreq"][slot] + 1)
        return dict(st, sfreq=st["sfreq"].at[slot].set(f))

    def case_main(st):
        f = jnp.minimum(st["freq_cap"], st["mfreq"][slot] + 1)
        return dict(st, mfreq=st["mfreq"].at[slot].set(f))

    def case_ghost(st):
        st = dict(st, gkey=st["gkey"].at[slot].set(EMPTY),
                  loc_w=st["loc_w"].at[key].set(W_NONE))
        return _s3_insert_main(st, key)

    def case_none(st):
        S = st["skey"].shape[0]
        s = st["spos"]
        displaced = st["skey"][s]
        dfreq = st["sfreq"][s]

        def promote(st):
            return _s3_insert_main(
                dict(st, loc_w=st["loc_w"].at[displaced].set(W_NONE)), displaced)

        def demote(st):
            return _s3_ghost_push(
                dict(st, loc_w=st["loc_w"].at[displaced].set(W_NONE)), displaced)

        st = jax.lax.cond(
            displaced >= 0,
            lambda st: jax.lax.cond(dfreq >= st["promote_at"], promote,
                                    demote, st),
            lambda st: st, st)
        return dict(
            st,
            skey=st["skey"].at[s].set(key),
            sfreq=st["sfreq"].at[s].set(0),
            spos=(s + 1) % S,
            loc_w=st["loc_w"].at[key].set(W_SMALL),
            loc_s=st["loc_s"].at[key].set(s))

    st = jax.lax.switch(where.astype(jnp.int32),
                        [case_none, case_small, case_main, case_ghost], st)
    return st, hit


# =============================================================================
# replay drivers
# =============================================================================

_POLICIES = {
    "fifo": (fifo_init, fifo_step),
    "clock": (clock_init, clock_step),
    "lru": (lru_init, lru_step),
    "s3fifo": (s3fifo_init, s3fifo_step),
    "clock2q+": (c2qp_init, c2qp_step),
    # Clock2Q == Clock2Q+ with 2Q sizing and the window covering the whole
    # Small FIFO (the ref bit is never set while resident there, §3.2).
    "clock2q": (functools.partial(c2qp_init, small_frac=0.25,
                                  window_frac=10.0), c2qp_step),
}


def jax_policy_names():
    return sorted(_POLICIES)


def init_state(policy: str, capacity: int, universe: int, **kw) -> Dict:
    init, _ = _POLICIES[policy]
    return init(capacity, universe, **kw)


@functools.partial(jax.jit, static_argnames=("policy",))
def replay(policy: str, state: Dict, trace: jnp.ndarray):
    """Replay one trace; returns (final_state, hits[bool per request])."""
    _, step = _POLICIES[policy]
    return jax.lax.scan(step, state, trace)


def replay_np(policy: str, trace: np.ndarray, capacity: int,
              universe: int | None = None, **kw):
    """Convenience host-side wrapper returning a hit-count + miss ratio."""
    trace = np.asarray(trace)
    if universe is None:
        universe = int(trace.max()) + 1
    st = init_state(policy, capacity, int(universe), **kw)
    _, hits = replay(policy, st, jnp.asarray(trace, jnp.int32))
    h = int(np.sum(np.asarray(hits)))
    return h, 1.0 - h / max(1, len(trace))


# =============================================================================
# chunked state-carry replay (streaming traces through TraceStore chunks)
# =============================================================================

@functools.lru_cache(maxsize=1)
def _replay_carry():
    """Resolved lazily so importing this module never initializes a JAX
    backend (device probing can hang minutes in hermetic environments).
    Donating the carried state lets XLA reuse its buffers across chunk
    calls (the state never needs two live copies); the CPU backend
    ignores donation with a warning, so only request it where it's
    implemented."""
    if jax.default_backend() == "cpu":
        return replay
    return jax.jit(
        lambda policy, state, trace: jax.lax.scan(
            _POLICIES[policy][1], state, trace),
        static_argnums=(0,), donate_argnums=(1,))


def replay_chunked(policy: str, chunks, capacity: int, universe: int,
                   state: Dict | None = None, **kw):
    """Replay an iterable of key chunks, threading the scan state across
    chunk boundaries.  ``lax.scan`` is sequential, so splitting a trace
    at ANY boundary and carrying the state is bit-identical to the
    single-shot ``replay`` of the concatenated trace (asserted in
    tests/test_chunked.py) — but peak memory holds one chunk, not the
    trace.  Chunks of equal length share one compiled executable; only a
    ragged tail chunk triggers a second compile.

    Returns ``(hits, n_requests, final_state)`` — pass ``state`` back in
    to continue a stream across calls.
    """
    universe = int(universe)
    if not (0 < universe <= np.iinfo(np.int32).max):
        # Keys are int32 ids with dense (universe,)-sized location tables:
        # raw production obj_ids (sparse/hashed 64-bit) must be relabelled
        # first — tuning.sweep.relabel in memory, or once on disk with
        # `python -m repro.traceio.convert --relabel`.
        raise ValueError(
            f"universe {universe} does not fit the engine's dense int32 id "
            "space; relabel the trace to [0, n_unique) first "
            "(repro.tuning.sweep.relabel or convert --relabel)")
    st = init_state(policy, capacity, universe, **kw) \
        if state is None else state
    carry = _replay_carry()
    hits = 0
    n = 0
    for chunk in chunks:
        arr = np.ascontiguousarray(chunk)
        # negative keys appear when hashed obj_ids >= 2**63 wrap through
        # the oracleGeneral uint64->int64 load — reject those too, or they
        # would wrap-index the dense tables instead of erroring
        if arr.size and (int(arr.max()) >= universe or int(arr.min()) < 0):
            bad = int(arr.max()) if int(arr.max()) >= universe \
                else int(arr.min())
            raise ValueError(
                f"chunk contains key {bad} outside [0, {universe}); "
                "relabel the trace (convert --relabel) or pass a larger "
                "universe")
        st, h = carry(policy, st, jnp.asarray(arr, jnp.int32))
        hits += int(np.asarray(jnp.sum(h)))
        n += int(arr.shape[0])
    return hits, n, st


def replay_store(policy: str, store, capacity: int,
                 universe: int | None = None,
                 chunk_size: int = 1 << 20, **kw):
    """``replay_np`` for an on-disk trace: stream a ``TraceStore`` (or
    anything ``repro.traceio.iter_chunks`` accepts) in ``chunk_size``
    pieces.  Returns (hit count, miss ratio), bit-identical to loading
    the whole trace and calling ``replay_np``."""
    from repro.traceio.store import TraceStore, iter_chunks

    if universe is None:
        if isinstance(store, TraceStore):
            universe = store.universe(chunk_size)
        elif isinstance(store, np.ndarray):
            universe = int(store.max()) + 1
        else:
            raise ValueError("pass universe= explicitly when streaming "
                             "from a one-shot chunk iterable")
    h, n, _ = replay_chunked(policy, iter_chunks(store, chunk_size),
                             capacity, int(universe), **kw)
    return h, 1.0 - h / max(1, n)


def replay_batch(policy: str, states: Dict, traces: jnp.ndarray):
    """vmap over leading lane axis of both states and traces."""
    _, step = _POLICIES[policy]

    def one(state, tr):
        return jax.lax.scan(step, state, tr)

    return jax.vmap(one)(states, traces)


# =============================================================================
# sharded simulation (repro.shardcache's partitioning, vmap-ed)
# =============================================================================

def sharded_replay(policy: str, trace: np.ndarray, capacity: int,
                   n_shards: int, universe: int | None = None, **kw):
    """Simulate the hash-sharded service: partition ``trace`` by the
    shardcache key hash into ``n_shards`` subtraces, replay them as vmap
    lanes at ``round(capacity / n_shards)`` each, and merge the per-lane
    hit arrays back into request order.

    Returns a bool hit array aligned with ``trace``.  Lanes are padded to
    equal length; the pad accesses run *after* every real access in their
    lane, so they cannot perturb real hits.

    vmap lanes must share state shapes, so every shard gets the SAME
    capacity ``round(capacity / n_shards)`` — the total simulated capacity
    can differ from ``capacity`` by up to ``n_shards // 2`` slots in either
    direction.  Pass a capacity divisible by ``n_shards`` for an exact
    equal-total comparison with the unsharded baseline (the benchmarks and
    parity tests do).
    """
    from repro.shardcache.hashing import shard_of_np

    trace = np.asarray(trace)
    if universe is None:
        universe = int(trace.max()) + 1
    cap_shard = int(round(capacity / n_shards))
    if cap_shard < 2:
        raise ValueError(f"capacity {capacity} too small for {n_shards} shards")
    sid = shard_of_np(trace, n_shards)
    idx = [np.nonzero(sid == s)[0] for s in range(n_shards)]
    lane_len = max((len(ix) for ix in idx), default=1) or 1
    lanes = np.zeros((n_shards, lane_len), dtype=np.int32)
    for s, ix in enumerate(idx):
        lanes[s, :len(ix)] = trace[ix]
    states = jax.vmap(
        lambda _: init_state(policy, cap_shard, int(universe), **kw))(
        jnp.arange(n_shards))
    _, hits = replay_batch(policy, states, jnp.asarray(lanes))
    hits = np.asarray(hits)
    merged = np.zeros(trace.shape[0], dtype=bool)
    for s, ix in enumerate(idx):
        merged[ix] = hits[s, :len(ix)]
    return merged


def sharded_replay_np(policy: str, trace: np.ndarray, capacity: int,
                      n_shards: int, universe: int | None = None, **kw):
    """Host-side convenience wrapper: (hit count, miss ratio)."""
    merged = sharded_replay(policy, trace, capacity, n_shards,
                            universe=universe, **kw)
    h = int(merged.sum())
    return h, 1.0 - h / max(1, merged.shape[0])
