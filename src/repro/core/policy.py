"""Cache-policy API shared by every replacement algorithm in the zoo.

Keys are opaque hashable block ids (ints in practice).  ``access`` returns
True on a hit.  Policies that support dirty blocks accept ``dirty=True`` on
access (a write); others ignore the flag.

Event recording (``record_events=True``) captures queue-flow events used by
the Table-1 / Fig-10 reproductions:

    ("small_to_main", key, t) | ("small_to_ghost", key, t) |
    ("ghost_to_main", key, t) | ("evict_main", key, t) | ("evict_small", key, t)
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, List, Optional, Tuple


@dataclasses.dataclass
class SimResult:
    name: str
    capacity: int
    requests: int
    hits: int

    @property
    def misses(self) -> int:
        return self.requests - self.hits

    @property
    def miss_ratio(self) -> float:
        return self.misses / max(1, self.requests)

    @property
    def hit_ratio(self) -> float:
        return self.hits / max(1, self.requests)


class CachePolicy:
    """Base class.  Subclasses implement ``access``."""

    name: str = "base"

    def __init__(self, capacity: int, record_events: bool = False):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.record_events = record_events
        self.events: List[Tuple[str, int, int]] = []
        self.clock_time = 0  # request counter, advanced by access()

    # -- subclass API ------------------------------------------------------
    def access(self, key, dirty: bool = False) -> bool:
        raise NotImplementedError

    def __contains__(self, key) -> bool:  # resident (data present, not ghost)
        raise NotImplementedError

    def __len__(self) -> int:  # number of resident blocks
        raise NotImplementedError

    # -- shared helpers ----------------------------------------------------
    def _event(self, kind: str, key) -> None:
        if self.record_events:
            self.events.append((kind, key, self.clock_time))

    def run(self, trace: Iterable, dirty_fn: Optional[Callable] = None) -> SimResult:
        """Replay ``trace``; ``dirty_fn(i, key) -> bool`` marks writes."""
        hits = 0
        n = 0
        for i, key in enumerate(trace):
            self.clock_time = i
            d = bool(dirty_fn(i, key)) if dirty_fn is not None else False
            hits += self.access(key, dirty=d)
            n += 1
        return SimResult(self.name, self.capacity, n, hits)


# ---------------------------------------------------------------------------
# registry

_REGISTRY: Dict[str, Callable[..., CachePolicy]] = {}


def register(name: str):
    def deco(factory):
        _REGISTRY[name] = factory
        return factory
    return deco


def make_policy(name: str, capacity: int, **kw) -> CachePolicy:
    if name not in _REGISTRY:
        raise KeyError(f"unknown policy {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name](capacity, **kw)


def policy_names() -> List[str]:
    return sorted(_REGISTRY)


def seg_size(capacity: int, frac: float, minimum: int = 1) -> int:
    """Segment sizing helper: round(frac*capacity) clamped to [minimum, capacity-?]."""
    return max(minimum, int(round(capacity * frac)))
