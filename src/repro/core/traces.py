"""Synthetic trace generation + metadata-trace derivation (paper §2.3, §5.1).

The CloudPhysics/Wikimedia/Meta/Tencent datasets are not available offline,
so the benchmarks run on seeded synthetic traces that reproduce the access-
pattern *classes* the paper's analysis relies on:

  * ``storage_data_trace`` — block (LBN) traces: Zipf-popular region +
    sequential runs + uniform cold traffic + working-set drift + periodic
    scans, optionally filtered through an upper-tier LRU (paper §2.2: the
    upper file system's own cache removes temporal locality before requests
    reach the lower layer).
  * ``derive_metadata`` — LBN // fanout (paper §2.3; fanout 200 = vSAN ESA).
  * ``object_trace`` — skewed key-value/object workloads with churn, for the
    non-block evaluation (Fig. 14).

All generators are pure functions of their seed.
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass

import numpy as np

DEFAULT_FANOUT = 200


def _zipf_cdf(universe: int, alpha: float) -> np.ndarray:
    ranks = np.arange(1, universe + 1, dtype=np.float64)
    w = ranks ** (-alpha)
    return np.cumsum(w) / np.sum(w)


def zipf_trace(n: int, universe: int, alpha: float = 1.0, seed: int = 0,
               permute: bool = True) -> np.ndarray:
    """Zipf(alpha) over ``universe`` ids; ranks scattered over the id space."""
    rng = np.random.default_rng(seed)
    cdf = _zipf_cdf(universe, alpha)
    ranks = np.searchsorted(cdf, rng.random(n))
    if permute:
        perm = rng.permutation(universe)
        return perm[ranks].astype(np.int64)
    return ranks.astype(np.int64)


def upper_tier_filter(trace: np.ndarray, cache_size: int) -> np.ndarray:
    """Replay through an LRU of ``cache_size`` and return only the misses —
    models the upper file system's data cache (paper §2.2)."""
    od: OrderedDict = OrderedDict()
    out = []
    for k in trace.tolist():
        if k in od:
            od.move_to_end(k)
            continue
        if len(od) >= cache_size:
            od.popitem(last=False)
        od[k] = None
        out.append(k)
    return np.asarray(out, dtype=np.int64)


def storage_data_trace(n: int, universe: int = 1 << 21, seed: int = 0,
                       zipf_alpha: float = 1.1, n_files: int = 4096,
                       frac_seq_in_file: float = 0.6, mean_run: int = 48,
                       frac_cold: float = 0.05, scan_every: int = 0,
                       scan_len: int = 0, drift_epochs: int = 0,
                       upper_cache_frac: float = 0.0,
                       frac_rmw: float = 0.15, rmw_gap: int = 12) -> np.ndarray:
    """Composite production-like LBN trace.

    The LBN space is carved into ``n_files`` extents with lognormal sizes;
    file popularity is Zipf(``zipf_alpha``).  Requests to a file are either
    sequential runs (geometric length) or uniform-random within the file.
    This preserves *spatial* locality (hot files -> hot extents), which is
    what makes the derived metadata trace realistic: hot leaves stay hot
    long-term, while sequential runs create short correlated-reference
    bursts on consecutive leaves (paper §2.2).
    """
    rng = np.random.default_rng(seed)
    # -- carve the LBN space into files --------------------------------------
    raw = rng.lognormal(mean=5.0, sigma=1.6, size=n_files)
    sizes = np.maximum(4, (raw / raw.sum() * universe)).astype(np.int64)
    starts = np.concatenate([[0], np.cumsum(sizes)[:-1]])
    starts = np.minimum(starts, universe - 1)
    sizes = np.minimum(sizes, universe - starts)
    cdf = _zipf_cdf(n_files, zipf_alpha)
    rank_to_file = rng.permutation(n_files)
    epoch_len = max(1, n // max(1, drift_epochs)) if drift_epochs else n + 1
    # -- emit ------------------------------------------------------------------
    pieces = []
    emitted = 0
    while emitted < n:
        drift = ((emitted // epoch_len) * 1009) if drift_epochs else 0
        r = rng.random()
        if r < frac_cold:  # uniform cold block anywhere on the volume
            pieces.append(np.asarray([rng.integers(0, universe)], dtype=np.int64))
            emitted += 1
            continue
        rank = int(np.searchsorted(cdf, rng.random()))
        f = int(rank_to_file[(rank + drift) % n_files])
        base, fsz = int(starts[f]), int(sizes[f])
        if rng.random() < frac_seq_in_file:  # sequential run within the file
            run = min(1 + int(rng.geometric(1.0 / mean_run)), fsz, n - emitted)
            off = int(rng.integers(0, max(1, fsz - run + 1)))
            pieces.append(base + np.arange(off, off + run, dtype=np.int64))
            emitted += run
        else:  # random block within the file
            pieces.append(np.asarray([base + rng.integers(0, fsz)], dtype=np.int64))
            emitted += 1
    out = np.concatenate(pieces)[:n]
    if scan_every and scan_len:
        pieces = []
        for j in range(0, n, scan_every):
            pieces.append(out[j:j + scan_every])
            start = int(rng.integers(0, max(1, universe - scan_len)))
            pieces.append(np.arange(start, start + scan_len, dtype=np.int64))
        out = np.concatenate(pieces)[:n + (n // scan_every) * scan_len]
    if upper_cache_frac > 0:
        out = upper_tier_filter(out, max(1, int(upper_cache_frac * universe)))
    if frac_rmw > 0:
        out = _inject_rmw(out, frac_rmw, rmw_gap, rng)
    return out


def _inject_rmw(trace: np.ndarray, frac: float, gap: int, rng) -> np.ndarray:
    """Read-modify-write injection: with prob ``frac`` a request is repeated
    once a few requests later (partial-block write / flush-readback).  These
    are data-level correlated references (paper §5.3 conjectures real data
    traces contain them)."""
    import heapq
    dup = rng.random(trace.size) < frac
    gaps = rng.integers(1, gap + 1, size=trace.size)
    out = []
    pending = []  # (due input index, key)
    for i, k in enumerate(trace.tolist()):
        while pending and pending[0][0] <= i:
            out.append(heapq.heappop(pending)[1])
        out.append(k)
        if dup[i]:
            heapq.heappush(pending, (i + int(gaps[i]), k))
    out.extend(k for _, k in sorted(pending))
    return np.asarray(out, dtype=np.int64)


def derive_metadata(trace: np.ndarray, fanout: int = DEFAULT_FANOUT) -> np.ndarray:
    """Paper §2.3: metadata block id = LBN // fanout."""
    return (np.asarray(trace, dtype=np.int64) // fanout)


def object_trace(n: int, universe: int = 1 << 17, alpha: float = 1.2,
                 churn_frac: float = 0.1, seed: int = 0) -> np.ndarray:
    """Skewed object/key-value workload with arrival churn (Fig. 14 class)."""
    rng = np.random.default_rng(seed)
    base = zipf_trace(n, universe, alpha=alpha, seed=seed + 1)
    churn_mask = rng.random(n) < churn_frac
    # churned requests address a moving window of 'new' objects
    new_ids = universe + (np.arange(n) // max(1, n // universe))
    base[churn_mask] = new_ids[churn_mask]
    return base


def correlated_burst_trace(n_ops: int, universe: int = 1 << 16,
                           alpha: float = 0.8, burst_max: int = 4,
                           burst_window: int = 8, seed: int = 0) -> np.ndarray:
    """Explicit correlated-reference generator: every logical op touches its
    block 1..burst_max times within a short window (multiple tuples read
    from one metadata leaf), independent of the block's long-term heat."""
    rng = np.random.default_rng(seed)
    blocks = zipf_trace(n_ops, universe, alpha=alpha, seed=seed + 7)
    out = []
    pending = []  # (emit_at, key)
    t = 0
    for b in blocks.tolist():
        reps = int(rng.integers(1, burst_max + 1))
        out.append(b)
        t += 1
        for _ in range(reps - 1):
            pending.append((t + int(rng.integers(1, burst_window)), b))
        pending.sort()
        while pending and pending[0][0] <= t:
            out.append(pending.pop(0)[1])
            t += 1
    out.extend(k for _, k in pending)
    return np.asarray(out, dtype=np.int64)


@dataclass(frozen=True)
class TraceSpec:
    """Named, seeded workload used across benchmarks (a stand-in for one
    CloudPhysics trace)."""
    name: str
    n: int
    universe: int
    seed: int
    zipf_alpha: float = 1.1
    n_files: int = 4096
    frac_seq_in_file: float = 0.6
    mean_run: int = 48
    frac_cold: float = 0.05
    scan_every: int = 0
    scan_len: int = 0
    drift_epochs: int = 0
    upper_cache_frac: float = 0.0

    def data(self) -> np.ndarray:
        return storage_data_trace(
            self.n, self.universe, seed=self.seed, zipf_alpha=self.zipf_alpha,
            n_files=self.n_files, frac_seq_in_file=self.frac_seq_in_file,
            mean_run=self.mean_run, frac_cold=self.frac_cold,
            scan_every=self.scan_every, scan_len=self.scan_len,
            drift_epochs=self.drift_epochs,
            upper_cache_frac=self.upper_cache_frac)

    def metadata(self, fanout: int = DEFAULT_FANOUT) -> np.ndarray:
        return derive_metadata(self.data(), fanout)


# The benchmark suite: a spread of skews / scan intensities / localities /
# run lengths, mirroring the diversity of the 106 CloudPhysics traces at
# reduced scale.
SUITE = [
    TraceSpec("w01-skewed", n=400_000, universe=1 << 21, seed=101, zipf_alpha=1.3),
    TraceSpec("w02-balanced", n=400_000, universe=1 << 21, seed=202, zipf_alpha=1.0),
    TraceSpec("w03-seqheavy", n=400_000, universe=1 << 21, seed=303,
              zipf_alpha=0.9, frac_seq_in_file=0.85, mean_run=128),
    TraceSpec("w04-scans", n=400_000, universe=1 << 21, seed=404,
              zipf_alpha=1.1, scan_every=50_000, scan_len=20_000),
    TraceSpec("w05-filtered", n=400_000, universe=1 << 20, seed=505,
              zipf_alpha=1.2, upper_cache_frac=0.01),
    TraceSpec("w06-flat", n=400_000, universe=1 << 20, seed=606,
              zipf_alpha=0.7, frac_seq_in_file=0.4, mean_run=24),
    TraceSpec("w07-drift", n=400_000, universe=1 << 21, seed=707,
              zipf_alpha=1.1, drift_epochs=5),
    TraceSpec("w08-random", n=400_000, universe=1 << 20, seed=808,
              zipf_alpha=1.0, frac_seq_in_file=0.15, frac_cold=0.15),
]


def footprint(trace: np.ndarray) -> int:
    return int(np.unique(np.asarray(trace)).size)


def suite_capacity(trace: np.ndarray, frac: float = 0.05, align: int = 8,
                   floor: int = 64) -> int:
    """The benchmark/parity capacity rule: ``frac`` of the trace footprint,
    floored and aligned (shared by benchmarks/shard.py and the shardcache
    parity tests so both always compare against the same baseline)."""
    cap = max(floor, int(frac * footprint(trace)))
    return cap - cap % align
