"""Synthetic trace generation + metadata-trace derivation (paper §2.3, §5.1).

The CloudPhysics/Wikimedia/Meta/Tencent datasets are not available offline,
so the benchmarks run on seeded synthetic traces that reproduce the access-
pattern *classes* the paper's analysis relies on:

  * ``storage_data_trace`` — block (LBN) traces: Zipf-popular region +
    sequential runs + uniform cold traffic + working-set drift + periodic
    scans, optionally filtered through an upper-tier LRU (paper §2.2: the
    upper file system's own cache removes temporal locality before requests
    reach the lower layer).
  * ``derive_metadata`` — LBN // fanout (paper §2.3; fanout 200 = vSAN ESA).
  * ``object_trace`` — skewed key-value/object workloads with churn, for the
    non-block evaluation (Fig. 14).

All generators are pure functions of their seed, and every workload class
is registered by name in ``SCENARIOS`` (the scenario zoo): benchmarks,
the conformance suite, and the ``repro.traceio.convert`` CLI resolve
workloads with ``make_trace(name, n=..., seed=...)`` instead of hardcoding
generator calls.  Register new classes with ``register_scenario``.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from dataclasses import dataclass
from typing import Callable, Dict

import numpy as np

DEFAULT_FANOUT = 200


def _zipf_cdf(universe: int, alpha: float) -> np.ndarray:
    ranks = np.arange(1, universe + 1, dtype=np.float64)
    w = ranks ** (-alpha)
    return np.cumsum(w) / np.sum(w)


def zipf_trace(n: int, universe: int, alpha: float = 1.0, seed: int = 0,
               permute: bool = True) -> np.ndarray:
    """Zipf(alpha) over ``universe`` ids; ranks scattered over the id space."""
    rng = np.random.default_rng(seed)
    cdf = _zipf_cdf(universe, alpha)
    ranks = np.searchsorted(cdf, rng.random(n))
    if permute:
        perm = rng.permutation(universe)
        return perm[ranks].astype(np.int64)
    return ranks.astype(np.int64)


def upper_tier_filter(trace: np.ndarray, cache_size: int) -> np.ndarray:
    """Replay through an LRU of ``cache_size`` and return only the misses —
    models the upper file system's data cache (paper §2.2)."""
    od: OrderedDict = OrderedDict()
    out = []
    for k in trace.tolist():
        if k in od:
            od.move_to_end(k)
            continue
        if len(od) >= cache_size:
            od.popitem(last=False)
        od[k] = None
        out.append(k)
    return np.asarray(out, dtype=np.int64)


def storage_data_trace(n: int, universe: int = 1 << 21, seed: int = 0,
                       zipf_alpha: float = 1.1, n_files: int = 4096,
                       frac_seq_in_file: float = 0.6, mean_run: int = 48,
                       frac_cold: float = 0.05, scan_every: int = 0,
                       scan_len: int = 0, drift_epochs: int = 0,
                       upper_cache_frac: float = 0.0,
                       frac_rmw: float = 0.15, rmw_gap: int = 12) -> np.ndarray:
    """Composite production-like LBN trace.

    The LBN space is carved into ``n_files`` extents with lognormal sizes;
    file popularity is Zipf(``zipf_alpha``).  Requests to a file are either
    sequential runs (geometric length) or uniform-random within the file.
    This preserves *spatial* locality (hot files -> hot extents), which is
    what makes the derived metadata trace realistic: hot leaves stay hot
    long-term, while sequential runs create short correlated-reference
    bursts on consecutive leaves (paper §2.2).
    """
    rng = np.random.default_rng(seed)
    # -- carve the LBN space into files --------------------------------------
    raw = rng.lognormal(mean=5.0, sigma=1.6, size=n_files)
    sizes = np.maximum(4, (raw / raw.sum() * universe)).astype(np.int64)
    starts = np.concatenate([[0], np.cumsum(sizes)[:-1]])
    starts = np.minimum(starts, universe - 1)
    sizes = np.minimum(sizes, universe - starts)
    cdf = _zipf_cdf(n_files, zipf_alpha)
    rank_to_file = rng.permutation(n_files)
    epoch_len = max(1, n // max(1, drift_epochs)) if drift_epochs else n + 1
    # -- emit ------------------------------------------------------------------
    pieces = []
    emitted = 0
    while emitted < n:
        drift = ((emitted // epoch_len) * 1009) if drift_epochs else 0
        r = rng.random()
        if r < frac_cold:  # uniform cold block anywhere on the volume
            pieces.append(np.asarray([rng.integers(0, universe)], dtype=np.int64))
            emitted += 1
            continue
        rank = int(np.searchsorted(cdf, rng.random()))
        f = int(rank_to_file[(rank + drift) % n_files])
        base, fsz = int(starts[f]), int(sizes[f])
        if rng.random() < frac_seq_in_file:  # sequential run within the file
            run = min(1 + int(rng.geometric(1.0 / mean_run)), fsz, n - emitted)
            off = int(rng.integers(0, max(1, fsz - run + 1)))
            pieces.append(base + np.arange(off, off + run, dtype=np.int64))
            emitted += run
        else:  # random block within the file
            pieces.append(np.asarray([base + rng.integers(0, fsz)], dtype=np.int64))
            emitted += 1
    out = np.concatenate(pieces)[:n]
    if scan_every and scan_len:
        pieces = []
        for j in range(0, n, scan_every):
            pieces.append(out[j:j + scan_every])
            start = int(rng.integers(0, max(1, universe - scan_len)))
            pieces.append(np.arange(start, start + scan_len, dtype=np.int64))
        out = np.concatenate(pieces)[:n + (n // scan_every) * scan_len]
    if upper_cache_frac > 0:
        out = upper_tier_filter(out, max(1, int(upper_cache_frac * universe)))
    if frac_rmw > 0:
        out = _inject_rmw(out, frac_rmw, rmw_gap, rng)
    return out


def _inject_rmw(trace: np.ndarray, frac: float, gap: int, rng) -> np.ndarray:
    """Read-modify-write injection: with prob ``frac`` a request is repeated
    once a few requests later (partial-block write / flush-readback).  These
    are data-level correlated references (paper §5.3 conjectures real data
    traces contain them)."""
    import heapq
    dup = rng.random(trace.size) < frac
    gaps = rng.integers(1, gap + 1, size=trace.size)
    out = []
    pending = []  # (due input index, key)
    for i, k in enumerate(trace.tolist()):
        while pending and pending[0][0] <= i:
            out.append(heapq.heappop(pending)[1])
        out.append(k)
        if dup[i]:
            heapq.heappush(pending, (i + int(gaps[i]), k))
    out.extend(k for _, k in sorted(pending))
    return np.asarray(out, dtype=np.int64)


def derive_metadata(trace: np.ndarray, fanout: int = DEFAULT_FANOUT) -> np.ndarray:
    """Paper §2.3: metadata block id = LBN // fanout."""
    return (np.asarray(trace, dtype=np.int64) // fanout)


def object_trace(n: int, universe: int = 1 << 17, alpha: float = 1.2,
                 churn_frac: float = 0.1, seed: int = 0) -> np.ndarray:
    """Skewed object/key-value workload with arrival churn (Fig. 14 class)."""
    rng = np.random.default_rng(seed)
    base = zipf_trace(n, universe, alpha=alpha, seed=seed + 1)
    churn_mask = rng.random(n) < churn_frac
    # churned requests address a moving window of 'new' objects
    new_ids = universe + (np.arange(n) // max(1, n // universe))
    base[churn_mask] = new_ids[churn_mask]
    return base


def correlated_burst_trace(n_ops: int, universe: int = 1 << 16,
                           alpha: float = 0.8, burst_max: int = 4,
                           burst_window: int = 8, seed: int = 0) -> np.ndarray:
    """Explicit correlated-reference generator: every logical op touches its
    block 1..burst_max times within a short window (multiple tuples read
    from one metadata leaf), independent of the block's long-term heat."""
    rng = np.random.default_rng(seed)
    blocks = zipf_trace(n_ops, universe, alpha=alpha, seed=seed + 7)
    out = []
    pending = []  # (emit_at, key)
    t = 0
    for b in blocks.tolist():
        reps = int(rng.integers(1, burst_max + 1))
        out.append(b)
        t += 1
        for _ in range(reps - 1):
            pending.append((t + int(rng.integers(1, burst_window)), b))
        pending.sort()
        while pending and pending[0][0] <= t:
            out.append(pending.pop(0)[1])
            t += 1
    out.extend(k for _, k in pending)
    return np.asarray(out, dtype=np.int64)


# =============================================================================
# additional workload classes (the scenario zoo beyond the paper's three)
# =============================================================================

def cyclic_loop_trace(n: int, universe: int = 1 << 15, loop_frac: float = 0.8,
                      noise_frac: float = 0.05, seed: int = 0) -> np.ndarray:
    """Repeated sequential loop over ``loop_frac`` of the id space with a
    sprinkle of uniform noise — the classic LRU-adversarial scan/loop
    pattern (every reuse distance equals the loop length)."""
    rng = np.random.default_rng(seed)
    loop_len = max(1, int(round(loop_frac * universe)))
    out = (np.arange(n, dtype=np.int64) % loop_len)
    noise = rng.random(n) < noise_frac
    out[noise] = rng.integers(0, universe, int(noise.sum()))
    return out


def multi_tenant_trace(n: int, universe: int = 1 << 18, n_tenants: int = 4,
                       alphas=(1.3, 1.1, 0.9, 0.7),
                       weights=(0.4, 0.3, 0.2, 0.1),
                       seed: int = 0) -> np.ndarray:
    """``n_tenants`` workloads with disjoint key ranges and different
    skews, interleaved by traffic weight — the consolidated-cluster mix a
    shared metadata cache actually serves."""
    rng = np.random.default_rng(seed)
    if not (len(alphas) == len(weights) == n_tenants):
        raise ValueError("need one alpha and one weight per tenant")
    w = np.asarray(weights, dtype=np.float64)
    w = w / w.sum()
    tenant = rng.choice(n_tenants, size=n, p=w)
    span = universe // n_tenants
    out = np.empty(n, dtype=np.int64)
    for t in range(n_tenants):
        idx = np.nonzero(tenant == t)[0]
        if idx.size == 0:
            continue
        sub = zipf_trace(idx.size, max(1, span), alpha=float(alphas[t]),
                         seed=seed + 101 * (t + 1))
        out[idx] = t * span + sub
    return out


def diurnal_trace(n: int, universe: int = 1 << 18, hot_frac: float = 0.05,
                  n_periods: float = 2.0, alpha: float = 1.2,
                  seed: int = 0) -> np.ndarray:
    """Day/night drift: a Zipf-hot window of ``hot_frac * universe`` keys
    whose center moves sinusoidally across the id space, so the working
    set is stable locally but turns over completely every half period."""
    rng = np.random.default_rng(seed)
    width = max(1, int(round(hot_frac * universe)))
    offsets = zipf_trace(n, width, alpha=alpha, seed=seed + 7)
    phase = 2.0 * np.pi * n_periods * np.arange(n) / max(1, n)
    center = ((0.5 + 0.5 * np.sin(phase)) * (universe - width)).astype(np.int64)
    cold = rng.random(n) < 0.02
    out = center + offsets
    out[cold] = rng.integers(0, universe, int(cold.sum()))
    return out


def flash_crowd_trace(n: int, universe: int = 1 << 18, crowd_size: int = 64,
                      crowd_start: float = 0.4, crowd_len: float = 0.2,
                      crowd_frac: float = 0.8, alpha: float = 1.1,
                      seed: int = 0) -> np.ndarray:
    """Steady Zipf background with a flash crowd: mid-trace, most traffic
    suddenly hammers ``crowd_size`` previously-cold keys, then stops —
    tests how fast admission reacts to (and recovers from) a hot-set
    inversion."""
    rng = np.random.default_rng(seed)
    out = zipf_trace(n, universe - crowd_size, alpha=alpha, seed=seed + 3)
    lo = int(crowd_start * n)
    hi = min(n, lo + int(crowd_len * n))
    in_crowd = np.zeros(n, dtype=bool)
    in_crowd[lo:hi] = rng.random(hi - lo) < crowd_frac
    # crowd keys live at the top of the id space: cold before the spike
    out[in_crowd] = (universe - crowd_size
                     + rng.integers(0, crowd_size, int(in_crowd.sum())))
    return out


def ghost_thrash_trace(n: int, set_size: int = 4096,
                       seed: int = 0) -> np.ndarray:
    """Adversarial ghost-thrash: a strict round-robin over ``set_size``
    keys.  Every reuse distance equals ``set_size``, so for any cache
    smaller than that every access misses, re-enters via the Ghost ring,
    and churns the Main Clock — the worst case for ghost-based admission
    (the N+1-loop analogue of the paper's scan resistance discussion)."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(set_size).astype(np.int64)
    return perm[np.arange(n, dtype=np.int64) % set_size]


def metadata_trace(n: int, fanout: int = DEFAULT_FANOUT,
                   universe: int = 1 << 21, seed: int = 0,
                   **storage_kw) -> np.ndarray:
    """Composite storage trace pushed through the paper's §2.3 metadata
    derivation at an arbitrary fanout (one scenario per tree geometry)."""
    data = storage_data_trace(n, universe=universe, seed=seed, **storage_kw)
    return derive_metadata(data, fanout=fanout)


# =============================================================================
# arrival processes (serving-scheduler simulation harness)
# =============================================================================
# These generators emit *arrival ticks* (sorted, non-decreasing int64) for
# n requests on the scheduler's virtual clock, not cache keys — but they
# live in the same registry so the simulation harness and the SLO
# benchmark resolve them by name like any other workload class.

def poisson_arrivals(n: int, mean_gap: float = 2.0,
                     seed: int = 0) -> np.ndarray:
    """Poisson process: exponential inter-arrival times with mean
    ``mean_gap`` ticks, floored onto the integer clock."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(mean_gap, n)
    return np.floor(np.cumsum(gaps)).astype(np.int64)


def burst_arrivals(n: int, burst: int = 16, period: int = 32,
                   seed: int = 0) -> np.ndarray:
    """On/off bursts: ``burst`` requests land on the same tick every
    ``period`` ticks, with ±25% seeded jitter on the period — the open-
    loop batch-ingest shape that stresses queue bounds and displacement."""
    rng = np.random.default_rng(seed)
    out, t = [], 0
    while len(out) < n:
        out.extend([t] * min(burst, n - len(out)))
        t += period + int(rng.integers(-(period // 4), period // 4 + 1))
    return np.asarray(out[:n], dtype=np.int64)


def adversarial_arrivals(n: int, herd: int = 64, lull: int = 96,
                         seed: int = 0) -> np.ndarray:
    """Thundering herd: long lulls, then a same-tick herd sized to
    overflow the default admission queue, with a seeded trickle during
    the lull — the worst case for bounded admission (sheds and
    displacement every herd) while the lulls test drain-to-idle."""
    rng = np.random.default_rng(seed)
    out, t = [], 0
    while len(out) < n:
        out.extend([t] * min(herd, n - len(out)))
        trickle = sorted(rng.integers(t + 1, t + lull,
                                      max(1, herd // 16)).tolist())
        out.extend(trickle[:max(0, n - len(out))])
        t += lull
    return np.asarray(out[:n], dtype=np.int64)


@dataclass(frozen=True)
class TraceSpec:
    """Named, seeded workload used across benchmarks (a stand-in for one
    CloudPhysics trace)."""
    name: str
    n: int
    universe: int
    seed: int
    zipf_alpha: float = 1.1
    n_files: int = 4096
    frac_seq_in_file: float = 0.6
    mean_run: int = 48
    frac_cold: float = 0.05
    scan_every: int = 0
    scan_len: int = 0
    drift_epochs: int = 0
    upper_cache_frac: float = 0.0

    def data(self) -> np.ndarray:
        return storage_data_trace(
            self.n, self.universe, seed=self.seed, zipf_alpha=self.zipf_alpha,
            n_files=self.n_files, frac_seq_in_file=self.frac_seq_in_file,
            mean_run=self.mean_run, frac_cold=self.frac_cold,
            scan_every=self.scan_every, scan_len=self.scan_len,
            drift_epochs=self.drift_epochs,
            upper_cache_frac=self.upper_cache_frac)

    def metadata(self, fanout: int = DEFAULT_FANOUT) -> np.ndarray:
        return derive_metadata(self.data(), fanout)


# =============================================================================
# scenario registry — the named workload zoo
# =============================================================================

@dataclass(frozen=True)
class Scenario:
    """A named, seeded workload class.  ``generate(n, seed)`` returns the
    request stream to feed a cache (length ~= n; some generators emit a
    few extra requests, e.g. injected RMW duplicates)."""
    name: str
    description: str
    generator: Callable[..., np.ndarray]
    defaults: tuple = ()  # ((param, value), ...) — hashable

    def generate(self, n: int, seed: int = 0, **overrides) -> np.ndarray:
        params = dict(self.defaults)
        params.update(overrides)
        return np.asarray(self.generator(n, seed=seed, **params),
                          dtype=np.int64)


SCENARIOS: Dict[str, Scenario] = {}


def register_scenario(name: str, description: str,
                      generator: Callable[..., np.ndarray],
                      **defaults) -> Scenario:
    """Register a workload class under ``name`` (last registration wins,
    so tests can shadow).  ``generator(n, seed=..., **defaults)`` must be
    a pure function of its arguments."""
    sc = Scenario(name, description, generator, tuple(sorted(defaults.items())))
    SCENARIOS[name] = sc
    return sc


def scenario_names() -> list:
    return sorted(SCENARIOS)


def get_scenario(name: str) -> Scenario:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; registered: "
                       f"{scenario_names()}") from None


def make_trace(name: str, n: int, seed: int = 0, **overrides) -> np.ndarray:
    """Resolve a scenario by name and generate its request stream."""
    return get_scenario(name).generate(n, seed=seed, **overrides)


# The benchmark suite: a spread of skews / scan intensities / localities /
# run lengths, mirroring the diversity of the 106 CloudPhysics traces at
# reduced scale.
SUITE = [
    TraceSpec("w01-skewed", n=400_000, universe=1 << 21, seed=101, zipf_alpha=1.3),
    TraceSpec("w02-balanced", n=400_000, universe=1 << 21, seed=202, zipf_alpha=1.0),
    TraceSpec("w03-seqheavy", n=400_000, universe=1 << 21, seed=303,
              zipf_alpha=0.9, frac_seq_in_file=0.85, mean_run=128),
    TraceSpec("w04-scans", n=400_000, universe=1 << 21, seed=404,
              zipf_alpha=1.1, scan_every=50_000, scan_len=20_000),
    TraceSpec("w05-filtered", n=400_000, universe=1 << 20, seed=505,
              zipf_alpha=1.2, upper_cache_frac=0.01),
    TraceSpec("w06-flat", n=400_000, universe=1 << 20, seed=606,
              zipf_alpha=0.7, frac_seq_in_file=0.4, mean_run=24),
    TraceSpec("w07-drift", n=400_000, universe=1 << 21, seed=707,
              zipf_alpha=1.1, drift_epochs=5),
    TraceSpec("w08-random", n=400_000, universe=1 << 20, seed=808,
              zipf_alpha=1.0, frac_seq_in_file=0.15, frac_cold=0.15),
]

_SUITE_DESCRIPTIONS = {
    "w01-skewed": "highly skewed (Zipf 1.3) production block trace",
    "w02-balanced": "balanced-skew (Zipf 1.0) production block trace",
    "w03-seqheavy": "sequential-run-heavy block trace (85% seq, run 128)",
    "w04-scans": "skewed block trace with periodic full-volume scans",
    "w05-filtered": "block trace behind an upper-tier LRU (locality stripped)",
    "w06-flat": "flat-skew small-run block trace",
    "w07-drift": "working set drifts across 5 epochs",
    "w08-random": "random-dominated block trace (15% cold, few runs)",
}


def _spec_generator(spec: TraceSpec):
    def gen(n: int, seed: int = 0, **overrides) -> np.ndarray:
        return dataclasses.replace(spec, n=n, seed=seed, **overrides).data()
    return gen


for _spec in SUITE:
    register_scenario(_spec.name, _SUITE_DESCRIPTIONS[_spec.name],
                      _spec_generator(_spec))

register_scenario(
    "zipf", "pure Zipf(1.2) popularity over a permuted id space",
    zipf_trace, universe=1 << 17, alpha=1.2)
register_scenario(
    "object-churn", "skewed key-value workload with arrival churn (Fig. 14)",
    object_trace)
register_scenario(
    "correlated-burst",
    "every logical op re-touches its block within a short window (§2.2)",
    correlated_burst_trace)
register_scenario(
    "cyclic-loop", "sequential loop larger than the cache (LRU-adversarial)",
    cyclic_loop_trace)
register_scenario(
    "multi-tenant", "4 tenants, disjoint ranges, different skews, 40/30/20/10",
    multi_tenant_trace)
register_scenario(
    "diurnal", "Zipf-hot window drifting sinusoidally across the id space",
    diurnal_trace)
register_scenario(
    "flash-crowd", "sudden mid-trace spike on previously-cold keys",
    flash_crowd_trace)
register_scenario(
    "write-heavy-rmw",
    "write-heavy block trace: 45% read-modify-write duplication",
    storage_data_trace, universe=1 << 19, frac_seq_in_file=0.3,
    frac_rmw=0.45, rmw_gap=6)
register_scenario(
    "meta-fine", "metadata trace at fanout 16 (fine-grained tree leaves)",
    metadata_trace, fanout=16, universe=1 << 19)
register_scenario(
    "meta-coarse", "metadata trace at fanout 1000 (coarse tree leaves)",
    metadata_trace, fanout=1000, universe=1 << 21)
register_scenario(
    "ghost-thrash",
    "adversarial round-robin: every reuse lands in the Ghost ring",
    ghost_thrash_trace)
register_scenario(
    "arrivals-poisson",
    "serving arrival ticks: Poisson process, mean gap 2 ticks",
    poisson_arrivals)
register_scenario(
    "arrivals-burst",
    "serving arrival ticks: same-tick bursts of 16 every ~32 ticks",
    burst_arrivals)
register_scenario(
    "arrivals-adversarial",
    "serving arrival ticks: thundering herds of 64 between long lulls",
    adversarial_arrivals)


def footprint(trace: np.ndarray) -> int:
    return int(np.unique(np.asarray(trace)).size)


def suite_capacity(trace: np.ndarray, frac: float = 0.05, align: int = 8,
                   floor: int = 64) -> int:
    """The benchmark/parity capacity rule: ``frac`` of the trace footprint,
    floored and aligned (shared by benchmarks/shard.py and the shardcache
    parity tests so both always compare against the same baseline)."""
    cap = max(floor, int(frac * footprint(trace)))
    return cap - cap % align
