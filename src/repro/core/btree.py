"""Insert-order B+tree used to validate the divide-by-fanout metadata
derivation (paper §5.2 / Fig. 7).

Keys are inserted on first access (as a write-anywhere storage B-tree would
allocate mappings on first write) and leaves split at ``fanout`` keys.  The
replay records the *leaf block id* touched by every request; comparing miss
ratios on this trace vs the ``LBN // fanout`` derivation reproduces the
paper's fidelity experiment.
"""

from __future__ import annotations

import bisect

import numpy as np


class LeafBTree:
    def __init__(self, fanout: int = 200):
        self.fanout = fanout
        self.lower = [0]        # sorted lower bounds per leaf position
        self.leaf_ids = [0]     # stable block id per leaf position
        self.leaf_keys = [[]]   # sorted keys per leaf position
        self.next_id = 1
        self.known = set()

    def _leaf_pos(self, key: int) -> int:
        return max(0, bisect.bisect_right(self.lower, key) - 1)

    def lookup_or_insert(self, key: int) -> int:
        pos = self._leaf_pos(key)
        if key not in self.known:
            self.known.add(key)
            keys = self.leaf_keys[pos]
            bisect.insort(keys, key)
            if len(keys) > self.fanout:
                if pos == len(self.leaf_keys) - 1 and keys[-1] == key:
                    # sequential tail insert: split at the end so the left
                    # leaf stays FULL (the classic bulk-load behaviour of
                    # B+trees under in-order insertion, incl. TLX)
                    mid = self.fanout
                else:
                    mid = len(keys) // 2
                right = keys[mid:]
                self.leaf_keys[pos] = keys[:mid]
                rpos = pos + 1
                self.lower.insert(rpos, right[0])
                self.leaf_ids.insert(rpos, self.next_id)
                self.leaf_keys.insert(rpos, right)
                self.next_id += 1
                if key >= right[0]:
                    pos = rpos
        return self.leaf_ids[pos]

    def prepopulate(self, universe: int) -> None:
        """Insert the whole LBN space in order (the volume's map exists
        before the trace runs — matching the paper's TLX experiment)."""
        for k in range(universe):
            self.lookup_or_insert(k)

    @property
    def n_leaves(self) -> int:
        return len(self.leaf_ids)


def btree_metadata_trace(data_trace: np.ndarray, fanout: int = 200,
                         universe: int = 0) -> np.ndarray:
    tree = LeafBTree(fanout)
    if universe:
        tree.prepopulate(universe)
    return np.asarray([tree.lookup_or_insert(int(k)) for k in data_trace],
                      dtype=np.int64)
