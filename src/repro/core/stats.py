"""Simulation drivers + analysis used by the paper-reproduction benchmarks."""

from __future__ import annotations

from typing import Dict, Iterable, List, Sequence

import numpy as np

from repro.core.policy import SimResult, make_policy

_INF = 1 << 62


def simulate(policy_name: str, trace, capacity: int, dirty_fn=None,
             **kw) -> SimResult:
    pol_kw = dict(kw)
    if policy_name == "belady":
        pol_kw["trace"] = trace
    pol = make_policy(policy_name, capacity, **pol_kw)
    return pol.run(trace, dirty_fn=dirty_fn)


def miss_ratios(policy_names: Sequence[str], trace, capacity: int,
                **kw) -> Dict[str, float]:
    return {p: simulate(p, trace, capacity, **kw).miss_ratio
            for p in policy_names}


def improvement_vs_clock(policy_names: Sequence[str], trace,
                         capacity: int, **kw) -> Dict[str, float]:
    """Paper Eq. 1: (MR_clock - MR_algo) / MR_clock."""
    mrs = miss_ratios(list(policy_names) + ["clock"], trace, capacity, **kw)
    base = mrs["clock"]
    return {p: (base - mrs[p]) / max(base, 1e-12) for p in policy_names}


def mrc(policy_name: str, trace, sizes: Iterable[int], **kw) -> Dict[int, float]:
    """Miss-ratio curve over absolute cache sizes."""
    return {int(c): simulate(policy_name, trace, int(c), **kw).miss_ratio
            for c in sizes}


def next_use_indices(trace) -> np.ndarray:
    """next_use[i] = index of the next occurrence of trace[i] after i (or INF)."""
    trace = list(trace)
    n = len(trace)
    nxt = np.full(n, _INF, dtype=np.int64)
    last: Dict = {}
    for i in range(n - 1, -1, -1):
        k = trace[i]
        if k in last:
            nxt[i] = last[k]
        last[k] = i
    return nxt


def flow_nrd(policy_name: str, trace, capacity: int, **kw):
    """Table-1 / Fig-10 reproduction: per queue-flow counts and the next-
    reuse distance (in requests; INF if never reused) of each moved block."""
    pol_kw = dict(kw)
    pol = make_policy(policy_name, capacity, record_events=True, **pol_kw)
    res = pol.run(trace)
    trace = list(trace)
    n = len(trace)
    # occurrences per key for binary search of "next access after t"
    occ: Dict = {}
    for i, k in enumerate(trace):
        occ.setdefault(k, []).append(i)
    flows: Dict[str, List[int]] = {}
    for kind, key, t in pol.events:
        lst = occ.get(key)
        if lst is None:
            continue
        import bisect
        j = bisect.bisect_right(lst, t)
        d = (lst[j] - t) if j < len(lst) else _INF
        flows.setdefault(kind, []).append(d)
    counts = {k: len(v) for k, v in flows.items()}
    return res, counts, flows
