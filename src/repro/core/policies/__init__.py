from repro.core.policies.simple import FIFO, LRU, Clock, SLRU, LFU, SIEVE  # noqa: F401
from repro.core.policies.two_q import TwoQ, Clock2Q  # noqa: F401
from repro.core.policies.s3fifo import S3FIFO  # noqa: F401
from repro.core.policies.clock2qplus import Clock2QPlus  # noqa: F401
from repro.core.policies.arc import ARC  # noqa: F401
from repro.core.policies.tinylfu import WTinyLFU  # noqa: F401
from repro.core.policies.belady import Belady  # noqa: F401
from repro.core.policies.lirs import LIRS  # noqa: F401
