"""2Q (VLDB'94, full version sizing per the paper's Fig. 2) and Clock2Q
(vSAN's previous algorithm: 2Q with the Main LRU replaced by a Clock).

Sizing (paper §3.1/§3.2): Main = 75%, Small FIFO = 25% of capacity,
Ghost FIFO = 50% of capacity (keys only).
"""

from __future__ import annotations

import collections
from collections import OrderedDict

from repro.core.policy import CachePolicy, register, seg_size


class _GhostFIFO:
    """Ghost FIFO with the paper's production ring semantics (§4.1): a ring
    of the last ``capacity`` pushed keys; a promoted (removed) key leaves a
    tombstone that is reclaimed only when the ring wraps over it.

    Entries are sequence-stamped so that lazy removals (ghost hits) never
    evict a newer re-insertion of the same key via a stale ring entry.
    """

    def __init__(self, capacity: int):
        self.capacity = max(1, capacity)
        self.q = collections.deque()  # (key, seq), ring of last `capacity` pushes
        self.members = {}  # key -> latest seq
        self._seq = 0

    def push(self, key):
        self._seq += 1
        self.q.append((key, self._seq))
        self.members[key] = self._seq
        while len(self.q) > self.capacity:
            k, s = self.q.popleft()
            if self.members.get(k) == s:
                del self.members[k]

    def remove(self, key):
        self.members.pop(key, None)  # deque entry becomes stale

    def __contains__(self, key):
        return key in self.members

    def __len__(self):
        return len(self.members)


class _SmallFIFO:
    """Bounded FIFO of resident keys (no ref bits) with O(1) membership."""

    def __init__(self, capacity: int):
        self.capacity = max(1, capacity)
        self.q = collections.deque()
        self.members = set()

    def full(self) -> bool:
        return len(self.q) >= self.capacity

    def push(self, key):
        self.q.append(key)
        self.members.add(key)

    def pop(self):
        key = self.q.popleft()
        self.members.discard(key)
        return key

    def __contains__(self, key):
        return key in self.members

    def __len__(self):
        return len(self.members)


@register("2q")
class TwoQ(CachePolicy):
    name = "2q"

    def __init__(self, capacity: int, small_frac: float = 0.25,
                 ghost_frac: float = 0.5, **kw):
        super().__init__(capacity, **kw)
        small_cap = min(capacity, seg_size(capacity, small_frac))
        self.main_cap = max(1, capacity - small_cap)
        self.small = _SmallFIFO(small_cap)
        self.ghost = _GhostFIFO(seg_size(capacity, ghost_frac))
        self.main = OrderedDict()  # LRU: MRU at end

    def _insert_main(self, key):
        while len(self.main) >= self.main_cap:
            victim, _ = self.main.popitem(last=False)
            self._event("evict_main", victim)
        self.main[key] = None

    def access(self, key, dirty: bool = False) -> bool:
        if key in self.main:
            self.main.move_to_end(key)
            return True
        if key in self.small:
            return True  # 2Q: no action for A1in hits
        if key in self.ghost:
            self.ghost.remove(key)
            self._event("ghost_to_main", key)
            self._insert_main(key)
            return False
        # brand-new block -> Small FIFO
        if self.small.full():
            victim = self.small.pop()
            self._event("small_to_ghost", victim)
            self.ghost.push(victim)
        self.small.push(key)
        return False

    def __contains__(self, key):
        return key in self.main or key in self.small

    def __len__(self):
        return len(self.main) + len(self.small)


class _MainClock:
    """Second-chance clock used as the Main queue of Clock2Q/Clock2Q+/S3-FIFO.

    ``skip_limit``: max ref-skips per eviction before a block is forcibly
    evicted regardless of its ref bit (paper §5.5.2); None = unlimited.
    ``dirty_limit``: max dirty blocks skipped per eviction before giving up.
    """

    def __init__(self, capacity: int, skip_limit=None, dirty_limit: int = 64):
        self.capacity = max(1, capacity)
        self.keys = [None] * self.capacity
        self.ref = [False] * self.capacity
        self.dirty = [False] * self.capacity
        self.slot_of = {}
        self.hand = 0
        self.fill = 0
        self.skip_limit = skip_limit
        self.dirty_limit = dirty_limit
        self.skipped_per_eviction = []  # stats for Fig. 12a

    def full(self) -> bool:
        return self.fill >= self.capacity and len(self.slot_of) >= self.capacity

    def hit(self, key) -> bool:
        s = self.slot_of.get(key)
        if s is None:
            return False
        self.ref[s] = True
        return True

    def set_dirty(self, key, val: bool):
        s = self.slot_of.get(key)
        if s is not None:
            self.dirty[s] = val

    def evict(self):
        """Return the evicted key (and free its slot), honoring skip limits."""
        ref_skips = 0
        dirty_skips = 0
        forced = False
        while True:
            s = self.hand
            if self.keys[s] is None:  # free slot (can happen after resize)
                self.hand = (self.hand + 1) % self.capacity
                continue
            if self.dirty[s]:
                dirty_skips += 1
                self.hand = (self.hand + 1) % self.capacity
                if dirty_skips > self.dirty_limit:
                    # production: trigger synchronous flush of this block
                    self.dirty[s] = False
                continue
            if self.ref[s] and not forced:
                self.ref[s] = False
                ref_skips += 1
                self.hand = (self.hand + 1) % self.capacity
                if self.skip_limit is not None and ref_skips >= self.skip_limit:
                    forced = True  # next clean block goes regardless of ref
                continue
            victim = self.keys[s]
            self.keys[s] = None
            self.ref[s] = False
            del self.slot_of[victim]
            self.hand = (self.hand + 1) % self.capacity
            self.skipped_per_eviction.append(ref_skips)
            return victim

    def insert(self, key, dirty: bool = False):
        """Insert assuming a free slot exists (call evict() first if full)."""
        if self.fill < self.capacity:
            s = self.fill
            self.fill += 1
            if self.keys[s] is not None:  # shouldn't happen
                raise RuntimeError("clock fill bookkeeping broken")
        else:
            # reuse the slot most recently freed by evict(): scan from hand-1
            s = None
            for off in range(self.capacity):
                cand = (self.hand - 1 - off) % self.capacity
                if self.keys[cand] is None:
                    s = cand
                    break
            if s is None:
                raise RuntimeError("insert into full clock without evict")
        self.keys[s] = key
        self.ref[s] = False
        self.dirty[s] = dirty
        self.slot_of[key] = s

    def __contains__(self, key):
        return key in self.slot_of

    def __len__(self):
        return len(self.slot_of)


@register("clock2q")
class Clock2Q(CachePolicy):
    """2Q with a Main Clock (the previous vSAN algorithm, paper §3.2)."""

    name = "clock2q"

    def __init__(self, capacity: int, small_frac: float = 0.25,
                 ghost_frac: float = 0.5, skip_limit=None, **kw):
        super().__init__(capacity, **kw)
        small_cap = min(capacity, seg_size(capacity, small_frac))
        self.small = _SmallFIFO(small_cap)
        self.ghost = _GhostFIFO(seg_size(capacity, ghost_frac))
        self.main = _MainClock(max(1, capacity - small_cap), skip_limit=skip_limit)

    def _insert_main(self, key):
        if self.main.full():
            victim = self.main.evict()
            self._event("evict_main", victim)
        self.main.insert(key)

    def access(self, key, dirty: bool = False) -> bool:
        if self.main.hit(key):
            return True
        if key in self.small:
            return True  # no ref bit in Clock2Q's Small FIFO
        if key in self.ghost:
            self.ghost.remove(key)
            self._event("ghost_to_main", key)
            self._insert_main(key)
            return False
        if self.small.full():
            victim = self.small.pop()
            self._event("small_to_ghost", victim)
            self.ghost.push(victim)
        self.small.push(key)
        return False

    def __contains__(self, key):
        return key in self.main or key in self.small

    def __len__(self):
        return len(self.main) + len(self.small)
