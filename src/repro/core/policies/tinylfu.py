"""W-TinyLFU (Einziger et al., ToS'17): 1% LRU window + SLRU main with a
Count-Min-Sketch admission filter (4 rows, 4-bit-style counters, periodic
halving after a sample of 10x capacity)."""

from __future__ import annotations

from collections import OrderedDict

from repro.core.policy import CachePolicy, register, seg_size

_PRIMES = (0x9E3779B1, 0x85EBCA77, 0xC2B2AE3D, 0x27D4EB2F)


class _CMSketch:
    def __init__(self, capacity: int):
        self.width = max(64, 1 << (4 * capacity - 1).bit_length())
        self.rows = [[0] * self.width for _ in range(4)]
        self.additions = 0
        self.sample = max(128, 10 * capacity)

    def _idx(self, key, row):
        h = (hash(key) * _PRIMES[row]) & 0xFFFFFFFF
        return (h ^ (h >> 16)) % self.width

    def add(self, key):
        for r in range(4):
            i = self._idx(key, r)
            if self.rows[r][i] < 15:
                self.rows[r][i] += 1
        self.additions += 1
        if self.additions >= self.sample:
            self._age()

    def estimate(self, key) -> int:
        return min(self.rows[r][self._idx(key, r)] for r in range(4))

    def _age(self):
        for r in range(4):
            row = self.rows[r]
            for i in range(self.width):
                row[i] >>= 1
        self.additions //= 2


@register("wtinylfu")
class WTinyLFU(CachePolicy):
    name = "wtinylfu"

    def __init__(self, capacity: int, window_frac: float = 0.01, **kw):
        super().__init__(capacity, **kw)
        self.win_cap = min(max(1, capacity - 1), seg_size(capacity, window_frac))
        main_cap = max(1, capacity - self.win_cap)
        self.prob_cap = max(1, main_cap - int(round(main_cap * 0.8)))
        self.prot_cap = main_cap - self.prob_cap
        self.window = OrderedDict()
        self.prob = OrderedDict()
        self.prot = OrderedDict()
        self.sketch = _CMSketch(capacity)

    def _main_insert(self, key):
        """Admit ``key`` into the probationary segment, evicting if needed."""
        if len(self.prob) + len(self.prot) >= self.prob_cap + self.prot_cap:
            victim = next(iter(self.prob)) if self.prob else next(iter(self.prot))
            if self.sketch.estimate(key) <= self.sketch.estimate(victim):
                return  # candidate rejected by the TinyLFU filter
            if self.prob:
                self.prob.popitem(last=False)
            else:
                self.prot.popitem(last=False)
        self.prob[key] = None

    def access(self, key, dirty: bool = False) -> bool:
        self.sketch.add(key)
        if key in self.window:
            self.window.move_to_end(key)
            return True
        if key in self.prot:
            self.prot.move_to_end(key)
            return True
        if key in self.prob:
            del self.prob[key]
            self.prot[key] = None
            while len(self.prot) > self.prot_cap:
                k, _ = self.prot.popitem(last=False)
                self.prob[k] = None
            return True
        # miss: new blocks enter the window
        self.window[key] = None
        if len(self.window) > self.win_cap:
            cand, _ = self.window.popitem(last=False)
            self._main_insert(cand)
        return False

    def __contains__(self, key):
        return key in self.window or key in self.prob or key in self.prot

    def __len__(self):
        return len(self.window) + len(self.prob) + len(self.prot)
