"""LIRS (Jiang & Zhang, SIGMETRICS'02) — low inter-reference recency set.

Stack S holds LIR blocks plus recently-seen HIR blocks (resident or
ghost); queue Q holds resident HIR blocks.  L_hirs = 1% of capacity
(min 1).  The stack's non-resident (ghost) population is bounded at
2x capacity, as production implementations do.
"""

from __future__ import annotations

from collections import OrderedDict, deque

from repro.core.policy import CachePolicy, register


@register("lirs")
class LIRS(CachePolicy):
    name = "lirs"

    def __init__(self, capacity: int, hirs_frac: float = 0.01, **kw):
        super().__init__(capacity, **kw)
        self.l_hirs = min(max(1, int(round(capacity * hirs_frac))),
                          max(1, capacity - 1))
        self.l_lirs = capacity - self.l_hirs
        self.stack = OrderedDict()   # key -> None (most recent at end)
        self.q = deque()             # resident HIR keys (front = oldest)
        self.is_lir = {}             # key -> bool (known keys)
        self.resident = set()
        self.ghost_cap = 2 * capacity
        self._n_lir = 0  # maintained incrementally (residents with LIR)

    # -- helpers ---------------------------------------------------------------
    def _stack_top(self, key):
        self.stack.pop(key, None)
        self.stack[key] = None

    def _prune(self):
        """Remove non-LIR entries from the stack bottom."""
        while self.stack:
            bottom = next(iter(self.stack))
            if self.is_lir.get(bottom, False):
                break
            del self.stack[bottom]
            if bottom not in self.resident:
                self.is_lir.pop(bottom, None)  # forget pruned ghosts

    def _bound_ghosts(self):
        """Amortized: only scan when the stack exceeds capacity+ghost_cap,
        and prune down with slack so scans happen every ~C/2 misses."""
        limit = self.capacity + self.ghost_cap
        if len(self.stack) <= limit:
            return
        target = limit - max(1, self.capacity // 2)  # hysteresis
        to_remove = []
        need = len(self.stack) - target
        for k in self.stack:  # oldest first
            if k not in self.resident and not self.is_lir.get(k):
                to_remove.append(k)
                if len(to_remove) >= need:
                    break
        for k in to_remove:
            del self.stack[k]
            self.is_lir.pop(k, None)

    def _demote_bottom_lir(self):
        """Bottom LIR -> resident HIR at the end of Q."""
        bottom = next(iter(self.stack))
        del self.stack[bottom]
        self.is_lir[bottom] = False
        self._n_lir -= 1
        self.q.append(bottom)
        self._prune()

    def _evict_hir(self):
        victim = self.q.popleft()
        self.resident.discard(victim)
        if victim not in self.stack:
            self.is_lir.pop(victim, None)
        self._event("evict_main", victim)

    @property
    def n_lir(self):
        return self._n_lir

    # -- access ------------------------------------------------------------------
    def access(self, key, dirty: bool = False) -> bool:
        if key in self.resident:
            if self.is_lir.get(key, False):
                was_bottom = next(iter(self.stack)) == key
                self._stack_top(key)
                if was_bottom:
                    self._prune()
            else:  # resident HIR
                if key in self.stack:
                    self.is_lir[key] = True
                    self._n_lir += 1
                    try:
                        self.q.remove(key)
                    except ValueError:
                        pass
                    self._stack_top(key)
                    self._demote_bottom_lir()
                else:
                    self._stack_top(key)
                    try:
                        self.q.remove(key)
                    except ValueError:
                        pass
                    self.q.append(key)
            return True

        # miss
        if len(self.resident) >= self.capacity:
            if self.q:
                self._evict_hir()
            else:  # degenerate: demote a LIR first
                self._demote_bottom_lir()
                self._evict_hir()
        if self.n_lir < self.l_lirs and key not in self.stack:
            # warmup: fill the LIR set directly
            self.is_lir[key] = True
            self._n_lir += 1
            self.resident.add(key)
            self._stack_top(key)
            return False
        if key in self.stack:  # ghost hit: straight to LIR
            self.is_lir[key] = True
            self._n_lir += 1
            self.resident.add(key)
            self._stack_top(key)
            self._demote_bottom_lir()
        else:  # cold block: resident HIR
            self.is_lir[key] = False
            self.resident.add(key)
            self._stack_top(key)
            self.q.append(key)
        self._bound_ghosts()
        return False

    def __contains__(self, key):
        return key in self.resident

    def __len__(self):
        return len(self.resident)
