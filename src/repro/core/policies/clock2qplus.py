"""Clock2Q+ — the paper's contribution (§3.4, §4.1.3, §5.5).

Structure: Small FIFO (10% of capacity) with a correlation window covering
the ``window_frac`` (default 50%) most-recently-inserted entries; Main
Clock (90%); Ghost FIFO (50%, keys only).

Semantics:
  * hit in Small FIFO: the Ref bit is set ONLY if the block has aged past
    the correlation window (i.e. >= W insertions happened since it entered).
  * hit in Main Clock: sets the Ref bit (second chance).
  * miss + ghost hit: block goes straight into the Main Clock.
  * miss: block enters the Small FIFO; Small-FIFO eviction promotes
    ref-set blocks to the Main Clock and pushes the rest to the Ghost FIFO.

Dirty-block handling (§4.1.3, toggled by ``dirty_mode``):
  * "off"        — dirty flags ignored (pure algorithm).
  * "simplified" — production behaviour: dirty blocks are skipped (cycled)
    when picking eviction candidates in the Small FIFO; after
    ``dirty_scan_limit`` dirty skips the new block bypasses straight into
    the Main Clock.  Dirty blocks are never moved Small->Main.
  * "accurate"   — like "simplified" but a dirty block with its Ref bit set
    IS moved to the Main Clock (the behaviour production skips; used as the
    Fig.-11 baseline).

Flushing (§4.1.3): time-based (``flush_after`` requests) + watermark
(``low_water``/``high_water`` fractions of capacity), both simulated in
request time.
"""

from __future__ import annotations

import collections
from collections import OrderedDict

from repro.core.policy import CachePolicy, register, seg_size
from repro.core.policies.two_q import _GhostFIFO, _MainClock


class _SmallEntry:
    __slots__ = ("key", "ref", "dirty", "seq")

    def __init__(self, key, seq):
        self.key = key
        self.ref = False
        self.dirty = False
        self.seq = seq


@register("clock2q+")
class Clock2QPlus(CachePolicy):
    name = "clock2q+"

    def __init__(self, capacity: int, small_frac: float = 0.1,
                 ghost_frac: float = 0.5, window_frac: float = 0.5,
                 skip_limit=None, dirty_mode: str = "off",
                 dirty_scan_limit: int = 16, flush_after: int = 0,
                 low_water: float = 0.1, high_water: float = 0.2,
                 adaptive: bool = False, **kw):
        super().__init__(capacity, **kw)
        if adaptive:
            # Beyond-paper (EXPERIMENTS.md §Perf, core-algorithm hillclimb):
            # the paper's 10%/50% sizing degenerates when the cache is
            # small (Small FIFO of 1-3 slots, window of 0-1 insertions —
            # §5.6 itself observes larger windows help small caches).
            # Floor the Small FIFO at min(8, 25% cap) and the window at
            # min(S, 4): identical to the paper's sizing for production
            # caches, 2Q-like filtering for tiny ones.
            small = max(int(round(0.1 * capacity)),
                        min(8, int(round(0.25 * capacity))))
            small_frac = small / capacity
        small_cap = min(capacity, seg_size(capacity, small_frac))
        self.small_cap = small_cap
        self.window = int(round(window_frac * small_cap))
        if adaptive:
            self.window = min(small_cap, max(self.window, 4))
        self.small = collections.deque()  # _SmallEntry, head = oldest
        self.in_small = {}
        self.ghost = _GhostFIFO(seg_size(capacity, ghost_frac))
        self.main = _MainClock(max(1, capacity - small_cap), skip_limit=skip_limit)
        self.small_seq = 0  # insertion counter for window aging
        assert dirty_mode in ("off", "simplified", "accurate")
        self.dirty_mode = dirty_mode
        self.dirty_scan_limit = dirty_scan_limit
        self.flush_after = flush_after
        self.low_water = low_water
        self.high_water = high_water
        self.dirty_since = OrderedDict()  # key -> request time first dirtied
        self.flows = collections.Counter()

    # -- dirty bookkeeping ---------------------------------------------------
    def _mark_dirty(self, key):
        if self.dirty_mode == "off":
            return
        if key not in self.dirty_since:
            self.dirty_since[key] = self.clock_time
        e = self.in_small.get(key)
        if e is not None:
            e.dirty = True
        else:
            self.main.set_dirty(key, True)

    def _clean(self, key):
        self.dirty_since.pop(key, None)
        e = self.in_small.get(key)
        if e is not None:
            e.dirty = False
        else:
            self.main.set_dirty(key, False)

    def _run_flushers(self):
        if self.dirty_mode == "off":
            return
        if self.flush_after:
            while self.dirty_since:
                key, t0 = next(iter(self.dirty_since.items()))
                if self.clock_time - t0 < self.flush_after:
                    break
                self._clean(key)
        high = self.high_water * self.capacity
        if len(self.dirty_since) > high:
            low = self.low_water * self.capacity
            while len(self.dirty_since) > low:
                key = next(iter(self.dirty_since))
                self._clean(key)

    # -- queue plumbing -------------------------------------------------------
    def _insert_main(self, key, dirty=False):
        if self.main.full():
            victim = self.main.evict()
            self._event("evict_main", victim)
        self.main.insert(key, dirty=dirty)

    def _evict_small(self) -> bool:
        """Free one Small-FIFO slot.  Returns False if every candidate within
        the dirty scan limit was dirty (caller should bypass to Main)."""
        dirty_skips = 0
        while True:
            e = self.small.popleft()
            if e.dirty:
                if self.dirty_mode == "accurate" and e.ref:
                    del self.in_small[e.key]
                    self._event("small_to_main", e.key)
                    self.flows["small_to_main"] += 1
                    self._insert_main(e.key, dirty=True)
                    return True
                # simplified (and accurate-without-ref): cycle it back
                self.small.append(e)
                dirty_skips += 1
                if dirty_skips >= min(self.dirty_scan_limit, len(self.small)):
                    return False
                continue
            del self.in_small[e.key]
            if e.ref:
                self._event("small_to_main", e.key)
                self.flows["small_to_main"] += 1
                self._insert_main(e.key)
            else:
                self._event("small_to_ghost", e.key)
                self.flows["small_to_ghost"] += 1
                self.ghost.push(e.key)
            return True

    # -- public ---------------------------------------------------------------
    def access(self, key, dirty: bool = False) -> bool:
        self._run_flushers()
        e = self.in_small.get(key)
        if e is not None:
            age = self.small_seq - e.seq
            if age >= self.window:
                e.ref = True
            if dirty:
                self._mark_dirty(key)
            return True
        if self.main.hit(key):
            if dirty:
                self._mark_dirty(key)
            return True
        if key in self.ghost:
            self.ghost.remove(key)
            self._event("ghost_to_main", key)
            self.flows["ghost_to_main"] += 1
            self._insert_main(key)
            if dirty:
                self._mark_dirty(key)
            return False
        # brand-new block
        if len(self.small) >= self.small_cap:
            if not self._evict_small():
                # §5.5.1: all scanned Small-FIFO candidates dirty -> bypass
                self.flows["small_bypass"] += 1
                self._insert_main(key)
                if dirty:
                    self._mark_dirty(key)
                return False
        e = _SmallEntry(key, self.small_seq)
        self.small_seq += 1
        self.small.append(e)
        self.in_small[key] = e
        if dirty:
            self._mark_dirty(key)
        return False

    def __contains__(self, key):
        return key in self.in_small or key in self.main

    def __len__(self):
        return len(self.in_small) + len(self.main)


@register("clock2q+a")
def _adaptive(capacity: int, **kw):
    """Clock2Q+A — adaptive small-FIFO/window floors (beyond-paper)."""
    kw.setdefault("adaptive", True)
    pol = Clock2QPlus(capacity, **kw)
    pol.name = "clock2q+a"
    return pol
