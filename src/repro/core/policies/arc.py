"""ARC (Megiddo & Modha, FAST'03) — faithful to the published pseudocode."""

from __future__ import annotations

from collections import OrderedDict

from repro.core.policy import CachePolicy, register


@register("arc")
class ARC(CachePolicy):
    name = "arc"

    def __init__(self, capacity: int, **kw):
        super().__init__(capacity, **kw)
        self.p = 0.0
        self.t1 = OrderedDict()  # recency, MRU at end
        self.t2 = OrderedDict()  # frequency
        self.b1 = OrderedDict()  # ghost of t1
        self.b2 = OrderedDict()  # ghost of t2

    def _replace(self, in_b2: bool):
        if self.t1 and ((in_b2 and len(self.t1) == int(self.p)) or len(self.t1) > int(self.p)):
            k, _ = self.t1.popitem(last=False)
            self.b1[k] = None
        else:
            k, _ = self.t2.popitem(last=False)
            self.b2[k] = None

    def access(self, key, dirty: bool = False) -> bool:
        c = self.capacity
        if key in self.t1:
            del self.t1[key]
            self.t2[key] = None
            return True
        if key in self.t2:
            self.t2.move_to_end(key)
            return True
        if key in self.b1:
            self.p = min(float(c), self.p + max(len(self.b2) / max(1, len(self.b1)), 1.0))
            self._replace(False)
            del self.b1[key]
            self.t2[key] = None
            return False
        if key in self.b2:
            self.p = max(0.0, self.p - max(len(self.b1) / max(1, len(self.b2)), 1.0))
            self._replace(True)
            del self.b2[key]
            self.t2[key] = None
            return False
        # Case IV: brand-new
        l1 = len(self.t1) + len(self.b1)
        l2 = len(self.t2) + len(self.b2)
        if l1 == c:
            if len(self.t1) < c:
                self.b1.popitem(last=False)
                self._replace(False)
            else:
                self.t1.popitem(last=False)
        elif l1 < c and l1 + l2 >= c:
            if l1 + l2 == 2 * c:
                self.b2.popitem(last=False)
            self._replace(False)
        self.t1[key] = None
        return False

    def __contains__(self, key):
        return key in self.t1 or key in self.t2

    def __len__(self):
        return len(self.t1) + len(self.t2)
