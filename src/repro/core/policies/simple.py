"""Classic single-structure policies: FIFO, LRU, Clock, SLRU, LFU, SIEVE."""

from __future__ import annotations

import collections
import heapq
from collections import OrderedDict

from repro.core.policy import CachePolicy, register, seg_size


@register("fifo")
class FIFO(CachePolicy):
    name = "fifo"

    def __init__(self, capacity: int, **kw):
        super().__init__(capacity, **kw)
        self.q = collections.deque()
        self.resident = set()

    def access(self, key, dirty: bool = False) -> bool:
        if key in self.resident:
            return True
        if len(self.q) >= self.capacity:
            old = self.q.popleft()
            self.resident.discard(old)
        self.q.append(key)
        self.resident.add(key)
        return False

    def __contains__(self, key):
        return key in self.resident

    def __len__(self):
        return len(self.resident)


@register("lru")
class LRU(CachePolicy):
    name = "lru"

    def __init__(self, capacity: int, **kw):
        super().__init__(capacity, **kw)
        self.od = OrderedDict()  # key -> None; MRU at end

    def access(self, key, dirty: bool = False) -> bool:
        if key in self.od:
            self.od.move_to_end(key)
            return True
        if len(self.od) >= self.capacity:
            self.od.popitem(last=False)
        self.od[key] = None
        return False

    def __contains__(self, key):
        return key in self.od

    def __len__(self):
        return len(self.od)


@register("clock")
class Clock(CachePolicy):
    """Second-chance clock over a fixed array of slots."""

    name = "clock"

    def __init__(self, capacity: int, **kw):
        super().__init__(capacity, **kw)
        self.keys = [None] * capacity
        self.ref = [False] * capacity
        self.slot_of = {}
        self.hand = 0
        self.fill = 0

    def _evict_slot(self) -> int:
        while True:
            if self.ref[self.hand]:
                self.ref[self.hand] = False
                self.hand = (self.hand + 1) % self.capacity
                continue
            s = self.hand
            self.hand = (self.hand + 1) % self.capacity
            return s

    def access(self, key, dirty: bool = False) -> bool:
        s = self.slot_of.get(key)
        if s is not None:
            self.ref[s] = True
            return True
        if self.fill < self.capacity:
            s = self.fill
            self.fill += 1
        else:
            s = self._evict_slot()
            del self.slot_of[self.keys[s]]
        self.keys[s] = key
        self.ref[s] = False
        self.slot_of[key] = s
        return False

    def __contains__(self, key):
        return key in self.slot_of

    def __len__(self):
        return len(self.slot_of)


@register("slru")
class SLRU(CachePolicy):
    """Segmented LRU: probationary (20%) + protected (80%)."""

    name = "slru"

    def __init__(self, capacity: int, protected_frac: float = 0.8, **kw):
        super().__init__(capacity, **kw)
        self.prot_cap = min(capacity - 1, seg_size(capacity, protected_frac)) if capacity > 1 else 0
        self.prob_cap = capacity - self.prot_cap
        self.prob = OrderedDict()
        self.prot = OrderedDict()

    def _demote_overflow(self):
        while len(self.prot) > self.prot_cap:
            k, _ = self.prot.popitem(last=False)
            self._insert_prob(k)

    def _insert_prob(self, key):
        while len(self.prob) >= self.prob_cap:
            self.prob.popitem(last=False)
        self.prob[key] = None

    def access(self, key, dirty: bool = False) -> bool:
        if key in self.prot:
            self.prot.move_to_end(key)
            return True
        if key in self.prob:
            del self.prob[key]
            self.prot[key] = None
            self._demote_overflow()
            return True
        self._insert_prob(key)
        return False

    def __contains__(self, key):
        return key in self.prob or key in self.prot

    def __len__(self):
        return len(self.prob) + len(self.prot)


@register("lfu")
class LFU(CachePolicy):
    """In-cache LFU with FIFO tie-break (lazy-deletion heap)."""

    name = "lfu"

    def __init__(self, capacity: int, **kw):
        super().__init__(capacity, **kw)
        self.freq = {}
        self.heap = []  # (freq, seq, key) lazy entries
        self.seq = 0

    def access(self, key, dirty: bool = False) -> bool:
        if key in self.freq:
            self.freq[key] += 1
            self.seq += 1
            heapq.heappush(self.heap, (self.freq[key], self.seq, key))
            return True
        if len(self.freq) >= self.capacity:
            while True:
                f, _, k = heapq.heappop(self.heap)
                if k in self.freq and self.freq[k] == f:
                    del self.freq[k]
                    break
        self.freq[key] = 1
        self.seq += 1
        heapq.heappush(self.heap, (1, self.seq, key))
        return False

    def __contains__(self, key):
        return key in self.freq

    def __len__(self):
        return len(self.freq)


@register("sieve")
class SIEVE(CachePolicy):
    """SIEVE (NSDI'24): single queue, visited bits, hand moves tail->head."""

    name = "sieve"

    def __init__(self, capacity: int, **kw):
        super().__init__(capacity, **kw)
        # doubly linked list; head = newest, tail = oldest
        self.prev = {}
        self.next = {}
        self.visited = {}
        self.head = None
        self.tail = None
        self.hand = None

    def _unlink(self, key):
        p, n = self.prev[key], self.next[key]
        if p is not None:
            self.next[p] = n
        else:
            self.head = n
        if n is not None:
            self.prev[n] = p
        else:
            self.tail = p
        del self.prev[key], self.next[key], self.visited[key]

    def _push_head(self, key):
        self.prev[key] = None
        self.next[key] = self.head
        if self.head is not None:
            self.prev[self.head] = key
        self.head = key
        if self.tail is None:
            self.tail = key
        self.visited[key] = False

    def _evict(self):
        obj = self.hand if self.hand is not None else self.tail
        while obj is not None and self.visited[obj]:
            self.visited[obj] = False
            obj = self.prev[obj]
            if obj is None:
                obj = self.tail
        self.hand = self.prev[obj]
        self._unlink(obj)

    def access(self, key, dirty: bool = False) -> bool:
        if key in self.visited:
            self.visited[key] = True
            return True
        if len(self.visited) >= self.capacity:
            self._evict()
        self._push_head(key)
        return False

    def __contains__(self, key):
        return key in self.visited

    def __len__(self):
        return len(self.visited)
