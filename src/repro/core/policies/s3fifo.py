"""S3-FIFO (SOSP'23), as described in the Clock2Q+ paper §3.3.

Sizing: Small FIFO = 10%, Main = 90% of capacity, Ghost = 100% of capacity
(keys only).  ``bits=1``: promote on >=1 re-reference (freq cap 1).
``bits=2`` (the default "S3-FIFO 2-bit"): promote on >=2 re-references
(freq cap 3).  The Main queue is a FIFO with reinsertion (freq decrement),
equivalent to a coarse clock; ``skip_limit`` bounds reinsertions per
eviction (paper §5.5.2).
"""

from __future__ import annotations

import collections

from repro.core.policy import CachePolicy, register, seg_size
from repro.core.policies.two_q import _GhostFIFO


@register("s3fifo")
class S3FIFO(CachePolicy):
    name = "s3fifo"

    def __init__(self, capacity: int, small_frac: float = 0.1,
                 ghost_frac: float = 1.0, bits: int = 2, skip_limit=None, **kw):
        super().__init__(capacity, **kw)
        self.name = f"s3fifo-{bits}bit"
        small_cap = min(capacity, seg_size(capacity, small_frac))
        self.small_cap = small_cap
        self.main_cap = max(1, capacity - small_cap)
        self.freq_cap = 1 if bits == 1 else 3
        self.promote_at = 1 if bits == 1 else 2
        self.small = collections.deque()  # [key, freq]
        self.main = collections.deque()   # [key, freq]
        self.in_small = {}  # key -> entry
        self.in_main = {}
        self.ghost = _GhostFIFO(seg_size(capacity, ghost_frac))
        self.skip_limit = skip_limit
        self.skipped_per_eviction = []

    # -- internals ---------------------------------------------------------
    def _evict_main(self):
        skips = 0
        while True:
            e = self.main.popleft()
            key, freq = e
            if freq >= 1 and (self.skip_limit is None or skips < self.skip_limit):
                e[1] = freq - 1
                self.main.append(e)
                skips += 1
                continue
            del self.in_main[key]
            self._event("evict_main", key)
            self.skipped_per_eviction.append(skips)
            return

    def _insert_main(self, key):
        while len(self.main) >= self.main_cap:
            self._evict_main()
        e = [key, 0]
        self.main.append(e)
        self.in_main[key] = e

    def _evict_small(self):
        e = self.small.popleft()
        key, freq = e
        del self.in_small[key]
        if freq >= self.promote_at:
            self._event("small_to_main", key)
            self._insert_main(key)
        else:
            self._event("small_to_ghost", key)
            self.ghost.push(key)

    # -- public ------------------------------------------------------------
    def access(self, key, dirty: bool = False) -> bool:
        e = self.in_small.get(key)
        if e is not None:
            e[1] = min(self.freq_cap, e[1] + 1)
            return True
        e = self.in_main.get(key)
        if e is not None:
            e[1] = min(self.freq_cap, e[1] + 1)
            return True
        if key in self.ghost:
            self.ghost.remove(key)
            self._event("ghost_to_main", key)
            self._insert_main(key)
            return False
        while len(self.small) >= self.small_cap:
            self._evict_small()
        e = [key, 0]
        self.small.append(e)
        self.in_small[key] = e
        return False

    def __contains__(self, key):
        return key in self.in_small or key in self.in_main

    def __len__(self):
        return len(self.in_small) + len(self.in_main)
