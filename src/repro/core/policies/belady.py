"""Belady's MIN — offline optimal, used as a lower bound in tests/benches.

Requires the trace up-front (``Belady(capacity, trace=...)``); ``access``
must then be called in trace order.
"""

from __future__ import annotations

import heapq

from repro.core.policy import CachePolicy, register

_INF = 1 << 62


@register("belady")
class Belady(CachePolicy):
    name = "belady"

    def __init__(self, capacity: int, trace=None, **kw):
        super().__init__(capacity, **kw)
        if trace is None:
            raise ValueError("Belady requires trace=")
        self.trace = list(trace)
        # next_use[i] = index of next occurrence of trace[i] after i, or INF
        last = {}
        n = len(self.trace)
        self.next_use = [_INF] * n
        for i in range(n - 1, -1, -1):
            k = self.trace[i]
            self.next_use[i] = last.get(k, _INF)
            last[k] = i
        self.pos = 0
        self.resident = {}  # key -> next use index
        self.heap = []      # (-next_use, key) lazy

    def access(self, key, dirty: bool = False) -> bool:
        assert self.trace[self.pos] == key, "Belady must replay its own trace"
        nxt = self.next_use[self.pos]
        self.pos += 1
        if key in self.resident:
            self.resident[key] = nxt
            heapq.heappush(self.heap, (-nxt, key))
            return True
        if len(self.resident) >= self.capacity:
            while True:
                negnxt, k = heapq.heappop(self.heap)
                if k in self.resident and self.resident[k] == -negnxt:
                    del self.resident[k]
                    break
        self.resident[key] = nxt
        heapq.heappush(self.heap, (-nxt, key))
        return False

    def __contains__(self, key):
        return key in self.resident

    def __len__(self):
        return len(self.resident)
