"""Production-style Clock2Q+ (paper §4) — the array-based implementation.

Faithful to the vSAN engineering design, adapted from kernel C to a
host-side Python/NumPy runtime (this structure runs on the *host* CPU of a
TPU serving stack, where it allocates HBM KV blocks — see repro.kvcache):

  * No allocation after init: every queue is a contiguous array
    preallocated to its maximum (resizable) size (§4.1, §4.2.1 "reserved
    virtual address space").
  * Chained hash tables stored as arrays (bucket heads + per-entry next
    pointers), one for resident entries and one for the Ghost ring (§4.1).
  * "Always-full" queues with a single cursor for the Small FIFO / Ghost
    ring and a clock hand for the Main Clock (§4.1.1): eviction candidates
    are found by advancing the cursor; dirty/pinned entries are skipped in
    place (the paper's "equivalent to reinserting at the head"), with a
    bounded scan that falls back to the Main Clock (§4.1.3, §5.5.1).
  * Entries being filled are marked DOING-IO (§4.1.1); completion via
    ``io_done``.
  * Live resizing (§4.2): logical capacities move within the preallocated
    maxima; the hash table is rehashed *incrementally* (``resize_step``),
    lookups consult only the new bucket array, and the insertion path
    detects+migrates strays from the old one — the paper's protocol.

Semantics (hit/miss/eviction sequence) are identical to
``repro.core.policies.clock2qplus.Clock2QPlus`` with ``dirty_mode=
"simplified"`` when no pinning/resizing is used; a property test asserts
exact parity.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro import obs as obs_mod
from repro.obs import (
    EV_EVICT, EV_GHOST_PROMOTE, EV_IO_WAIT, EV_RESIZE, EV_RETUNE,
    EV_WINDOW_ENTER, EV_WINDOW_EXIT, FLOW_KINDS,
)

# shared sentinel (repro.core.engine.layout is pure Python — importing it
# keeps this module JAX-free); re-exported here for the many callers that
# do `from repro.core.prodcache import EMPTY`
from repro.core.engine.layout import EMPTY  # noqa: F401


def _next_pow2(n: int) -> int:
    return 1 << max(1, (n - 1).bit_length())


def drive_resize(policy, steps_per_call: int = 64) -> bool:
    """Drive a live resize (``ProdClock2QPlus`` or any policy exposing
    ``resize_step``/``rehash_pending``/``undrained_count``) until all
    *migratable* work is done.  Returns True when fully complete, False
    when only undrainable (pinned/DOING-IO) entries remain — it never
    spins on those: the unpin/io_done that would release them may be
    waiting on this very thread."""
    prev = None
    while not policy.resize_step(steps_per_call):
        if policy.rehash_pending():
            prev = None  # rehashing always progresses: never give up
            continue
        left = policy.undrained_count()
        if left == prev:  # full pass, zero drain progress
            return False
        prev = left
    return True


@dataclasses.dataclass
class AccessResult:
    hit: bool
    block: int                 # payload handle for the key (>=0) or EMPTY
    evicted_key: int = EMPTY   # resident key whose payload was dropped
    evicted_block: int = EMPTY
    bypassed_to_main: bool = False
    io_pending: bool = False   # True when the caller must fill the block


class ProdClock2QPlus:
    """Array-based Clock2Q+ with pinning, dirty blocks, and live resizing."""

    # the registered lane engine that simulates this policy bit-for-bit
    # (consumed by the OnlineTuner and the MRC profiler)
    engine_policy = "clock2q+"

    def __init__(self, capacity: int, *, small_frac: float = 0.1,
                 ghost_frac: float = 0.5, window_frac: float = 0.5,
                 skip_limit=None, dirty_scan_limit: int = 16,
                 max_capacity: int = 0, track_io: bool = False,
                 max_small_frac: float = 0.0, max_ghost_frac: float = 0.0,
                 min_small_frac: float = 1.0, obs=None, shard_id: int = 0):
        self.track_io = track_io  # mark entries DOING-IO until io_done()
        self.max_capacity = max(capacity, max_capacity or capacity)
        self._small_frac = small_frac
        self._ghost_frac = ghost_frac
        self._window_frac = window_frac
        self.skip_limit = skip_limit
        self.dirty_scan_limit = dirty_scan_limit

        # Preallocation fractions: the small/ghost maxima cover fractions
        # up to max_small_frac, and the MAIN maximum covers fractions
        # down to min_small_frac (a smaller small queue means a larger
        # main), so ``retune`` can move the boundary either way at
        # runtime without the logical sizes clamping below capacity.
        ms = max(1, int(round(self.max_capacity
                              * max(small_frac, max_small_frac))))
        mm = max(1, self.max_capacity - max(1, int(round(
            self.max_capacity * min(small_frac, min_small_frac)))))
        mg = max(1, int(round(self.max_capacity
                              * max(ghost_frac, max_ghost_frac))))
        self.max_small, self.max_main, self.max_ghost = ms, mm, mg
        n_ent = ms + mm

        # entry arrays (small ids: [0, ms), main ids: [ms, ms+mm))
        self.key = np.full(n_ent, EMPTY, dtype=np.int64)
        self.ref = np.zeros(n_ent, dtype=bool)
        self.dirty = np.zeros(n_ent, dtype=bool)
        self.pin = np.zeros(n_ent, dtype=np.int32)
        self.io = np.zeros(n_ent, dtype=bool)
        self.block = np.full(n_ent, EMPTY, dtype=np.int64)
        self.seq = np.zeros(n_ent, dtype=np.int64)  # small insertion seq

        # resident hash: new + old bucket arrays for the resize protocol
        # (sized for the LOGICAL capacity; resize swaps in a new array)
        sc0 = max(1, min(ms, int(round(capacity * small_frac))))
        self.n_buckets = _next_pow2(2 * (sc0 + max(1, capacity - sc0)))
        self.buckets = np.full(self.n_buckets, EMPTY, dtype=np.int64)
        self.nxt = np.full(n_ent, EMPTY, dtype=np.int64)
        self.old_buckets: np.ndarray | None = None
        self.old_n_buckets = 0
        self._rehash_cursor = 0

        # ghost ring + its hash
        self.gkey = np.full(mg, EMPTY, dtype=np.int64)
        self.g_n_buckets = _next_pow2(2 * mg)
        self.gbuckets = np.full(self.g_n_buckets, EMPTY, dtype=np.int64)
        self.gnxt = np.full(mg, EMPTY, dtype=np.int64)
        self.gpos = 0

        # payload free list (stack)
        self.free_blocks = list(range(n_ent - 1, -1, -1))

        # observability (repro.obs): on by default, per-cache sink.  The
        # instruments below ARE the stats — ``hits``/``misses``/
        # ``io_waits``/``flows`` are thin views over them, so there is
        # exactly one schema to export and nothing to reconcile.  Hot
        # paths increment bound instruments directly (plain attribute /
        # array-cell adds); events fire on state transitions only.
        self.shard_id = int(shard_id)
        lbl = str(self.shard_id)
        if obs is None:
            obs = obs_mod.ObsSink(src=f"cache/shard{lbl}",
                                  labels={"shard": lbl})
        self.obs = obs
        self._ring = obs.ring
        self._c_hit_small = obs.counter(
            "cache_hits_total", ("shard", "queue"),
            "resident hits by queue").labels(lbl, "small")
        self._c_hit_main = obs.counter(
            "cache_hits_total", ("shard", "queue")).labels(lbl, "main")
        self._c_miss = obs.counter(
            "cache_misses_total", ("shard",), "misses (incl. ghost "
            "hits, which readmit to main)").labels(lbl)
        self._c_io_wait = obs.counter(
            "cache_io_waits_total", ("shard",),
            "hits on DOING-IO entries").labels(lbl)
        flow_fam = obs.counter("cache_flow_total", ("shard", "flow"),
                               "Clock2Q+ queue-transition counters")
        self._c_flow = {k: flow_fam.labels(lbl, k) for k in FLOW_KINDS}
        self._c_f_s2m = self._c_flow["small_to_main"]
        self._c_f_s2g = self._c_flow["small_to_ghost"]
        self._c_f_g2m = self._c_flow["ghost_to_main"]
        self._c_f_evict = self._c_flow["evict_main"]
        self._c_f_bypass = self._c_flow["small_bypass"]
        cap_fam = obs.gauge("cache_capacity", ("shard", "segment"),
                            "logical segment sizes (slots)")
        self._g_cap = {seg: cap_fam.labels(lbl, seg)
                       for seg in ("total", "small", "main", "ghost",
                                   "window")}
        self._g_resident = obs.gauge(
            "cache_resident_entries", ("shard",),
            "resident entries (set at snapshot time)").labels(lbl)
        obs.on_collect(self._obs_collect)

        # write-ahead delta journal hook (repro.faults.journal attaches
        # one via ShardJournal.attach; None keeps every hot path at a
        # single attribute test, same bargain as ``if ring.enabled``)
        self._journal = None
        self._in_retune = False  # retune() journals ONE record; its
        # internal begin_resize call must not add a second

        # cursors / logical sizes
        self.spos = 0
        self.hand = 0
        self.small_seq = 0
        self.set_capacity(capacity)

    def _obs_collect(self) -> None:
        self._g_resident.set(float(len(self)))

    # -- stats (views over the obs counter families) --------------------------
    @property
    def hits(self) -> int:
        return self._c_hit_small.value + self._c_hit_main.value

    @property
    def misses(self) -> int:
        return self._c_miss.value

    @property
    def io_waits(self) -> int:
        return self._c_io_wait.value

    @property
    def flows(self) -> dict:
        """Queue-transition counters, derived from the
        ``cache_flow_total`` family in canonical ``obs.FLOW_KINDS``
        order (same keys as always — the sharded aggregate derives from
        the identical schema, so the key sets cannot drift)."""
        return {k: self._c_flow[k].value for k in FLOW_KINDS}

    # -- sizing ---------------------------------------------------------------
    def set_capacity(self, capacity: int) -> None:
        """Set the logical capacity (grow or shrink target). Shrinking may
        leave entries beyond the boundary; drain with ``resize_step``."""
        if not (1 <= capacity <= self.max_capacity):
            raise ValueError(f"capacity {capacity} not in [1, {self.max_capacity}]")
        self.capacity = capacity
        sc = max(1, min(self.max_small, int(round(capacity * self._small_frac))))
        self.small_cap = sc
        self.main_cap = max(1, min(self.max_main, capacity - sc))
        self.ghost_cap = max(1, min(self.max_ghost,
                                    int(round(capacity * self._ghost_frac))))
        self.window = int(round(self._window_frac * sc))
        self.spos %= self.small_cap
        self.hand %= self.main_cap
        if self.gpos >= self.ghost_cap:
            self.gpos = 0
        # purge ghost entries stranded beyond a shrunken ring: the cursor
        # never revisits those slots, so without this they would stay
        # hash-reachable forever (unbounded-age ghost hits)
        tail = self.gkey[self.ghost_cap:]
        if tail.size:
            for off in np.nonzero(tail != EMPTY)[0].tolist():
                self._ghost_remove_slot(self.ghost_cap + off)
        g = self._g_cap
        g["total"].value = float(capacity)
        g["small"].value = float(self.small_cap)
        g["main"].value = float(self.main_cap)
        g["ghost"].value = float(self.ghost_cap)
        g["window"].value = float(self.window)

    @property
    def tuning(self) -> dict:
        """Current tuning knobs (what ``retune`` retargets)."""
        return dict(small_frac=self._small_frac, ghost_frac=self._ghost_frac,
                    window_frac=self._window_frac)

    def retune(self, *, small_frac: float | None = None,
               ghost_frac: float | None = None,
               window_frac: float | None = None) -> None:
        """Runtime tuning setter (the OnlineTuner hook): retarget the
        correlation window and/or the small/ghost fractions of a LIVE
        cache.  The window change is immediate; segment boundaries move
        via the live-resize protocol — ``begin_resize`` at the current
        capacity recomputes them (``set_capacity`` clamps to the
        preallocated maxima, so payload handles never move) and entries
        stranded beyond a shrunken boundary drain through ``resize_step``
        exactly as a capacity resize would."""
        # validate everything BEFORE assigning anything: a rejected call
        # must not leave half-applied fractions for a later resize to
        # silently activate
        if small_frac is not None and not (0.0 < small_frac <= 1.0):
            raise ValueError(f"small_frac {small_frac} not in (0, 1]")
        if ghost_frac is not None and ghost_frac < 0.0:
            raise ValueError(f"ghost_frac {ghost_frac} < 0")
        if window_frac is not None and window_frac < 0.0:
            raise ValueError(f"window_frac {window_frac} < 0")
        if small_frac is not None:
            self._small_frac = small_frac
        if ghost_frac is not None:
            self._ghost_frac = ghost_frac
        if window_frac is not None:
            self._window_frac = window_frac
        old_window = self.window
        jr = self._journal
        if jr is not None:
            # journal the retune as ONE record of absolute post-values;
            # the embedded begin_resize is its deterministic consequence
            jr.on_retune(self._small_frac, self._ghost_frac,
                         self._window_frac)
        self._in_retune = True
        try:
            self.begin_resize(self.capacity)
        finally:
            self._in_retune = False
        if self._ring.enabled:
            self._ring.emit(EV_RETUNE, self.shard_id, a=old_window,
                            b=self.window)

    # -- hashing ---------------------------------------------------------------
    def _h(self, key: int, n_buckets: int) -> int:
        x = (key * 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
        return (x >> 17) % n_buckets

    def _hash_insert(self, eid: int) -> None:
        b = self._h(int(self.key[eid]), self.n_buckets)
        self.nxt[eid] = self.buckets[b]
        self.buckets[b] = eid

    def _hash_remove(self, eid: int) -> None:
        key = int(self.key[eid])
        for buckets, nb in ((self.buckets, self.n_buckets),
                            (self.old_buckets, self.old_n_buckets)):
            if buckets is None:
                continue
            b = self._h(key, nb)
            cur = int(buckets[b])
            prev = EMPTY
            while cur != EMPTY:
                if cur == eid:
                    if prev == EMPTY:
                        buckets[b] = self.nxt[cur]
                    else:
                        self.nxt[prev] = self.nxt[cur]
                    self.nxt[cur] = EMPTY
                    return
                prev, cur = cur, int(self.nxt[cur])

    def _hash_lookup(self, key: int) -> int:
        """Search the NEW bucket array only (paper §4.2.1)."""
        cur = int(self.buckets[self._h(key, self.n_buckets)])
        while cur != EMPTY:
            if int(self.key[cur]) == key:
                return cur
            cur = int(self.nxt[cur])
        return EMPTY

    def _find_stray(self, key: int) -> int:
        """Insertion-path check of the OLD bucket array; migrate on hit."""
        if self.old_buckets is None:
            return EMPTY
        b = self._h(key, self.old_n_buckets)
        cur = int(self.old_buckets[b])
        prev = EMPTY
        while cur != EMPTY:
            if int(self.key[cur]) == key:
                if prev == EMPTY:
                    self.old_buckets[b] = self.nxt[cur]
                else:
                    self.nxt[prev] = self.nxt[cur]
                self.nxt[cur] = EMPTY
                self._hash_insert(cur)
                return cur
            prev, cur = cur, int(self.nxt[cur])
        return EMPTY

    # -- ghost ring -------------------------------------------------------------
    def _ghash(self, key: int) -> int:
        return self._h(key, self.g_n_buckets)

    def _ghost_lookup(self, key: int) -> int:
        cur = int(self.gbuckets[self._ghash(key)])
        while cur != EMPTY:
            if int(self.gkey[cur]) == key:
                return cur
            cur = int(self.gnxt[cur])
        return EMPTY

    def _ghost_remove_slot(self, slot: int) -> None:
        key = int(self.gkey[slot])
        b = self._ghash(key)
        cur = int(self.gbuckets[b])
        prev = EMPTY
        while cur != EMPTY:
            if cur == slot:
                if prev == EMPTY:
                    self.gbuckets[b] = self.gnxt[cur]
                else:
                    self.gnxt[prev] = self.gnxt[cur]
                break
            prev, cur = cur, int(self.gnxt[cur])
        self.gkey[slot] = EMPTY
        self.gnxt[slot] = EMPTY

    def _ghost_push(self, key: int) -> None:
        slot = self.gpos
        if int(self.gkey[slot]) != EMPTY:
            self._ghost_remove_slot(slot)
        self.gkey[slot] = key
        b = self._ghash(key)
        self.gnxt[slot] = self.gbuckets[b]
        self.gbuckets[b] = slot
        self.gpos = (self.gpos + 1) % self.ghost_cap

    # -- eviction ----------------------------------------------------------------
    def _evict_main_slot(self) -> int:
        """Advance the clock hand to a victim main slot; frees it. Returns
        the local main slot index."""
        skips = 0
        scanned_dirty = 0
        forced = False
        while True:
            s = self.hand
            self.hand = (self.hand + 1) % self.main_cap
            eid = self.max_small + s
            if int(self.key[eid]) == EMPTY:
                return s  # pre-warm / invalid slot: free for the taking
            if self.pin[eid] or self.io[eid]:
                continue
            if self.dirty[eid]:
                scanned_dirty += 1
                if scanned_dirty > self.dirty_scan_limit:
                    self.dirty[eid] = False  # synchronous flush fallback
                continue
            if self.ref[eid] and not forced:
                self.ref[eid] = False
                skips += 1
                if self.skip_limit is not None and skips >= self.skip_limit:
                    forced = True
                continue
            # victim
            self._hash_remove(eid)
            self._c_f_evict.value += 1
            self._last_evicted = (int(self.key[eid]), int(self.block[eid]))
            if self._ring.enabled:
                self._ring.emit(EV_EVICT, self.shard_id,
                                a=self._last_evicted[0], b=1)
            self.free_blocks.append(int(self.block[eid]))
            self.key[eid] = EMPTY
            self.block[eid] = EMPTY
            self.ref[eid] = False
            return s

    def _insert_main(self, key: int, block: int | None, dirty: bool,
                     io: bool) -> int:
        """Insert into the Main Clock; ``block=None`` allocates a payload
        handle AFTER the eviction has freed one."""
        s = self._evict_main_slot()
        if block is None:
            block = self.free_blocks.pop()
        eid = self.max_small + s
        self.key[eid] = key
        self.block[eid] = block
        self.ref[eid] = False
        self.dirty[eid] = dirty
        self.io[eid] = io
        self.pin[eid] = 0
        self._hash_insert(eid)
        return eid

    def _evict_small_slot(self):
        """Advance the small cursor to a free slot, promoting/demoting the
        displaced entries.  Returns slot or -1 (all-dirty bypass, §5.5.1)."""
        scanned = 0
        while True:
            s = self.spos
            self.spos = (self.spos + 1) % self.small_cap
            if int(self.key[s]) == EMPTY:
                return s
            if self.pin[s] or self.io[s] or self.dirty[s]:
                scanned += 1  # skipped in place == reinsert at head (§4.1.3)
                if scanned >= min(self.dirty_scan_limit, self.small_cap):
                    return -1
                continue
            key, block = int(self.key[s]), int(self.block[s])
            self._hash_remove(s)
            self.key[s] = EMPTY
            if self.ref[s]:
                self._c_f_s2m.value += 1
                self._insert_main(key, block, dirty=False, io=False)
            else:
                self._c_f_s2g.value += 1
                if self._ring.enabled:
                    self._ring.emit(EV_EVICT, self.shard_id, a=key, b=0)
                self._ghost_push(key)
                self.free_blocks.append(block)
                self._last_evicted = (key, block)
            self.ref[s] = False
            return s

    # -- public ------------------------------------------------------------------
    def access(self, key: int, dirty: bool = False, pin: bool = False) -> AccessResult:
        """Look up ``key``; on miss, admit it (Clock2Q+ placement) and return
        a payload handle the caller must fill (``io_pending=True``)."""
        self._last_evicted = (EMPTY, EMPTY)
        eid = self._hash_lookup(key)
        if eid == EMPTY:
            eid = self._find_stray(key)  # resize protocol: check old location
        if eid != EMPTY:
            if eid < self.max_small:  # small FIFO hit: correlation window
                self._c_hit_small.value += 1
                age = self.small_seq - int(self.seq[eid])
                if age >= self.window and not self.ref[eid]:
                    # the entry leaves its correlation window: this first
                    # qualifying re-reference is a state transition (the
                    # ref bit flips), so it may emit — later hits don't
                    if self._ring.enabled:
                        self._ring.emit(EV_WINDOW_EXIT, self.shard_id,
                                        a=key, b=age)
                    self.ref[eid] = True
            else:
                self._c_hit_main.value += 1
                self.ref[eid] = True
            if dirty:
                self.dirty[eid] = True
            if pin:
                self.pin[eid] += 1
            if self.io[eid]:
                self._c_io_wait.value += 1
                if self._ring.enabled:
                    self._ring.emit(EV_IO_WAIT, self.shard_id, a=key)
            res = AccessResult(True, int(self.block[eid]),
                               io_pending=bool(self.io[eid]))
            jr = self._journal
            if jr is not None:
                jr.on_access(key, dirty, pin, res)
            return res

        self._c_miss.value += 1
        gslot = self._ghost_lookup(key)
        bypass = False
        if gslot != EMPTY:
            self._ghost_remove_slot(gslot)
            self._c_f_g2m.value += 1
            if self._ring.enabled:
                self._ring.emit(EV_GHOST_PROMOTE, self.shard_id, a=key)
            eid = self._insert_main(key, None, dirty=dirty, io=self.track_io)
            block = int(self.block[eid])
        else:
            s = self._evict_small_slot()
            if s < 0:
                self._c_f_bypass.value += 1
                bypass = True
                eid = self._insert_main(key, None, dirty=dirty, io=self.track_io)
                block = int(self.block[eid])
            else:
                block = self.free_blocks.pop()
                eid = s
                self.key[s] = key
                self.block[s] = block
                self.ref[s] = False
                self.dirty[s] = dirty
                self.io[s] = self.track_io
                self.pin[s] = 0
                self.seq[s] = self.small_seq
                self.small_seq += 1
                self._hash_insert(s)
                if self._ring.enabled:  # correlation window opens
                    self._ring.emit(EV_WINDOW_ENTER, self.shard_id, a=key)
        if pin:
            self.pin[eid] += 1
        ek, eb = self._last_evicted
        res = AccessResult(False, block, evicted_key=ek, evicted_block=eb,
                           bypassed_to_main=bypass, io_pending=True)
        jr = self._journal
        if jr is not None:
            jr.on_access(key, dirty, pin, res)
        return res

    def io_done(self, key: int) -> None:
        eid = self._hash_lookup(key)
        if eid == EMPTY:
            eid = self._find_stray(key)
        if eid != EMPTY:
            self.io[eid] = False
        jr = self._journal
        if jr is not None:
            jr.on_io_done(key)

    def unpin(self, key: int) -> None:
        eid = self._hash_lookup(key)
        if eid == EMPTY:
            eid = self._find_stray(key)
        if eid != EMPTY and self.pin[eid] > 0:
            self.pin[eid] -= 1
        jr = self._journal
        if jr is not None:
            jr.on_unpin(key)

    def clean(self, key: int) -> None:
        """Mark a dirty block flushed (host copy completed)."""
        eid = self._hash_lookup(key)
        if eid == EMPTY:
            eid = self._find_stray(key)
        if eid != EMPTY:
            self.dirty[eid] = False
        jr = self._journal
        if jr is not None:
            jr.on_clean(key)

    def set_dirty(self, key: int) -> None:
        """Mark resident block dirty without touching replacement state."""
        eid = self._hash_lookup(key)
        if eid == EMPTY:
            eid = self._find_stray(key)
        if eid != EMPTY:
            self.dirty[eid] = True
        jr = self._journal
        if jr is not None:
            jr.on_set_dirty(key)

    def contains(self, key: int) -> bool:
        return self._hash_lookup(key) != EMPTY or self._find_stray(key) != EMPTY

    def slot_of(self, key: int) -> int:
        """Payload slot of a resident key (no replacement-state update), or
        EMPTY if absent."""
        eid = self._hash_lookup(key)
        if eid == EMPTY:
            eid = self._find_stray(key)
        return EMPTY if eid == EMPTY else int(self.block[eid])

    def replay(self, source, chunk_size: int = 1 << 20) -> int:
        """Replay a request stream (ndarray, ``repro.traceio.TraceStore``,
        or any iterable of key chunks) through ``access``; returns the hit
        count (``hits``/``misses`` counters advance as usual).  The cache
        is stateful, so chunked streaming is state-carry by construction:
        any chunk_size is bit-identical to replaying the whole trace in
        one call, with peak memory bounded by the chunk."""
        from repro.traceio.store import iter_chunks

        acc = self.access
        hits = 0
        for chunk in iter_chunks(source, chunk_size):
            for k in np.asarray(chunk).tolist():
                hits += acc(k).hit
        return hits

    @property
    def n_slots(self) -> int:
        """Size of the payload-handle space (preallocated entry count)."""
        return int(self.key.shape[0])

    def __len__(self) -> int:
        return int(np.sum(self.key != EMPTY))

    def dirty_keys(self):
        mask = (self.key != EMPTY) & self.dirty
        return [int(k) for k in self.key[mask]]

    def resident_keys(self):
        """Resident keys, coldest first: Main Clock entries in hand order
        (the slot under the hand is the next eviction candidate), then
        Small FIFO entries by insertion sequence.  This is the admission
        order a failover rewarm replays so the rebuilt shard evicts in
        the same relative order the lost one would have
        (``repro.faults.recovery``)."""
        out = []
        ms = self.max_small
        for i in range(self.main_cap):
            eid = ms + (self.hand + i) % self.main_cap
            if int(self.key[eid]) != EMPTY:
                out.append(int(self.key[eid]))
        # out-of-bounds main entries (mid-resize strays), slot order
        for eid in range(ms + self.main_cap, ms + self.max_main):
            if int(self.key[eid]) != EMPTY:
                out.append(int(self.key[eid]))
        smalls = [(int(self.seq[s]), int(self.key[s]))
                  for s in range(ms) if int(self.key[s]) != EMPTY]
        out.extend(k for _, k in sorted(smalls))
        return out

    def ghost_keys(self):
        """Ghost-ring keys, oldest first (``gpos`` is the next overwrite
        slot, i.e. the oldest surviving ghost)."""
        out = []
        for i in range(self.ghost_cap):
            slot = (self.gpos + i) % self.ghost_cap
            if int(self.gkey[slot]) != EMPTY:
                out.append(int(self.gkey[slot]))
        return out

    # -- live resizing (§4.2) -----------------------------------------------------
    def rehash_pending(self) -> bool:
        """True while the incremental hash migration has work left (it can
        always progress — never blocked by pins/dirty/DOING-IO)."""
        return self.old_buckets is not None

    def undrained_count(self) -> int:
        """Resident entries beyond the logical boundaries (only pinned or
        DOING-IO ones can persist across resize_step calls)."""
        n = int((self.key[self.small_cap:self.max_small] != EMPTY).sum())
        n += int((self.key[self.max_small + self.main_cap:] != EMPTY).sum())
        return n

    def finish_rehash(self, n_entries: int = 256) -> None:
        """Drive the incremental hash migration (ONLY — never the
        out-of-bounds drain, whose boundaries may be about to change) to
        completion.  Unlike the drain, rehashing is pure pointer work and
        can never be blocked by pinned/dirty/DOING-IO entries, so this
        always terminates.  Required before a new ``begin_resize`` may
        retire the old bucket array."""
        while not self._rehash_step(n_entries):
            pass

    def begin_resize(self, new_capacity: int) -> None:
        """Start a live resize: swap in a right-sized bucket array and let
        ``resize_step`` migrate entries in the background.  If a previous
        resize's hash migration is still pending it is completed first
        (two old bucket arrays cannot coexist)."""
        jr = self._journal
        if jr is not None and not self._in_retune:
            jr.on_resize(new_capacity)
        self.finish_rehash()
        if self._ring.enabled:
            self._ring.emit(EV_RESIZE, self.shard_id, a=self.capacity,
                            b=new_capacity)
        self.set_capacity(new_capacity)
        n_new = _next_pow2(2 * (self.small_cap + self.main_cap))
        if n_new != self.n_buckets:
            self.old_buckets = self.buckets
            self.old_n_buckets = self.n_buckets
            self.buckets = np.full(n_new, EMPTY, dtype=np.int64)
            self.n_buckets = n_new
            self._rehash_cursor = 0

    def _rehash_step(self, n_entries: int) -> bool:
        """Migrate up to ``n_entries`` from the old hash location; True
        when the old bucket array is fully retired."""
        if self.old_buckets is None:
            return True
        moved = 0
        while self._rehash_cursor < self.old_n_buckets and moved < n_entries:
            b = self._rehash_cursor
            cur = int(self.old_buckets[b])
            while cur != EMPTY and moved < n_entries:
                nxt = int(self.nxt[cur])
                self.old_buckets[b] = nxt
                self._hash_insert(cur)
                cur = nxt
                moved += 1
            if cur == EMPTY:
                self._rehash_cursor += 1
        if self._rehash_cursor >= self.old_n_buckets:
            self.old_buckets = None
            self.old_n_buckets = 0
            return True
        return False

    def resize_step(self, n_entries: int = 64) -> bool:
        """Background-thread analogue: migrate up to ``n_entries`` from the
        old hash location and drain out-of-bounds slots.  Returns True when
        the resize is complete."""
        jr = self._journal
        if jr is not None:
            jr.on_resize_step(n_entries)
        done_hash = self._rehash_step(n_entries)
        done_drain = self._drain_out_of_bounds(n_entries)
        return done_hash and done_drain

    def _drain_out_of_bounds(self, budget: int) -> bool:
        """Evict entries living beyond the shrunken logical capacities.
        Dirty blocks are flushed (cleaned) first, as §4.2.2 prescribes."""
        done = True
        for eid in range(self.small_cap, self.max_small):
            if budget <= 0:
                return False
            if int(self.key[eid]) != EMPTY:
                if self.pin[eid] or self.io[eid]:
                    done = False
                    continue
                if self.dirty[eid]:
                    self.dirty[eid] = False  # trigger transaction flush
                key, block = int(self.key[eid]), int(self.block[eid])
                self._hash_remove(eid)
                self._ghost_push(key)
                self.free_blocks.append(block)
                self.key[eid] = EMPTY
                budget -= 1
        for s in range(self.main_cap, self.max_main):
            eid = self.max_small + s
            if budget <= 0:
                return False
            if int(self.key[eid]) != EMPTY:
                if self.pin[eid] or self.io[eid]:
                    done = False
                    continue
                if self.dirty[eid]:
                    self.dirty[eid] = False
                self._hash_remove(eid)
                self.free_blocks.append(int(self.block[eid]))
                self.key[eid] = EMPTY
                budget -= 1
        return done
