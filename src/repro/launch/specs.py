"""Input builders for every (arch x shape) cell.

``make_batch`` returns concrete host arrays (smoke tests / real runs);
``input_specs`` returns jax.ShapeDtypeStruct stand-ins (dry-run lowering,
no allocation).  Modality frontends are stubs: VLM cells get precomputed
patch embeddings, audio cells precomputed frame embeddings (per the
assignment note).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import encdec
from repro.models.config import ModelConfig, ShapeCell


def _token_shapes(cfg: ModelConfig, cell: ShapeCell) -> Dict[str, tuple]:
    B, S = cell.global_batch, cell.seq_len
    emb_dt = jnp.dtype(cfg.dtype)
    if cfg.family == "vlm":
        P = min(cfg.n_patches, S // 2)
        shapes = {"tokens": ((B, S - P), jnp.int32),
                  "patch_embeds": ((B, P, cfg.d_model), emb_dt)}
    elif cfg.family == "encdec":
        Se = encdec.enc_len_for(cfg, S)
        shapes = {"tokens": ((B, S), jnp.int32),
                  "audio_embeds": ((B, Se, cfg.d_model), emb_dt)}
    else:
        shapes = {"tokens": ((B, S), jnp.int32)}
    return shapes


def batch_shapes(cfg: ModelConfig, cell: ShapeCell) -> Dict[str, tuple]:
    """{name: (shape, dtype)} for the step input batch."""
    B, S = cell.global_batch, cell.seq_len
    if cell.kind == "train":
        shapes = _token_shapes(cfg, cell)
        shapes["labels"] = ((B, S), jnp.int32)
        return shapes
    if cell.kind == "prefill":
        return _token_shapes(cfg, cell)
    # decode: one new token against a seq_len-sized cache (cache specs come
    # from api.init_cache and are handled by the dry-run driver).
    return {"tokens": ((B, 1), jnp.int32)}


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> Dict[str, jax.ShapeDtypeStruct]:
    return {k: jax.ShapeDtypeStruct(shape, dt)
            for k, (shape, dt) in batch_shapes(cfg, cell).items()}


def make_batch(cfg: ModelConfig, cell: ShapeCell, seed: int = 0) -> Dict:
    """Concrete random batch (for smoke tests; use the data pipeline for
    real training)."""
    rng = np.random.default_rng(seed)
    out = {}
    for k, (shape, dt) in batch_shapes(cfg, cell).items():
        if dt == jnp.int32:
            hi = cfg.vocab if k in ("tokens", "labels") else 2
            out[k] = jnp.asarray(rng.integers(0, hi, size=shape), jnp.int32)
        else:
            out[k] = jnp.asarray(rng.normal(0, 1, size=shape), dt)
    if cell.kind == "train" and cfg.family == "vlm":
        # patch positions carry no next-token target
        P = out["patch_embeds"].shape[1]
        lab = np.array(out["labels"])  # writable copy
        lab[:, :P] = -1
        out["labels"] = jnp.asarray(lab)
    return out
