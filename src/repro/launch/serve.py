"""Serving launcher: batched requests through the Clock2Q+-paged engine.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b \
        --requests 8 --max-new 8 [--hbm-blocks 28] [--shrink-to 14]
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models.model import build
from repro.serving.engine import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--prefix-len", type=int, default=32)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--hbm-blocks", type=int, default=28)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--shrink-to", type=int, default=0,
                    help="live-resize the pool mid-run (paper §4.2)")
    ap.add_argument("--full-config", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = reduced(cfg)
    if cfg.family not in ("dense", "vlm", "moe"):
        raise SystemExit(f"{cfg.family} archs have no paged-KV serving path")
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prefix = list(rng.integers(0, cfg.vocab, args.prefix_len))
    reqs = [Request(i, prefix + list(rng.integers(0, cfg.vocab,
                                                  int(rng.integers(4, 12)))),
                    max_new=args.max_new) for i in range(args.requests)]
    eng = ServingEngine(api, params, block_size=args.block_size,
                        hbm_blocks=args.hbm_blocks,
                        max_batch=args.max_batch)
    half = len(reqs) // 2 if args.shrink_to else len(reqs)
    t0 = time.time()
    done = eng.run(reqs[:half])
    if args.shrink_to:
        print(f"live-shrinking pool {args.hbm_blocks} -> {args.shrink_to}")
        eng.pool.resize(args.shrink_to)
        done += eng.run(reqs[half:])
    dt = time.time() - t0
    stats, flows = eng.stats
    n_tok = sum(len(c.tokens) for c in done)
    print(f"{len(done)} completions, {n_tok} tokens in {dt:.1f}s "
          f"({n_tok/dt:.1f} tok/s)")
    print(f"pool: hit_ratio={stats.hit_ratio:.2f} swap_out={stats.swap_out} "
          f"swap_in={stats.swap_in}  flows={flows}")


if __name__ == "__main__":
    main()
