"""Roofline report generator (deliverable g).

Reads the dry-run records (experiments/dryrun/<mesh>/<arch>__<shape>.json),
computes MODEL_FLOPS (6·N·D dense / 6·N_active·D MoE for train; 2·N_active
per generated/prefilled token for serving), the three roofline terms, the
useful-compute ratio, and the dominant bottleneck per cell; writes
experiments/roofline.md.

    PYTHONPATH=src python -m repro.launch.roofline
"""

from __future__ import annotations

import json
from pathlib import Path

from repro.configs import get_config
from repro.launch.hlo_analysis import PEAK_FLOPS
from repro.models.config import shape_by_name

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments"


def model_flops(arch: str, shape: str) -> float:
    cfg = get_config(arch)
    cell = shape_by_name(shape)
    n_act = cfg.n_active_params()
    if cell.kind == "train":
        return 6.0 * n_act * cell.global_batch * cell.seq_len
    if cell.kind == "prefill":
        return 2.0 * n_act * cell.global_batch * cell.seq_len
    return 2.0 * n_act * cell.global_batch  # decode: one token per seq


def mitigation(rec: dict) -> str:
    arch, shape = rec["arch"], rec["shape"]
    dom = rec["roofline"]["dominant"]
    cats = rec.get("byte_categories", {})
    top = max(cats, key=cats.get) if cats else ""
    if dom == "memory":
        if "convert" in top or "dynamic-update-slice" in top:
            if rec["kind"] == "decode":
                return ("paged/one-hot cache writes avoid the full-shard "
                        "select+convert the sharded DUS lowers to")
            return ("blocked (flash) attention / fused mixed-precision "
                    "removes materialized f32 score tensors")
        if "transpose" in top:
            return "store KV pre-transposed in the attention's layout"
        if "dot" in top:
            return "already dot-dominated: raise arithmetic intensity (batch)"
        return "fuse the dominant fusion chain (see byte_categories)"
    if dom == "collective":
        return "overlap collectives with compute; reshard to cut volume"
    return "compute-bound: good; tune block shapes for MXU utilization"


def load_records(variant: str = "dryrun"):
    recs = {}
    for mesh_dir in sorted((OUT_DIR / variant).glob("*x*")):
        for f in sorted(mesh_dir.glob("*.json")):
            rec = json.loads(f.read_text())
            arch, shape = f.stem.split("__")
            rec.setdefault("arch", arch)
            rec.setdefault("shape", shape)
            recs[(mesh_dir.name, arch, shape)] = rec
    return recs


def build_report() -> str:
    recs = load_records()
    lines = ["# Roofline analysis (per device; v5e: 197 TF/s bf16, "
             "819 GB/s HBM, 4x50 GB/s ICI)", ""]
    for mesh in sorted({m for m, _, _ in recs}):
        lines.append(f"\n## Mesh {mesh}\n")
        lines.append("| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) "
                     "| dominant | MODEL_FLOPS/dev | useful/HLO | roofline "
                     "frac | top byte category | next move |")
        lines.append("|---|---|---|---|---|---|---|---|---|---|---|")
        for (m, arch, shape), rec in sorted(recs.items()):
            if m != mesh:
                continue
            if rec.get("status") == "SKIP":
                lines.append(f"| {arch} | {shape} | — | — | — | SKIP | — | "
                             f"— | — | — | {rec['reason'][:60]} |")
                continue
            if rec.get("status") != "OK":
                lines.append(f"| {arch} | {shape} | — | — | — | FAIL | — | "
                             f"— | — | — | {rec.get('error', '')[:60]} |")
                continue
            r = rec["roofline"]
            mf = model_flops(arch, shape) / rec["n_chips"]
            ratio = mf / max(rec["cost_flops"], 1.0)
            t_useful = mf / PEAK_FLOPS
            frac = t_useful / max(r["bound_s"], 1e-12)
            cats = rec.get("byte_categories", {})
            top = max(cats, key=cats.get) if cats else "-"
            topv = cats.get(top, 0.0)
            lines.append(
                f"| {arch} | {shape} | {r['t_compute_s']:.4f} | "
                f"{r['t_memory_s']:.4f} | {r['t_collective_s']:.5f} | "
                f"{r['dominant']} | {mf:.3e} | {ratio:.2f} | "
                f"{frac*100:.1f}% | {top} ({topv/1e9:.0f} GB) | "
                f"{mitigation(rec)} |")
    # hillclimb candidates
    singles = {k: v for k, v in recs.items()
               if k[0] == "16x16" and v.get("status") == "OK"}

    def frac_of(k):
        rec = singles[k]
        mf = model_flops(k[1], k[2]) / rec["n_chips"]
        return (mf / PEAK_FLOPS) / max(rec["roofline"]["bound_s"], 1e-12)

    worst = min(singles, key=frac_of)
    coll = max(singles,
               key=lambda k: singles[k]["roofline"]["t_collective_s"]
               / max(singles[k]["roofline"]["bound_s"], 1e-12))
    lines.append("\n## Hillclimb candidates (single-pod)\n")
    lines.append(f"* worst roofline fraction: {worst[1]} x {worst[2]} "
                 f"({frac_of(worst)*100:.2f}%)")
    lines.append(f"* most collective-bound: {coll[1]} x {coll[2]}")
    lines.append("* most paper-representative: granite-3-8b x decode_32k "
                 "(Clock2Q+-paged KV decode)")
    # optimized-variant comparison (EXPERIMENTS.md §Perf)
    opt = load_records("dryrun_opt")
    if opt:
        lines.append("\n## Optimized variant (--variant opt) vs baseline\n")
        lines.append("| mesh | arch | shape | bound base (s) | bound opt "
                     "(s) | speedup |")
        lines.append("|---|---|---|---|---|---|")
        for key, rec in sorted(opt.items()):
            if rec.get("status") != "OK" or key not in recs:
                continue
            b = recs[key]
            if b.get("status") != "OK":
                continue
            b0 = b["roofline"]["bound_s"]
            b1 = rec["roofline"]["bound_s"]
            lines.append(f"| {key[0]} | {key[1]} | {key[2]} | {b0:.4f} | "
                         f"{b1:.4f} | {b0 / max(b1, 1e-12):.2f}x |")
    return "\n".join(lines) + "\n"


def main():
    report = build_report()
    out = OUT_DIR / "roofline.md"
    out.write_text(report)
    print(report[:4000])
    print(f"... written to {out}")


if __name__ == "__main__":
    main()
