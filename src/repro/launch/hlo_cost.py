"""Loop-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE, which
under-reports FLOPs/bytes by ~n_layers x microbatches for scanned models
(verified empirically — see EXPERIMENTS.md §Dry-run).  This module parses
the optimized HLO text and walks the call graph multiplying every
computation's cost by the enclosing loops' ``known_trip_count``:

  * FLOPs: dot ops (2 * prod(output) * prod(lhs contracting dims)).
  * HBM bytes: per materializing op (fusion/dot/copy/slice/...) — operand
    bytes + output bytes, where a fusion parameter consumed only through
    dynamic-slice ops is charged at slice size, not full size.
  * Collectives: count + result bytes + ring wire bytes, per kind.

This is a structural model (roofline input), not a cycle-accurate one.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "f8e5m2fnuz": 1, "f8e4m3b11fnuz": 1, "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")
_WIRE_MULT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
              "all-to-all": 1.0, "collective-permute": 1.0,
              "ragged-all-to-all": 1.0}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"          # result name
    r"((?:\([^()]*\))|(?:\w+\[[\d,]*\](?:{[^}]*})?))\s+"  # shape (or tuple)
    r"([\w\-]+?)"                                  # op name
    r"\((.*)$")                                    # operands + attrs
_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count\D+(\d+)')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TOAPPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_BRANCHES_RE = re.compile(r"(?:branch_computations|true_computation|"
                          r"false_computation)=\{?%?([\w.\-,%\s]+)\}?")


def _shape_dims(shape_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(shape_str):
        dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
        out.append((m.group(1), dims))
    return out


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 0)
    return total


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    rest: str

    @property
    def operands(self) -> List[str]:
        # operand list = %names before the closing paren of the op call;
        # attributes follow after "), " — cut at the first ")," at depth 0
        depth = 0
        end = len(self.rest)
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    end = i
                    break
                depth -= 1
        return _OPERAND_RE.findall(self.rest[:end])


@dataclasses.dataclass
class CostTotals:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, Dict[str, float]] = dataclasses.field(
        default_factory=lambda: {k: {"count": 0.0, "bytes": 0.0,
                                     "wire_bytes": 0.0} for k in COLLECTIVES})
    by_cat: Dict[str, float] = dataclasses.field(default_factory=dict)

    def cat(self, name: str, b: float):
        self.by_cat[name] = self.by_cat.get(name, 0.0) + b

    def add(self, other: "CostTotals", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k in COLLECTIVES:
            for f in ("count", "bytes", "wire_bytes"):
                self.coll[k][f] += other.coll[k][f] * mult
        for k, v in other.by_cat.items():
            self.by_cat[k] = self.by_cat.get(k, 0.0) + v * mult


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps: Dict[str, Dict[str, Instr]] = {}
        self.order: Dict[str, List[Instr]] = {}
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self._memo: Dict[str, CostTotals] = {}

    # -- parsing -------------------------------------------------------------
    def _parse(self, text: str):
        cur = None
        for raw in text.splitlines():
            line = raw.strip()
            if cur is None or (line.endswith("{") and "=" not in line.split("{")[0]):
                h = _HDR_RE.match(line)
                if h and line.rstrip().endswith("{"):
                    cur = h.group(1)
                    self.comps[cur] = {}
                    self.order[cur] = []
                    if line.startswith("ENTRY"):
                        self.entry = cur
                    continue
            if cur is None:
                continue
            if line == "}":
                cur = None
                continue
            m = _INSTR_RE.match(line)
            if not m:
                continue
            ins = Instr(m.group(1), m.group(2), m.group(3), m.group(4))
            self.comps[cur][ins.name] = ins
            self.order[cur].append(ins)

    def _operand_shape(self, comp: str, name: str) -> Optional[str]:
        ins = self.comps[comp].get(name)
        return ins.shape if ins is not None else None

    # -- per-op costs -----------------------------------------------------------
    def _dot_flops(self, comp: str, ins: Instr) -> float:
        out_elems = 1
        for _, dims in _shape_dims(ins.shape):
            for d in dims:
                out_elems *= d
        m = _LHS_CONTRACT_RE.search(ins.rest)
        k = 1
        if m and m.group(1):
            ops = ins.operands
            lhs_shape = self._operand_shape(comp, ops[0]) if ops else None
            if lhs_shape:
                dims = _shape_dims(lhs_shape)[0][1]
                for idx in m.group(1).split(","):
                    i = int(idx)
                    if i < len(dims):
                        k *= dims[i]
        return 2.0 * out_elems * k

    def _fusion_bytes(self, comp: str, ins: Instr) -> float:
        """Operand + output bytes; params consumed only via dynamic-slice
        are charged at total sliced size instead of full size."""
        called = _CALLS_RE.search(ins.rest)
        total = _shape_bytes(ins.shape)  # output write
        inner = self.comps.get(called.group(1)) if called else None
        operands = ins.operands
        if inner is None:
            for o in operands:
                s = self._operand_shape(comp, o)
                if s:
                    total += _shape_bytes(s)
            return total
        # map param index -> inner param name
        params = {}
        for iname, iins in inner.items():
            if iins.op == "parameter":
                pm = re.match(r"(\d+)", iins.rest)
                if pm:
                    params[int(pm.group(1))] = iname
        cname = called.group(1)
        inner_order = self.order.get(cname) or []
        inner = self.comps[cname]
        dus_update_bytes = 0
        dus_target_params = set()
        for u in inner_order:
            if u.op == "dynamic-update-slice":
                ops_u = u.operands
                if ops_u and ops_u[0] in set(params.values()):
                    dus_target_params.add(ops_u[0])
                if len(ops_u) > 1:
                    s = inner.get(ops_u[1])
                    dus_update_bytes += _shape_bytes(s.shape) if s else 0
        # per-use accounting: direct uses of a fusion parameter are charged
        # at what they actually touch (slice reads, in-place update writes);
        # any full-reading use charges the whole buffer once.
        for pi, o in enumerate(operands):
            s = self._operand_shape(comp, o)
            if s is None:
                continue
            pname = params.get(pi)
            uses = [u for u in inner_order
                    if pname in u.operands] if pname else []
            if not uses:
                total += _shape_bytes(s)
                continue
            b = 0
            full = False
            for u in uses:
                if u.op in ("dynamic-slice", "gather"):
                    b += _shape_bytes(u.shape)
                elif (u.op == "dynamic-update-slice"
                      and u.operands and u.operands[0] == pname):
                    us = inner.get(u.operands[1]) if len(u.operands) > 1 \
                        else None
                    b += 2 * (_shape_bytes(us.shape) if us else 0)
                else:
                    full = True
            total += max(b, _shape_bytes(s)) if full else b
        if dus_target_params:
            # output aliases the updated buffer: replace the full-output
            # charge with the update-region write
            total -= _shape_bytes(ins.shape)
            total += 2 * dus_update_bytes
        return total

    # -- computation walk --------------------------------------------------------
    def comp_cost(self, comp: str) -> CostTotals:
        if comp in self._memo:
            return self._memo[comp]
        tot = CostTotals()
        self._memo[comp] = tot  # guard cycles
        for ins in self.order.get(comp, []):
            op = ins.op
            if op == "dot":
                tot.flops += self._dot_flops(comp, ins)
                b = _shape_bytes(ins.shape)
                for o in ins.operands:
                    s = self._operand_shape(comp, o)
                    if s:
                        b += _shape_bytes(s)
                tot.bytes += b
                tot.cat("dot", b)
            elif op == "fusion":
                called = _CALLS_RE.search(ins.rest)
                if called and called.group(1) in self.comps:
                    tot.add(self._flops_only(self.comp_cost(called.group(1))))
                b = self._fusion_bytes(comp, ins)
                tot.bytes += b
                # category = fusion-name prefix (e.g. "convert", "transpose")
                cat = re.split(r"[._]", ins.name)[0] or "fusion"
                tot.cat("fusion:" + cat, b)
            elif op == "while":
                trip = 1
                tm = _TRIP_RE.search(ins.rest)
                if tm:
                    trip = int(tm.group(1))
                body = _BODY_RE.search(ins.rest)
                cond = _COND_RE.search(ins.rest)
                sub = CostTotals()
                if body and body.group(1) in self.comps:
                    sub.add(self.comp_cost(body.group(1)))
                if cond and cond.group(1) in self.comps:
                    sub.add(self.comp_cost(cond.group(1)))
                tot.add(sub, mult=trip)
            elif op in ("call", "async-start"):
                cm = _TOAPPLY_RE.search(ins.rest) or _CALLS_RE.search(ins.rest)
                if cm and cm.group(1) in self.comps:
                    tot.add(self.comp_cost(cm.group(1)))
            elif op == "conditional":
                bm = _BRANCHES_RE.search(ins.rest)
                if bm:
                    names = re.findall(r"[\w.\-]+", bm.group(1))
                    subs = [self.comp_cost(n) for n in names
                            if n in self.comps]
                    if subs:
                        best = max(subs, key=lambda c: c.flops + c.bytes)
                        tot.add(best)
            elif any(op == k or op.startswith(k + "-") for k in COLLECTIVES):
                if op.endswith("-done"):
                    continue
                base = next(k for k in COLLECTIVES
                            if op == k or op.startswith(k + "-"))
                b = _shape_bytes(ins.shape)
                tot.coll[base]["count"] += 1
                tot.coll[base]["bytes"] += b
                tot.coll[base]["wire_bytes"] += b * _WIRE_MULT[base]
                tot.bytes += b  # collectives also touch HBM
                tot.cat(f"coll:{base}:{ins.shape[:48]}", b)
            elif op == "dynamic-slice":
                tot.bytes += 2 * _shape_bytes(ins.shape)  # read + write slice
                tot.cat("dynamic-slice", 2 * _shape_bytes(ins.shape))
            elif op == "dynamic-update-slice":
                ops_u = ins.operands
                upd = self._operand_shape(comp, ops_u[1]) if len(ops_u) > 1 \
                    else None
                b = 2 * _shape_bytes(upd) if upd else _shape_bytes(ins.shape)
                tot.bytes += b
                tot.cat("dynamic-update-slice", b)
            elif op in ("copy", "copy-start", "transpose", "reshape",
                        "broadcast", "convert", "slice",
                        "concatenate", "pad",
                        "reduce", "gather", "scatter", "select", "compare",
                        "add", "multiply", "iota", "reverse", "sort",
                        "convolution", "rng-bit-generator", "exponential",
                        "custom-call"):
                b = _shape_bytes(ins.shape)
                for o in ins.operands:
                    s = self._operand_shape(comp, o)
                    if s:
                        b += _shape_bytes(s)
                tot.bytes += b
                tot.cat(op, b)
            # parameter / constant / tuple / get-tuple-element / bitcast: free
        return tot

    @staticmethod
    def _flops_only(c: CostTotals) -> CostTotals:
        out = CostTotals()
        out.flops = c.flops
        for k in COLLECTIVES:
            out.coll[k] = dict(c.coll[k])
        return out

    def total(self) -> CostTotals:
        if self.entry is None:
            return CostTotals()
        return self.comp_cost(self.entry)


def analyze(hlo_text: str) -> Dict:
    cm = HloCostModel(hlo_text)
    tot = cm.total()
    wire = sum(v["wire_bytes"] for v in tot.coll.values())
    cats = dict(sorted(tot.by_cat.items(), key=lambda kv: -kv[1])[:12])
    return {"flops": tot.flops, "hbm_bytes": tot.bytes,
            "collectives": tot.coll, "collective_wire_bytes": wire,
            "byte_categories": cats}
