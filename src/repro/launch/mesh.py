"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state): single-pod = (16, 16) over ("data", "model") = 256
chips; multi-pod = (2, 16, 16) over ("pod", "data", "model") = 512 chips.
The dry-run driver sets XLA_FLAGS=--xla_force_host_platform_device_count
before any jax import so these meshes can be built on CPU.
"""

from __future__ import annotations

import jax


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (CPU) devices exist — used by tests."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=_auto(2))
