"""Production-style training launcher (host-mesh scale).

Fault-tolerant loop: resume-from-latest-checkpoint, per-step retry,
periodic async checkpointing, deterministic restart-safe data pipeline.
On real TPU pods the same entry point runs under multi-host jax.distributed;
in this container it runs the reduced configs on the host devices.

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b \
        --steps 50 --seq 64 --batch 8 --ckpt /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.ckpt import CheckpointManager
from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models.model import build
from repro.training import optim, step as step_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full architecture config (TPU scale)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = reduced(cfg)
    api = build(cfg)
    print(f"arch={cfg.name} params={cfg.n_params():,}")

    oc = optim.AdamWConfig(lr=args.lr, warmup_steps=10)
    rc = step_lib.RunConfig(microbatches=args.microbatches, adamw=oc)
    step_fn = jax.jit(step_lib.make_train_step(api, rc))
    pipe = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                    global_batch=args.batch, seed=0))
    mgr = CheckpointManager(args.ckpt)

    start = mgr.latest_step() or 0
    if start:
        like = jax.eval_shape(
            lambda r: step_lib.init_train_state(api, r, oc),
            jax.random.PRNGKey(0))
        state = jax.tree.map(jnp.asarray, mgr.restore(start, like))
        print(f"resumed from step {start}")
    else:
        state = step_lib.init_train_state(api, jax.random.PRNGKey(0), oc)

    t0 = time.time()
    for i in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(i).items()}
        for attempt in range(3):  # straggler/failure retry
            try:
                state, m = step_fn(state, batch)
                break
            except Exception as e:  # noqa: BLE001
                print(f"step {i} attempt {attempt} failed: {e!r}")
                if attempt == 2:
                    raise
        if i % 10 == 0 or i == args.steps - 1:
            tok_s = args.batch * args.seq * (i - start + 1) \
                / max(1e-9, time.time() - t0)
            print(f"step {i:4d} loss={float(m['loss']):.4f} "
                  f"lr={float(m['lr']):.2e} tok/s={tok_s:,.0f} "
                  f"idx_cache_hit={pipe.index_hit_ratio:.2f}")
        if (i + 1) % args.ckpt_every == 0:
            mgr.save(i + 1, state, blocking=False)
    mgr.save(args.steps, state, blocking=True)
    print(f"done; checkpoints: {mgr.all_steps()}")


if __name__ == "__main__":
    main()
