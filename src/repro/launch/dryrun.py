import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input-shape x mesh) cell: build the step
function (train / prefill / decode), attach in/out shardings from the rule
engine, ``jit(...).lower(**ShapeDtypeStructs).compile()``, and record
memory analysis, cost analysis, and the HLO collective schedule into
experiments/dryrun/<mesh>/<arch>__<shape>.json (resumable: existing files
are skipped unless --force).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
      [--mesh single|multi|both] [--force] [--list]
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.launch import hlo_analysis, hlo_cost, specs as specs_lib
from repro.launch.mesh import make_production_mesh
from repro.models import opt_flags, transformer as T_lib
from repro.models.config import SHAPES, cell_applicable
from repro.models.model import build
from repro.sharding import rules
from repro.training import optim, step as step_lib

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def flags_for(arch: str, shape: str, variant: str) -> dict:
    """Per-cell optimization flags for the 'opt' variant (§Perf)."""
    if variant != "opt":
        return {}
    cfg = get_config(arch)
    cell = [s for s in SHAPES if s.shape == shape][0]
    f = {}
    if cell.kind == "decode":
        f["decode_shard_scores"] = True
        if cfg.family in ("dense", "vlm", "moe"):
            f["decode_buffered"] = True
    if cfg.family == "ssm" and cell.kind in ("train", "prefill"):
        f["mamba_seq_scan"] = True  # iteration 2.2 (2.1 refuted)
    if arch == "kimi-k2-1t-a32b" and cell.kind == "train":
        f["moe_local_dispatch"] = True
    return f

# per-arch training knobs (microbatches, moment dtype) chosen for HBM
TRAIN_KNOBS = {
    "kimi-k2-1t-a32b": dict(microbatches=8, moment_dtype="bfloat16"),
    "phi3-medium-14b": dict(microbatches=4, moment_dtype="float32"),
    "granite-3-8b": dict(microbatches=4, moment_dtype="float32"),
    "llava-next-mistral-7b": dict(microbatches=4, moment_dtype="float32"),
    "chatglm3-6b": dict(microbatches=4, moment_dtype="float32"),
    "falcon-mamba-7b": dict(microbatches=4, moment_dtype="float32"),
    "zamba2-2.7b": dict(microbatches=2, moment_dtype="float32"),
}


def _shard(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _sds(tree):
    return jax.tree.map(lambda t: jax.ShapeDtypeStruct(t.shape, t.dtype), tree)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               variant: str = "base"):
    cfg = get_config(arch)
    cell = [s for s in SHAPES if s.shape == shape_name][0]
    skip = cell_applicable(cfg, cell)
    if skip:
        return {"status": "SKIP", "reason": skip}

    mesh = make_production_mesh(multi_pod=multi_pod)
    api = build(cfg)
    log = rules.RuleLog()
    t0 = time.time()
    with opt_flags.use_flags(**flags_for(arch, shape_name, variant)):
        return _lower_cell_inner(cfg, cell, mesh, api, log, t0, arch,
                                 shape_name, multi_pod, variant)


def _lower_cell_inner(cfg, cell, mesh, api, log, t0, arch, shape_name,
                      multi_pod, variant):

    with jax.set_mesh(mesh):
        params_shape = jax.eval_shape(api.init, jax.random.PRNGKey(0))
        pspecs = rules.param_specs(cfg, mesh, params_shape, log)
        bshapes = specs_lib.batch_shapes(cfg, cell)
        bspecs = rules.batch_specs(cfg, mesh, bshapes, log)
        binputs = specs_lib.input_specs(cfg, cell)

        if cell.kind == "train":
            knobs = TRAIN_KNOBS.get(arch, dict(microbatches=1,
                                               moment_dtype="float32"))
            oc = optim.AdamWConfig(moment_dtype=knobs["moment_dtype"])
            rc = step_lib.RunConfig(microbatches=knobs["microbatches"],
                                    adamw=oc)
            state_shape = step_lib.abstract_train_state(api, oc)
            ospecs = rules.opt_state_specs(cfg, mesh, params_shape, pspecs,
                                           log)
            state_spec = step_lib.TrainState(
                params=pspecs,
                opt=optim.OptState(mu=ospecs, nu=ospecs, step=P()))
            train_step = step_lib.make_train_step(api, rc)
            jitted = jax.jit(
                train_step,
                in_shardings=(_shard(mesh, state_spec),
                              _shard(mesh, bspecs)),
                out_shardings=(_shard(mesh, state_spec), None),
                donate_argnums=(0,))
            lowered = jitted.lower(_sds(state_shape), binputs)
        elif cell.kind == "prefill":
            pre = step_lib.make_prefill_step(api)
            cache_shape = jax.eval_shape(
                lambda p, b: api.prefill(p, b)[1], params_shape,
                _sds_batch(binputs))
            cspecs = rules.cache_specs(cfg, mesh, cache_shape, log)
            jitted = jax.jit(
                pre,
                in_shardings=(_shard(mesh, pspecs), _shard(mesh, bspecs)),
                out_shardings=(None, _shard(mesh, cspecs)))
            lowered = jitted.lower(_sds(params_shape), binputs)
        else:  # decode
            B, S = cell.global_batch, cell.seq_len
            buffered = (opt_flags.FLAGS.decode_buffered
                        and cfg.family in ("dense", "vlm", "moe"))
            if buffered:
                R = opt_flags.FLAGS.decode_buffer_len
                cache_shape = jax.eval_shape(
                    lambda: T_lib.init_buffered_cache(cfg, B, S, buf_len=R))
                dec = lambda p, t, c: T_lib.forward_decode_buffered(
                    cfg, p, t, c)
            else:
                cache_shape = jax.eval_shape(lambda: api.init_cache(B, S))
                dec = step_lib.make_decode_step(api)
            cspecs = rules.cache_specs(cfg, mesh, cache_shape, log)
            tok_sds = binputs["tokens"]
            tok_spec = rules.batch_specs(
                cfg, mesh, {"tokens": ((B, 1), jnp.int32)}, log)["tokens"]
            jitted = jax.jit(
                dec,
                in_shardings=(_shard(mesh, pspecs),
                              NamedSharding(mesh, tok_spec),
                              _shard(mesh, cspecs)),
                out_shardings=(None, _shard(mesh, cspecs)),
                donate_argnums=(2,))
            lowered = jitted.lower(_sds(params_shape), tok_sds,
                                   _sds(cache_shape))
            if buffered:  # the amortized ring->base flush, every R steps
                jc = jax.jit(lambda c: T_lib.commit_buffer(cfg, c),
                             in_shardings=(_shard(mesh, cspecs),),
                             out_shardings=_shard(mesh, cspecs),
                             donate_argnums=(0,))
                commit_lowered = jc.lower(_sds(cache_shape))

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        commit_extra = None
        if cell.kind == "decode" and opt_flags.FLAGS.decode_buffered \
                and cfg.family in ("dense", "vlm", "moe"):
            ccomp = commit_lowered.compile()
            cla = hlo_cost.analyze(ccomp.as_text())
            R = opt_flags.FLAGS.decode_buffer_len
            commit_extra = {
                "flops": cla["flops"], "hbm_bytes": cla["hbm_bytes"],
                "collective_wire_bytes": cla["collective_wire_bytes"],
                "amortize_over": R}

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    hlo = compiled.as_text()
    n_chips = int(np.prod(mesh.devices.shape))
    # loop-aware structural cost model (XLA's cost_analysis counts while
    # bodies once — see hlo_cost.py); per-device numbers.
    la = hlo_cost.analyze(hlo)
    flops = la["flops"]
    bytes_acc = la["hbm_bytes"]
    wire = la["collective_wire_bytes"]
    coll = la["collectives"]
    if commit_extra is not None:  # fold in the amortized commit cost
        R = commit_extra["amortize_over"]
        flops += commit_extra["flops"] / R
        bytes_acc += commit_extra["hbm_bytes"] / R
        wire += commit_extra["collective_wire_bytes"] / R
    terms = hlo_analysis.roofline_terms(flops, bytes_acc, wire, n_chips)
    xla_flops = float(cost.get("flops", 0.0)) if cost else 0.0
    xla_bytes = float(cost.get("bytes accessed", 0.0)) if cost else 0.0

    mem_d = {}
    if mem is not None:
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            mem_d[f] = getattr(mem, f, None)

    return {
        "status": "OK",
        "arch": arch, "shape": shape_name,
        "variant": variant,
        "commit_amortized": commit_extra,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "kind": cell.kind,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory_analysis": mem_d,
        "cost_flops": flops,
        "cost_bytes_accessed": bytes_acc,
        "xla_cost_flops_looponce": xla_flops,
        "xla_cost_bytes_looponce": xla_bytes,
        "collectives": coll,
        "collective_wire_bytes": wire,
        "byte_categories": la.get("byte_categories", {}),
        "roofline": terms,
        "sharding_fallbacks": log.fallbacks,
        "hlo_bytes": len(hlo),
    }


def _sds_batch(binputs):
    return binputs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--variant", default="base", choices=["base", "opt"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else [s.shape for s in SHAPES]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    cells = [(a, s, m) for a in archs for s in shapes for m in meshes]
    if args.list:
        for c in cells:
            print(c)
        return

    for arch, shape_name, multi in cells:
        root = OUT_DIR if args.variant == "base" else \
            OUT_DIR.parent / "dryrun_opt"
        mdir = root / ("2x16x16" if multi else "16x16")
        mdir.mkdir(parents=True, exist_ok=True)
        out = mdir / f"{arch}__{shape_name}.json"
        if out.exists() and not args.force:
            print(f"[skip-cached] {out.name} ({'multi' if multi else 'single'})")
            continue
        label = f"{arch} x {shape_name} x {'2x16x16' if multi else '16x16'}"
        print(f"[dryrun] {label} ...", flush=True)
        t0 = time.time()
        try:
            rec = lower_cell(arch, shape_name, multi, variant=args.variant)
        except Exception as e:  # noqa: BLE001 — record the failure, keep going
            rec = {"status": "FAIL", "error": repr(e),
                   "traceback": traceback.format_exc()[-4000:]}
        rec["wall_s"] = round(time.time() - t0, 1)
        out.write_text(json.dumps(rec, indent=1, default=str))
        status = rec["status"]
        extra = ""
        if status == "OK":
            r = rec["roofline"]
            extra = (f" flops={rec['cost_flops']:.3e}"
                     f" dom={r['dominant']} bound={r['bound_s']:.4f}s"
                     f" compile={rec['compile_s']}s")
        elif status == "FAIL":
            extra = " " + rec["error"][:200]
        print(f"[{status}] {label}{extra} ({rec['wall_s']}s)", flush=True)


if __name__ == "__main__":
    main()
