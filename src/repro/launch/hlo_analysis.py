"""Parse collective-communication bytes out of optimized HLO text and
compute the three roofline terms (DESIGN.md §7).

Hardware model: TPU v5e-class chip — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (values fixed by the assignment).
"""

from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12     # bf16 / chip
HBM_BW = 819e9          # bytes/s / chip
ICI_BW = 50e9           # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# bytes-on-the-wire multiplier per output byte (ring algorithms):
#   all-reduce moves ~2x the buffer; the others ~1x.
_WIRE_MULT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
              "all-to-all": 1.0, "collective-permute": 1.0}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-collective-kind: op count, result bytes, wire bytes."""
    out = {k: {"count": 0, "bytes": 0.0, "wire_bytes": 0.0}
           for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        # result-shape then op name:  %x = bf16[..]{..} all-gather(...)
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}\s]*?)\s+([a-z\-]+)(?:\.\d+)?\(", ls)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        # normalize fused variants like "all-gather-start"
        base = None
        for k in _COLLECTIVES:
            if op == k or op.startswith(k + "-"):
                base = k
                break
        if base is None:
            continue
        if op.endswith("-done"):
            continue  # counted at -start
        b = _shape_bytes(shape_str)
        out[base]["count"] += 1
        out[base]["bytes"] += b
        out[base]["wire_bytes"] += b * _WIRE_MULT[base]
    return out


def roofline_terms(flops: float, hbm_bytes: float, coll_wire_bytes: float,
                   n_chips: int, links_per_chip: int = 4,
                   per_device: bool = True) -> Dict[str, float]:
    """All inputs are per-device when ``per_device`` (XLA reports the
    partitioned module); terms in seconds."""
    div = 1 if per_device else n_chips
    t_compute = (flops / div) / PEAK_FLOPS
    t_memory = (hbm_bytes / div) / HBM_BW
    t_coll = (coll_wire_bytes / div) / (ICI_BW * links_per_chip)
    dom = max((t_compute, "compute"), (t_memory, "memory"),
              (t_coll, "collective"))
    return {"t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_collective_s": t_coll, "dominant": dom[1],
            "bound_s": dom[0]}
