"""Deterministic synthetic token pipeline with a Clock2Q+-managed shard-
index cache.

A large virtual dataset is split into shards; reading a global batch
requires resolving (shard -> index-block -> token offsets) through an
index cache — the literal metadata-cache use case of the paper (index
blocks pack many entries, so one batch touches each block several times
in a burst: correlated references).  Misses are counted as simulated host
I/O; the cache keeps the pipeline off the host-I/O critical path.

The stream is a pure function of (seed, step, host_id) — restart-safe
(resume from any step without replaying) and elastic (hosts can be
re-assigned disjoint slices).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np

from repro.core.prodcache import ProdClock2QPlus


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    n_shards: int = 1 << 14
    docs_per_shard: int = 128
    index_entries_per_block: int = 64   # fan-out of the index structure
    index_cache_blocks: int = 256
    seed: int = 0


class TokenPipeline:
    def __init__(self, dc: DataConfig, host_id: int = 0, n_hosts: int = 1):
        self.dc = dc
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.index_cache = ProdClock2QPlus(dc.index_cache_blocks)
        self.io_misses = 0
        self.lookups = 0

    # -- index resolution (through the Clock2Q+ cache) -------------------------
    def _resolve(self, shard: int, doc: int) -> int:
        """Resolve a (shard, doc) to its seed via the index cache.  The
        index block id = global doc number // fan-out (paper §2.3)."""
        gdoc = shard * self.dc.docs_per_shard + doc
        block = gdoc // self.dc.index_entries_per_block
        self.lookups += 1
        r = self.index_cache.access(block)
        if not r.hit:
            self.io_misses += 1  # simulated host/index I/O
        return gdoc

    def _doc_tokens(self, gdoc: int, n: int, rng_salt: int) -> np.ndarray:
        rng = np.random.default_rng((self.dc.seed, gdoc, rng_salt))
        # skewed unigram stream with local repetition structure
        base = rng.integers(0, self.dc.vocab, size=n)
        rep = rng.random(n) < 0.3
        base[1:][rep[1:]] = base[:-1][rep[1:]]
        return base.astype(np.int32)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        """Host-local slice of the global batch for ``step``."""
        dc = self.dc
        per_host = dc.global_batch // self.n_hosts
        rng = np.random.default_rng((dc.seed, step))
        # data loaders read shards from a sliding window (shuffle buffer):
        # index blocks are re-touched across adjacent batches — the
        # correlated-reference pattern the Clock2Q+ cache absorbs.
        window = max(8, dc.global_batch // 2)
        base = (step * max(1, window // 8)) % dc.n_shards
        shards = (base + rng.integers(0, window, size=dc.global_batch)) \
            % dc.n_shards
        docs = rng.integers(0, dc.docs_per_shard, size=dc.global_batch)
        lo = self.host_id * per_host
        toks = np.empty((per_host, dc.seq_len + 1), np.int32)
        for i in range(per_host):
            gdoc = self._resolve(int(shards[lo + i]), int(docs[lo + i]))
            toks[i] = self._doc_tokens(gdoc, dc.seq_len + 1, rng_salt=step)
        return {"tokens": toks[:, :-1],
                "labels": toks[:, 1:].copy()}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1

    @property
    def index_hit_ratio(self) -> float:
        return 1.0 - self.io_misses / max(1, self.lookups)
