"""``repro.obs`` — the observability subsystem (metrics, events, export).

Bottom layer of the repo, sealed: imports only stdlib + numpy, is
imported by every cache subsystem (core, shardcache, kvcache, tuning,
serving) — see tools/check_layering.py.

    sink = ObsSink(src="shard0", labels={"shard": "0"})
    hits = sink.counter("cache_hits_total", ("shard", "queue"))
    c = hits.labels("0", "small")   # bind once at init ...
    c.value += 1                    # ... increment directly on the hot path
    sink.emit(EV_EVICT, shard=0, a=key)          # state transitions only
    print(to_prometheus(sink.snapshot()))
"""

from repro.obs.events import (  # noqa: F401
    EV_ADMIT, EV_BATCH, EV_DEGRADED, EV_EVICT, EV_FAULT, EV_GHOST_PROMOTE,
    EV_IO_ERROR, EV_IO_RETRY, EV_IO_WAIT, EV_JOURNAL_TRUNCATED,
    EV_PROMOTE, EV_REBALANCE, EV_REJECT, EV_RESIZE, EV_RESIZE_DONE,
    EV_RESTORE, EV_RETUNE, EV_SHARD_LOST, EV_SHARD_REWARM, EV_SHED,
    EV_SNAPSHOT, EV_WINDOW_ENTER, EV_WINDOW_EXIT, EVENT_NAMES,
    INCIDENT_KINDS, EventRing, NullRing,
)
from repro.obs.export import (  # noqa: F401
    NullSink, ObsSink, Snapshot, delta, merge, snapshot, to_prometheus,
)
from repro.obs.metrics import (  # noqa: F401
    Counter, Family, Gauge, Histogram, Registry, parse_sample_key,
    sample_key,
)

# canonical Clock2Q+ flow-counter schema: every implementation's
# ``flows()`` dict is derived from the ``cache_flow_total{flow=...}``
# counter family iterated in THIS order, so the single-shard and
# sharded-aggregate key sets can never drift (ISSUE satellite).
FLOW_KINDS = ("small_to_main", "small_to_ghost", "ghost_to_main",
              "evict_main", "small_bypass")
