"""Snapshots, deltas, and export formats (JSON + Prometheus text).

A ``Snapshot`` is a point-in-time, plain-data view of one or many
registries/rings: flat ``sample_key -> value`` dicts per instrument
kind, plus the retained event records.  Plain data means snapshots
survive JSON round-trips bit-for-bit, merge across shards by key, and
subtract into deltas — the three operations every consumer needs
(per-shard aggregation, CI artifacts, scrape endpoints, obsreport).

Merge/delta algebra:
  * counters and histogram buckets are sums -> merge adds, delta
    subtracts; the 4-thread conformance test asserts the merged snapshot
    equals the sum of per-shard deltas exactly.
  * gauges are point-in-time -> merge unions (duplicate keys: last
    wins), delta keeps the newer value.
  * events are identified by (src, seq) -> merge concatenates, delta
    keeps events newer than the old snapshot's per-src high-water mark.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Dict, Iterable, List, Optional

from repro.obs import events as events_mod
from repro.obs import metrics as metrics_mod


@dataclasses.dataclass
class Snapshot:
    ts: float
    counters: Dict[str, int] = dataclasses.field(default_factory=dict)
    gauges: Dict[str, float] = dataclasses.field(default_factory=dict)
    hists: Dict[str, dict] = dataclasses.field(default_factory=dict)
    events: List[dict] = dataclasses.field(default_factory=list)
    dropped_events: int = 0
    meta: Dict[str, str] = dataclasses.field(default_factory=dict)

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(dataclasses.asdict(self), indent=indent,
                          sort_keys=False)

    @classmethod
    def from_json(cls, text: str) -> "Snapshot":
        return cls(**json.loads(text))


def snapshot(registries, rings=(), ts: Optional[float] = None,
             meta: Optional[Dict[str, str]] = None) -> Snapshot:
    """Point-in-time snapshot of one or many registries + event rings."""
    if isinstance(registries, metrics_mod.Registry):
        registries = (registries,)
    if isinstance(rings, events_mod.EventRing):
        rings = (rings,)
    snap = Snapshot(ts=time.time() if ts is None else ts,
                    meta=dict(meta or {}))
    for reg in registries:
        for kind, _name, key, value in reg.samples():
            if kind == "counter":
                snap.counters[key] = snap.counters.get(key, 0) + value
            elif kind == "gauge":
                snap.gauges[key] = value
            else:
                _hist_add(snap.hists, key, value)
    for ring in rings:
        snap.events.extend(ring.records())
        snap.dropped_events += ring.dropped
    return snap


def _hist_add(into: Dict[str, dict], key: str, h: dict,
              sign: int = 1) -> None:
    cur = into.get(key)
    if cur is None:
        into[key] = dict(le=list(h["le"]),
                         counts=[sign * c for c in h["counts"]],
                         sum=sign * h["sum"], count=sign * h["count"])
        return
    if cur["le"] != list(h["le"]):
        raise ValueError(f"histogram {key!r}: incompatible bucket bounds")
    cur["counts"] = [a + sign * b
                     for a, b in zip(cur["counts"], h["counts"])]
    cur["sum"] += sign * h["sum"]
    cur["count"] += sign * h["count"]


def merge(snaps: Iterable[Snapshot]) -> Snapshot:
    """Union of snapshots: counters/histograms add, gauges last-wins,
    events concatenate (kept in input order, each identified by
    (src, seq))."""
    snaps = list(snaps)
    out = Snapshot(ts=max((s.ts for s in snaps), default=0.0))
    for s in snaps:
        for k, v in s.counters.items():
            out.counters[k] = out.counters.get(k, 0) + v
        out.gauges.update(s.gauges)
        for k, h in s.hists.items():
            _hist_add(out.hists, k, h)
        out.events.extend(s.events)
        out.dropped_events += s.dropped_events
        out.meta.update(s.meta)
    return out


def delta(old: Snapshot, new: Snapshot) -> Snapshot:
    """What happened between two snapshots of the same source(s):
    counter/histogram differences, the newer gauge values, and the
    events emitted after ``old`` (per-src sequence high-water mark)."""
    out = Snapshot(ts=new.ts, meta=dict(new.meta))
    for k, v in new.counters.items():
        out.counters[k] = v - old.counters.get(k, 0)
    out.gauges = dict(new.gauges)
    for k, h in new.hists.items():
        out.hists[k] = dict(le=list(h["le"]), counts=list(h["counts"]),
                            sum=h["sum"], count=h["count"])
        if k in old.hists:
            _hist_add(out.hists, k, old.hists[k], sign=-1)
    mark: Dict[str, int] = {}
    for e in old.events:
        mark[e["src"]] = max(mark.get(e["src"], -1), e["seq"])
    out.events = [e for e in new.events
                  if e["seq"] > mark.get(e["src"], -1)]
    out.dropped_events = new.dropped_events - old.dropped_events
    return out


def to_prometheus(snap: Snapshot) -> str:
    """Prometheus text exposition format (0.0.4).  Histograms expand to
    the standard ``_bucket``/``_sum``/``_count`` series with cumulative
    ``le`` buckets."""
    by_family: Dict[str, List[str]] = {}

    def add(key: str, kind: str, line: str) -> None:
        name, _ = metrics_mod.parse_sample_key(key)
        fam = by_family.setdefault(name, [f"# TYPE {name} {kind}"])
        fam.append(line)

    for key in sorted(snap.counters):
        add(key, "counter", f"{key} {snap.counters[key]}")
    for key in sorted(snap.gauges):
        add(key, "gauge", f"{key} {_fmt(snap.gauges[key])}")
    for key in sorted(snap.hists):
        h = snap.hists[key]
        name, labels = metrics_mod.parse_sample_key(key)
        fam = by_family.setdefault(name, [f"# TYPE {name} histogram"])
        cum = 0
        for le, c in zip(h["le"], h["counts"]):
            cum += c
            lb = dict(labels)
            lb["le"] = "+Inf" if le == float("inf") else _fmt(le)
            fam.append(f"{metrics_mod.sample_key(name + '_bucket', lb)} "
                       f"{cum}")
        fam.append(f"{metrics_mod.sample_key(name + '_sum', labels)} "
                   f"{_fmt(h['sum'])}")
        fam.append(f"{metrics_mod.sample_key(name + '_count', labels)} "
                   f"{h['count']}")
    lines: List[str] = []
    for name in sorted(by_family):
        lines.extend(by_family[name])
    return "\n".join(lines) + "\n"


def _fmt(v: float) -> str:
    return repr(float(v)) if v != int(v) else str(int(v))


# -- sinks ---------------------------------------------------------------------

class ObsSink:
    """One component's telemetry bundle: a registry + an event ring.

    This is the object the cache stack passes around (``obs=`` kwargs):
    constructing instruments goes through it at init time, the hot path
    touches only the bound instruments, and ``snapshot()`` renders the
    whole bundle.  ``src`` names the component in event records and
    default shard labels."""

    null = False

    def __init__(self, src: str = "", labels: Optional[Dict] = None,
                 events_capacity: int = 4096):
        self.src = src
        self.registry = metrics_mod.Registry(labels)
        self.ring = events_mod.EventRing(events_capacity, src=src)

    # registry passthroughs (the wiring surface)
    def counter(self, name, labelnames=(), help=""):
        return self.registry.counter(name, labelnames, help)

    def gauge(self, name, labelnames=(), help=""):
        return self.registry.gauge(name, labelnames, help)

    def histogram(self, name, labelnames=(), help="", base=1e-6,
                  n_buckets=28):
        return self.registry.histogram(name, labelnames, help, base=base,
                                       n_buckets=n_buckets)

    def on_collect(self, fn):
        return self.registry.on_collect(fn)

    def emit(self, kind: int, shard: int = -1, a: int = 0, b: int = 0,
             c: float = 0.0) -> None:
        self.ring.emit(kind, shard, a, b, c)

    def snapshot(self, ts: Optional[float] = None) -> Snapshot:
        return snapshot(self.registry, self.ring, ts=ts,
                        meta={"src": self.src} if self.src else None)


class NullSink(ObsSink):
    """Telemetry disabled: the event ring is a no-op and snapshots are
    empty.  Instruments still exist and still count — they back the
    semantic ``hits``/``misses``/``flows`` surfaces the cache stack has
    always exposed (the same plain increments it did before the obs
    layer existed), so correctness-visible state is identical with the
    sink nulled.  The ``perf_obs_overhead`` benchmark gates the
    instrumented/NullSink wall-time ratio at <= 1.05x."""

    null = True

    def __init__(self, src: str = "", labels: Optional[Dict] = None,
                 events_capacity: int = 0):
        self.src = src
        self.registry = metrics_mod.Registry(labels)
        self.ring = events_mod.NullRing(src=src)

    def snapshot(self, ts: Optional[float] = None) -> Snapshot:
        return Snapshot(ts=time.time() if ts is None else ts,
                        meta={"src": self.src, "null": "1"})
