"""Typed metric instruments + registry — the bottom of the obs layer.

Design constraints (ISSUE: hit-path-cheap telemetry):

  * An instrument is a tiny plain-Python object; the hot path mutates a
    single attribute (``counter.value += 1``) or one numpy array cell
    (``hist.counts[i] += 1``) — no locks, no dict lookups, no string
    formatting.  Callers bind instruments to local attributes at init
    and increment directly; ``inc``/``observe`` methods exist for cold
    paths and tests.
  * Lock-free WITHIN a shard: every concurrent component (each
    ``ProdClock2QPlus`` shard, each replay worker thread) owns its own
    ``Registry``; cross-shard aggregation happens only at snapshot time
    by merging flat sample dicts (``repro.obs.export``), never on the
    access path.
  * Mergeable: counters and histogram bucket arrays are sums, so
    per-shard snapshots (and snapshot deltas) add exactly — no dropped
    increments, asserted by tests/test_obs.py under 4-thread replay.

This module may import ONLY the stdlib and numpy: ``repro.obs`` sits
beside ``repro.core.engine`` at the bottom of the layering order and is
sealed (tools/check_layering.py) — every cache subsystem imports obs,
obs imports none of them.
"""

from __future__ import annotations

from typing import Dict, Iterator, List, Tuple

import numpy as np

KINDS = ("counter", "gauge", "histogram")


class Counter:
    """Monotonic counter.  Hot paths do ``c.value += n`` directly."""

    kind = "counter"
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def sample(self):
        return self.value


class Gauge:
    """Point-in-time value (set-or-adjust)."""

    kind = "gauge"
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def dec(self, n: float = 1.0) -> None:
        self.value -= n

    def sample(self):
        return self.value


class Histogram:
    """Log2-bucketed histogram (numpy-backed counts).

    Bucket ``i`` holds observations ``v`` with ``base * 2**(i-1) <= v <
    base * 2**i`` (bucket 0 holds ``v < base``); the top bucket is a
    catch-all.  ``observe`` is one ``bit_length`` + one array-cell
    increment — cheap enough for per-request latencies.  Bucket arrays
    from two histograms with the same shape add elementwise, which is
    what makes per-shard histograms mergeable.
    """

    kind = "histogram"
    __slots__ = ("base", "counts", "sum")

    def __init__(self, base: float = 1e-6, n_buckets: int = 28):
        self.base = float(base)
        self.counts = np.zeros(n_buckets, np.int64)
        self.sum = 0.0

    def observe(self, v: float) -> None:
        i = int(v / self.base).bit_length()
        c = self.counts
        c[i if i < c.shape[0] else c.shape[0] - 1] += 1
        self.sum += v

    @property
    def count(self) -> int:
        return int(self.counts.sum())

    def bounds(self) -> List[float]:
        """Upper (``le``) bound of each bucket; the last is +inf."""
        n = self.counts.shape[0]
        return [self.base * (1 << i) for i in range(n - 1)] + [float("inf")]

    def quantile(self, q: float) -> float:
        """Bucket-resolution quantile estimate (upper bound of the bucket
        holding the q-th observation); NaN when empty."""
        total = self.count
        if total == 0:
            return float("nan")
        target = q * total
        run = 0
        for i, c in enumerate(self.counts.tolist()):
            run += c
            if run >= target:
                return self.base * (1 << min(i, self.counts.shape[0] - 2))
        return self.base * (1 << (self.counts.shape[0] - 2))

    def sample(self):
        return dict(le=self.bounds(), counts=self.counts.tolist(),
                    sum=float(self.sum), count=self.count)


_KIND_CLS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


def sample_key(name: str, labels: Dict[str, str]) -> str:
    """Canonical flat sample key, ``name{k1="v1",k2="v2"}`` with label
    names sorted — the merge/export/Prometheus identity of a series."""
    if not labels:
        return name
    inner = ",".join(f'{k}="{labels[k]}"' for k in sorted(labels))
    return f"{name}{{{inner}}}"


def parse_sample_key(key: str) -> Tuple[str, Dict[str, str]]:
    """Inverse of ``sample_key`` (labels values must not contain ``",``)."""
    if "{" not in key:
        return key, {}
    name, rest = key.split("{", 1)
    labels = {}
    for part in rest.rstrip("}").split('",'):
        k, v = part.split("=", 1)
        labels[k] = v.strip('"')
    return name, labels


class Family:
    """A named metric family: one instrument per label-value tuple."""

    __slots__ = ("name", "kind", "labelnames", "help", "kw", "children")

    def __init__(self, name: str, kind: str, labelnames: Tuple[str, ...] = (),
                 help: str = "", **kw):
        if kind not in KINDS:
            raise ValueError(f"unknown instrument kind {kind!r}")
        self.name = name
        self.kind = kind
        self.labelnames = tuple(labelnames)
        self.help = help
        self.kw = kw
        self.children: Dict[Tuple[str, ...], object] = {}

    def labels(self, *values):
        """Get-or-create the instrument for one label-value tuple."""
        if len(values) != len(self.labelnames):
            raise ValueError(
                f"{self.name}: expected labels {self.labelnames}, "
                f"got {values!r}")
        key = tuple(str(v) for v in values)
        inst = self.children.get(key)
        if inst is None:
            inst = self.children[key] = _KIND_CLS[self.kind](**self.kw)
        return inst


class Registry:
    """Per-component (per-shard) instrument registry.

    ``base_labels`` (e.g. ``{"shard": "3"}``) are folded into every
    sample key at snapshot time, so N shard registries with the same
    family names merge into disjoint labeled series.
    """

    def __init__(self, base_labels: Dict[str, str] | None = None):
        self.base_labels = {k: str(v)
                            for k, v in (base_labels or {}).items()}
        self.families: Dict[str, Family] = {}
        self._collectors: List = []

    def _family(self, name: str, kind: str, labelnames=(), help: str = "",
                **kw) -> Family:
        fam = self.families.get(name)
        if fam is None:
            fam = self.families[name] = Family(name, kind, labelnames,
                                               help, **kw)
        elif fam.kind != kind or fam.labelnames != tuple(labelnames):
            raise ValueError(
                f"family {name!r} re-registered as {kind}{labelnames} "
                f"(was {fam.kind}{fam.labelnames})")
        return fam

    def counter(self, name: str, labelnames=(), help: str = "") -> Family:
        return self._family(name, "counter", labelnames, help)

    def gauge(self, name: str, labelnames=(), help: str = "") -> Family:
        return self._family(name, "gauge", labelnames, help)

    def histogram(self, name: str, labelnames=(), help: str = "",
                  base: float = 1e-6, n_buckets: int = 28) -> Family:
        return self._family(name, "histogram", labelnames, help,
                            base=base, n_buckets=n_buckets)

    def on_collect(self, fn) -> None:
        """Register a pre-snapshot hook (set occupancy-style gauges
        lazily instead of maintaining them on the access path)."""
        self._collectors.append(fn)

    def samples(self) -> Iterator[Tuple[str, str, str, object]]:
        """Yield ``(kind, family_name, sample_key, value)`` for every
        instrument, with base labels folded in."""
        for fn in self._collectors:
            fn()
        for fam in self.families.values():
            for lv, inst in fam.children.items():
                labels = dict(self.base_labels)
                labels.update(zip(fam.labelnames, lv))
                yield fam.kind, fam.name, sample_key(fam.name,
                                                     labels), inst.sample()
