"""Fixed-capacity ring-buffer event trace.

Events record the *state transitions* of the cache stack — evictions,
ghost promotions, correlation-window entries/exits, tuner retune
decisions, shard rebalance / live-resize steps, IO waits, and periodic
replay snapshot rows.  Pure cache hits never emit (ISSUE: hit-path-cheap
— hits are the line-rate path the paper optimizes).

A record is deliberately compact: parallel preallocated numpy columns
(seq, kind, shard, a, b int64; c float64) written by scalar stores — an
``emit`` is six array-cell assignments, no object allocation, no
formatting.  ``seq`` is a monotonic per-ring sequence number: total
events ever emitted is ``ring.n``, the ring retains the last
``capacity`` of them, and ``dropped = n - capacity`` tells a reader
exactly how much history wrapped away.

Like the metric registries, rings are lock-free within their owner (one
ring per shard / component) and merged only at snapshot time.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

# event kinds (int8 codes in the ring; names in exports)
EV_EVICT = 1            # a=key, b=1 main-clock victim / 0 small->ghost demote
EV_GHOST_PROMOTE = 2    # a=key   (ghost hit readmitted straight to main)
EV_WINDOW_ENTER = 3     # a=key   (inserted into the Small FIFO: window opens)
EV_WINDOW_EXIT = 4      # a=key, b=age  (first re-reference past the window)
EV_IO_WAIT = 5          # a=key   (access landed on a DOING-IO entry)
EV_RETUNE = 6           # a/b=window before/after (slots or 1e4*frac), c=gain
EV_REBALANCE = 7        # a/b=shard capacity before/after
EV_RESIZE = 8           # a/b=total capacity before/after (begin_resize)
EV_RESIZE_DONE = 9      # live-resize migration drained for this shard
EV_SNAPSHOT = 10        # a=accesses so far, b=hits so far, c=miss ratio

# fault-injection / recovery vocabulary (repro.faults).  These are the
# incident-timeline records: every injected fault, every retry, every
# degraded-mode flip, shard loss/rewarm, and state snapshot/restore
# emits exactly one event, so `tools/obsreport.py --incidents` can
# reconstruct what happened to a wounded cache from the ring alone.
EV_FAULT = 11           # a=fault kind code (faults.plan), b=key/op seq
EV_IO_RETRY = 12        # a=attempt number (1-based), b=backoff ticks
EV_IO_ERROR = 13        # a=key, b=attempts made (op gave up)
EV_DEGRADED = 14        # a=1 entered read-through / 0 recovered
EV_SHARD_LOST = 15      # shard=sid, a=resident entries lost
EV_SHARD_REWARM = 16    # shard=sid, a=residents readmitted, b=ghosts
EV_RESTORE = 17         # a=snapshot step restored, b=resident entries

# write-ahead journal / hot-standby replication vocabulary
# (repro.faults.journal / repro.faults.replica)
EV_JOURNAL_TRUNCATED = 22  # shard=sid, a=last durable LSN, b=torn bytes cut
EV_PROMOTE = 23            # shard=sid, a=journal records replayed, b=lag
                           # (LSNs the standby was behind at loss)

# serving-scheduler vocabulary (repro.serving.scheduler).  The scheduler
# runs on a virtual tick clock, so `shard` carries the tick the decision
# was made at — the events ARE the schedule, and the simulation-test
# harness asserts the stream is bit-identical per seed.
EV_ADMIT = 18           # shard=tick, a=req_id, b=priority class
EV_REJECT = 19          # shard=tick, a=req_id, b=reason code
EV_SHED = 20            # shard=tick, a=req_id, b=reason code
EV_BATCH = 21           # shard=tick, a=prefills, b=decodes, c=token budget used

EVENT_NAMES: Dict[int, str] = {
    EV_EVICT: "evict",
    EV_GHOST_PROMOTE: "ghost_promote",
    EV_WINDOW_ENTER: "window_enter",
    EV_WINDOW_EXIT: "window_exit",
    EV_IO_WAIT: "io_wait",
    EV_RETUNE: "retune",
    EV_REBALANCE: "rebalance",
    EV_RESIZE: "resize",
    EV_RESIZE_DONE: "resize_done",
    EV_SNAPSHOT: "snapshot",
    EV_FAULT: "fault_inject",
    EV_IO_RETRY: "io_retry",
    EV_IO_ERROR: "io_error",
    EV_DEGRADED: "degraded",
    EV_SHARD_LOST: "shard_lost",
    EV_SHARD_REWARM: "shard_rewarm",
    EV_RESTORE: "restore",
    EV_JOURNAL_TRUNCATED: "journal_truncated",
    EV_PROMOTE: "promote",
    EV_ADMIT: "admit",
    EV_REJECT: "reject",
    EV_SHED: "shed",
    EV_BATCH: "batch",
}

# the subset obsreport's --incidents view keeps: fault/recovery flow
# (plus scheduler load-shedding/rejection — the serving half of an
# incident timeline: a degraded flip is usually followed by sheds)
INCIDENT_KINDS = frozenset((
    "fault_inject", "io_retry", "io_error", "degraded", "shard_lost",
    "shard_rewarm", "restore", "rebalance", "resize", "resize_done",
    "shed", "reject", "journal_truncated", "promote",
))


class EventRing:
    """Preallocated ring of structured event records."""

    enabled = True

    def __init__(self, capacity: int = 4096, src: str = ""):
        if capacity < 1:
            raise ValueError("ring capacity must be >= 1")
        self.capacity = capacity
        self.src = src
        self.n = 0  # total emitted == next sequence number
        self._seq = np.zeros(capacity, np.int64)
        self._kind = np.zeros(capacity, np.int8)
        self._shard = np.zeros(capacity, np.int64)
        self._a = np.zeros(capacity, np.int64)
        self._b = np.zeros(capacity, np.int64)
        self._c = np.zeros(capacity, np.float64)

    def emit(self, kind: int, shard: int = -1, a: int = 0, b: int = 0,
             c: float = 0.0) -> None:
        i = self.n % self.capacity
        self._seq[i] = self.n
        self._kind[i] = kind
        self._shard[i] = shard
        self._a[i] = a
        self._b[i] = b
        self._c[i] = c
        self.n += 1

    @property
    def dropped(self) -> int:
        """Events that wrapped out of the ring."""
        return max(0, self.n - self.capacity)

    def records(self) -> List[dict]:
        """Retained events, oldest first, as plain dicts (export form)."""
        n_live = min(self.n, self.capacity)
        start = self.n - n_live
        out = []
        for s in range(start, self.n):
            i = s % self.capacity
            kind = int(self._kind[i])
            out.append(dict(seq=int(self._seq[i]), src=self.src,
                            kind=EVENT_NAMES.get(kind, str(kind)),
                            shard=int(self._shard[i]), a=int(self._a[i]),
                            b=int(self._b[i]), c=float(self._c[i])))
        return out


class NullRing(EventRing):
    """Event trace disabled: ``emit`` is a no-op, nothing is retained.
    The ``enabled`` flag lets instrumentation skip event-payload
    computation entirely (``if ring.enabled: ...``)."""

    enabled = False

    def __init__(self, src: str = ""):
        self.capacity = 0
        self.src = src
        self.n = 0

    def emit(self, kind: int, shard: int = -1, a: int = 0, b: int = 0,
             c: float = 0.0) -> None:
        return None

    @property
    def dropped(self) -> int:
        return 0

    def records(self) -> List[dict]:
        return []
