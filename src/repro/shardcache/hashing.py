"""Shard-selection hash, shared by the concurrent service and the JAX
engine's sharded-simulation mode (both must partition identically for the
fidelity comparisons to be apples-to-apples).

Deliberately a *different* mix than ``ProdClock2QPlus._h`` (the intra-shard
bucket hash) so shard id and bucket id are uncorrelated — a shared hash
would funnel each shard's keys into a subset of its buckets.
"""

from __future__ import annotations

import numpy as np

_MASK64 = 0xFFFFFFFFFFFFFFFF
_MUL = 0xD1B54A32D192ED03  # pseudo-golden-ratio multiplier (distinct from _h's)


def shard_of(key: int, n_shards: int) -> int:
    """Shard index for a scalar key."""
    x = (key * _MUL) & _MASK64
    x ^= x >> 29
    return (x >> 16) % n_shards


def shard_of_np(keys: np.ndarray, n_shards: int) -> np.ndarray:
    """Vectorized ``shard_of`` for a key array (int64 in, int64 out)."""
    x = (np.asarray(keys, dtype=np.uint64) * np.uint64(_MUL))
    x ^= x >> np.uint64(29)
    return ((x >> np.uint64(16)) % np.uint64(n_shards)).astype(np.int64)
