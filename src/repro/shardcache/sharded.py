"""``ShardedClock2QPlus`` — N hash-partitioned ``ProdClock2QPlus`` shards
behind one facade.

Concurrency model (the paper's multi-CPU story, §4/§5, adapted to a host
runtime): each shard owns its arrays and a lock; independent keys land on
independent shards, so threads contend only when they collide on a shard.
``access_many`` additionally amortizes dispatch: one vectorized hash
partition and one lock acquisition per shard per batch.

Capacity is elastic *across* shards: ``rebalance``/``set_shard_capacities``
move logical capacity from cold shards to hot ones using each shard's live
resize protocol (``begin_resize``/``resize_step``, §4.2) — no
stop-the-world rebuild, lookups stay correct mid-migration.

Payload handles are globalized as ``shard_idx * stride + local_block`` so
callers (e.g. ``repro.kvcache.pool.BlockPool``) can back all shards with
one flat block array.
"""

from __future__ import annotations

import math
import threading
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro import obs as obs_mod
from repro.core.prodcache import (
    EMPTY, AccessResult, ProdClock2QPlus, drive_resize,
)
from repro.obs import EV_REBALANCE, EV_RESIZE_DONE, FLOW_KINDS
from repro.shardcache.hashing import shard_of, shard_of_np

MIN_SHARD_CAP = 2


def apportion(weights: Sequence[float], total: int, lo: int, hi: int) -> List[int]:
    """Largest-remainder apportionment of ``total`` capacity over shards
    proportionally to ``weights``, with every share clamped to [lo, hi].
    Always returns shares summing exactly to ``total``.
    """
    n = len(weights)
    if total < n * lo or total > n * hi:
        raise ValueError(f"total {total} not representable with {n} shards "
                         f"in [{lo}, {hi}]")
    wsum = float(sum(weights)) or 1.0
    raw = [total * w / wsum for w in weights]
    shares = [min(hi, max(lo, int(math.floor(r)))) for r in raw]
    # distribute the remainder by largest fractional part, then fix any
    # clamp-induced imbalance greedily
    order = sorted(range(n), key=lambda i: raw[i] - math.floor(raw[i]),
                   reverse=True)
    deficit = total - sum(shares)
    i = 0
    while deficit != 0:
        s = order[i % n]
        if deficit > 0 and shares[s] < hi:
            shares[s] += 1
            deficit -= 1
        elif deficit < 0 and shares[s] > lo:
            shares[s] -= 1
            deficit += 1
        i += 1
        if i > 4 * n * (hi - lo + 1):  # bounds guarantee termination above
            raise RuntimeError("apportion failed to converge")
    return shares


class ShardedClock2QPlus:
    """Hash-sharded Clock2Q+ cache service (thread-safe facade)."""

    # the registered lane engine that simulates each shard (OnlineTuner)
    engine_policy = "clock2q+"

    def __init__(self, capacity: int, n_shards: int = 4, *,
                 small_frac: float = 0.1, ghost_frac: float = 0.5,
                 window_frac: float = 0.5, skip_limit=None,
                 dirty_scan_limit: int = 16, max_capacity: int = 0,
                 track_io: bool = False, rebalance_headroom: float = 2.0,
                 max_small_frac: float = 0.0, max_ghost_frac: float = 0.0,
                 min_small_frac: float = 1.0, obs=None):
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        if capacity < n_shards * MIN_SHARD_CAP:
            raise ValueError(
                f"capacity {capacity} too small for {n_shards} shards "
                f"(need >= {n_shards * MIN_SHARD_CAP})")
        self.n_shards = n_shards
        self.capacity = capacity
        total_max = max(capacity, max_capacity or capacity)
        self.max_capacity = total_max
        # Uniform per-shard preallocation (=> uniform block stride) with
        # headroom so a hot shard can grow past its even share.
        share = -(-total_max // n_shards)  # ceil
        self.shard_max = max(MIN_SHARD_CAP + 1,
                             int(math.ceil(share * rebalance_headroom)))
        caps = apportion([1.0] * n_shards, capacity,
                         MIN_SHARD_CAP, self.shard_max)
        # facade-level sink: cross-shard events (rebalance decisions,
        # migration completions).  Each shard builds its OWN sink (lock-
        # free within the shard lock) labeled shard=i; obs_snapshot()
        # merges them all.  Passing obs=NullSink() nulls the facade AND
        # every shard.
        self.obs = obs_mod.ObsSink(src="shardcache") if obs is None else obs
        mk_shard_obs = (obs_mod.NullSink if getattr(self.obs, "null", False)
                        else obs_mod.ObsSink)
        self.shards: List[ProdClock2QPlus] = [
            ProdClock2QPlus(c, small_frac=small_frac, ghost_frac=ghost_frac,
                            window_frac=window_frac, skip_limit=skip_limit,
                            dirty_scan_limit=dirty_scan_limit,
                            max_capacity=self.shard_max, track_io=track_io,
                            max_small_frac=max_small_frac,
                            max_ghost_frac=max_ghost_frac,
                            min_small_frac=min_small_frac, shard_id=i,
                            obs=mk_shard_obs(src=f"cache/shard{i}",
                                             labels={"shard": str(i)}))
            for i, c in enumerate(caps)]
        self.locks = [threading.Lock() for _ in range(n_shards)]
        self.stride = self.shards[0].max_small + self.shards[0].max_main
        self._resizing: set[int] = set()
        self._resize_lock = threading.Lock()  # guards _resizing itself
        # serializes capacity retargeting end-to-end: concurrent
        # rebalance()/set_shard_capacities() would otherwise interleave
        # per-shard begin_resize calls and leave targets that overcommit
        # the total budget (RLock: rebalance -> set_shard_capacities)
        self._mutate_lock = threading.RLock()
        self._miss_mark = [0] * n_shards  # miss counts at last rebalance

    # -- routing -----------------------------------------------------------------
    def shard_of(self, key: int) -> int:
        return shard_of(key, self.n_shards)

    def _globalize(self, sid: int, r: AccessResult) -> AccessResult:
        base = sid * self.stride
        if r.block != EMPTY:
            r.block += base
        if r.evicted_block != EMPTY:
            r.evicted_block += base
        return r

    # -- access ------------------------------------------------------------------
    def access(self, key: int, dirty: bool = False,
               pin: bool = False) -> AccessResult:
        sid = shard_of(key, self.n_shards)
        with self.locks[sid]:
            return self._globalize(sid, self.shards[sid].access(
                key, dirty=dirty, pin=pin))

    def access_many(self, keys, dirty: bool = False) -> np.ndarray:
        """Batched access: partition ``keys`` by shard (vectorized), then
        replay each shard's group under one lock acquisition.  Returns a
        bool hit array aligned with the input order.

        Within a shard the input order is preserved; *across* shards the
        interleaving is relaxed to per-shard runs — the Multi-step-LRU
        trade (PAPERS.md): per-access global ordering for dispatch
        throughput.  Keys on different shards never interact, so the only
        semantic delta vs. serial replay is the timestamp skew between
        shards inside one batch.

        Batched replay returns no payload handles, so on a ``track_io``
        cache the fill obligation of each miss is completed inline —
        otherwise the entries this batch admits would stay DOING-IO
        forever (unevictable) with no caller able to ``io_done`` them.
        In-flight entries admitted by ``access()`` callers are untouched.
        """
        keys = np.asarray(keys, dtype=np.int64)
        hits = np.zeros(keys.shape[0], dtype=bool)
        if keys.size == 0:
            return hits
        sid = shard_of_np(keys, self.n_shards)
        for s in range(self.n_shards):
            idx = np.nonzero(sid == s)[0]
            if idx.size == 0:
                continue
            shard = self.shards[s]
            group = keys[idx].tolist()
            with self.locks[s]:
                acc = shard.access
                track_io = shard.track_io
                for j, k in zip(idx.tolist(), group):
                    hit = acc(k, dirty=dirty).hit
                    hits[j] = hit
                    if track_io and not hit:
                        shard.io_done(k)
        return hits

    # -- per-key maintenance ops (routed) -----------------------------------------
    def _routed(self, key: int):
        sid = shard_of(key, self.n_shards)
        return sid, self.shards[sid], self.locks[sid]

    def io_done(self, key: int) -> None:
        _, sh, lk = self._routed(key)
        with lk:
            sh.io_done(key)

    def unpin(self, key: int) -> None:
        _, sh, lk = self._routed(key)
        with lk:
            sh.unpin(key)

    def clean(self, key: int) -> None:
        _, sh, lk = self._routed(key)
        with lk:
            sh.clean(key)

    def set_dirty(self, key: int) -> None:
        _, sh, lk = self._routed(key)
        with lk:
            sh.set_dirty(key)

    def contains(self, key: int) -> bool:
        _, sh, lk = self._routed(key)
        with lk:
            return sh.contains(key)

    def slot_of(self, key: int) -> int:
        """Global payload slot of a resident key, or EMPTY."""
        sid, sh, lk = self._routed(key)
        with lk:
            local = sh.slot_of(key)
        return EMPTY if local == EMPTY else sid * self.stride + local

    # -- aggregated views ----------------------------------------------------------
    @property
    def n_slots(self) -> int:
        """Size of the global payload-handle space."""
        return self.n_shards * self.stride

    @property
    def hits(self) -> int:
        return sum(s.hits for s in self.shards)

    @property
    def misses(self) -> int:
        return sum(s.misses for s in self.shards)

    @property
    def io_waits(self) -> int:
        return sum(s.io_waits for s in self.shards)

    @property
    def flows(self) -> Dict[str, int]:
        """Aggregate queue-transition counters.  Derived from the same
        ``cache_flow_total`` obs family and canonical ``obs.FLOW_KINDS``
        order as each shard's ``flows`` — the aggregate and single-shard
        key sets are the same schema by construction."""
        agg = {k: 0 for k in FLOW_KINDS}
        for s in self.shards:
            for k, c in s._c_flow.items():
                agg[k] += c.value
        return agg

    def obs_snapshot(self) -> "obs_mod.Snapshot":
        """Point-in-time merged telemetry: every shard's counters/
        gauges/histograms under its ``shard`` label plus the facade's
        rebalance/resize events."""
        return obs_mod.merge([self.obs.snapshot()]
                             + [s.obs.snapshot() for s in self.shards])

    @property
    def hit_ratio(self) -> float:
        h, m = self.hits, self.misses
        return h / max(1, h + m)

    def __len__(self) -> int:
        return sum(len(s) for s in self.shards)

    def __contains__(self, key: int) -> bool:
        return self.contains(key)

    def dirty_keys(self) -> List[int]:
        out: List[int] = []
        for s, lk in zip(self.shards, self.locks):
            with lk:
                out.extend(s.dirty_keys())
        return out

    @property
    def shard_capacities(self) -> List[int]:
        return [s.capacity for s in self.shards]

    def shard_stats(self) -> List[Dict[str, int]]:
        """Per-shard occupancy/traffic snapshot (for rebalancing + benches)."""
        return [dict(shard=i, capacity=s.capacity, resident=len(s),
                     hits=s.hits, misses=s.misses)
                for i, s in enumerate(self.shards)]

    # -- shard failover (repro.faults.recovery) --------------------------------------
    def lose_shard(self, sid: int) -> "ProdClock2QPlus":
        """Simulate crash-loss of shard ``sid``: its entire state (resident
        entries, ghost ring, counters, pending resize) vanishes and a fresh
        empty shard with IDENTICAL preallocation takes its place, so every
        global payload handle keeps meaning ``sid * stride + local``.

        The replacement inherits the lost shard's logical capacity and
        current tuning fractions, its rebalance miss mark is zeroed (its
        counters restart from zero — a stale mark would make the next
        miss-delta negative), and any in-flight resize tracking for the
        shard is dropped.  Emits ``EV_SHARD_LOST`` with the resident count
        lost.  Returns the dead shard (post-mortem inspection only — its
        payload handles are no longer valid).

        ``repro.faults.recovery.failover`` builds on this: lose, rewarm
        from the ghost journal, rejoin rebalancing.
        """
        if not (0 <= sid < self.n_shards):
            raise ValueError(f"no shard {sid}")
        with self._mutate_lock, self.locks[sid]:
            old = self.shards[sid]
            lost = len(old)
            mc = self.shard_max
            fresh = ProdClock2QPlus(
                old.capacity, small_frac=old._small_frac,
                ghost_frac=old._ghost_frac, window_frac=old._window_frac,
                skip_limit=old.skip_limit,
                dirty_scan_limit=old.dirty_scan_limit, max_capacity=mc,
                track_io=old.track_io,
                max_small_frac=old.max_small / mc,
                max_ghost_frac=old.max_ghost / mc,
                min_small_frac=(mc - old.max_main) / mc, shard_id=sid,
                obs=type(old.obs)(src=f"cache/shard{sid}",
                                  labels={"shard": str(sid)}))
            if (fresh.max_small, fresh.max_main, fresh.max_ghost) != \
                    (old.max_small, old.max_main, old.max_ghost):
                raise RuntimeError(
                    "replacement shard preallocation mismatch: "
                    f"{(fresh.max_small, fresh.max_main, fresh.max_ghost)}"
                    f" != {(old.max_small, old.max_main, old.max_ghost)}")
            self.shards[sid] = fresh
            self._miss_mark[sid] = 0
            with self._resize_lock:
                self._resizing.discard(sid)
        if self.obs.ring.enabled:
            self.obs.emit(obs_mod.EV_SHARD_LOST, shard=sid, a=lost)
        return old

    # -- cross-shard capacity rebalancing -------------------------------------------
    def set_shard_capacities(self, caps: Sequence[int],
                             steps_per_call: int = 64,
                             complete: bool = True) -> None:
        """Retarget per-shard capacities (must sum to ``self.capacity``).
        Shrinking shards release capacity via their live-resize protocol;
        with ``complete=False`` the migration is left to ``rebalance_step``
        (the background-thread analogue).

        ``complete=True`` drives all *migratable* work to completion and
        then returns: entries pinned or DOING-IO beyond a new boundary
        cannot be drained until released, so their shards simply stay
        pending (later ``rebalance_step`` calls finish them) rather than
        spinning — the release call may be waiting on this very thread."""
        caps = list(caps)
        if len(caps) != self.n_shards:
            raise ValueError("need one capacity per shard")
        for c in caps:
            if not (MIN_SHARD_CAP <= c <= self.shard_max):
                raise ValueError(f"shard capacity {c} not in "
                                 f"[{MIN_SHARD_CAP}, {self.shard_max}]")
        with self._mutate_lock:
            # the sum check must sit inside the lock: a concurrent
            # begin_resize may move self.capacity between check and apply
            if sum(caps) != self.capacity:
                raise ValueError(
                    f"shard capacities must sum to {self.capacity}")
            for i, (s, c) in enumerate(zip(self.shards, caps)):
                if s.capacity != c:
                    if self.obs.ring.enabled:
                        self.obs.emit(EV_REBALANCE, shard=i,
                                      a=s.capacity, b=c)
                    with self.locks[i]:
                        # begin_resize finishes any pending HASH migration
                        # itself (bounded pointer work); the out-of-bounds
                        # drain — which pinned/DOING-IO entries CAN block —
                        # simply continues under the new targets, so no
                        # spin-wait is needed and unpin/io_done from other
                        # threads can never be deadlocked out
                        s.begin_resize(c)
                    with self._resize_lock:
                        self._resizing.add(i)
            if complete:
                drive_resize(self, steps_per_call)

    def rehash_pending(self) -> bool:
        with self._resize_lock:
            pending = sorted(self._resizing)
        return any(self.shards[i].rehash_pending() for i in pending)

    def undrained_count(self) -> int:
        """Resident entries beyond pending shards' logical boundaries."""
        with self._resize_lock:
            pending = sorted(self._resizing)
        n = 0
        for i in pending:
            with self.locks[i]:
                n += self.shards[i].undrained_count()
        return n

    def rebalance_step(self, n_entries: int = 64) -> bool:
        """Advance pending shard resizes; True when all migrations done."""
        with self._resize_lock:
            pending = sorted(self._resizing)
        done = True
        for i in pending:
            # the discard must happen under the same shard-lock hold as
            # the completion check: a concurrent retarget (which also
            # takes locks[i] for its begin_resize) could otherwise re-add
            # i between our check and discard, and the discard would
            # permanently untrack the NEW migration
            with self.locks[i]:
                finished = self.shards[i].resize_step(n_entries)
                if finished:
                    with self._resize_lock:
                        self._resizing.discard(i)
            if finished:
                if self.obs.ring.enabled:
                    self.obs.emit(EV_RESIZE_DONE, shard=i)
            else:
                done = False
        return done

    def rebalance(self, steps_per_call: int = 64,
                  complete: bool = True) -> List[int]:
        """Miss-driven rebalance: shards that missed more since the last
        rebalance get proportionally more capacity (hot shards borrow from
        cold ones).  Returns the new per-shard capacity targets."""
        with self._mutate_lock:
            deltas = [s.misses - m
                      for s, m in zip(self.shards, self._miss_mark)]
            self._miss_mark = [s.misses for s in self.shards]
            weights = [d + 1.0 for d in deltas]  # +1: never starve a shard
            caps = apportion(weights, self.capacity, MIN_SHARD_CAP,
                             self.shard_max)
            self.set_shard_capacities(caps, steps_per_call=steps_per_call,
                                      complete=complete)
            return caps

    # -- runtime tuning (OnlineTuner hook) ------------------------------------------
    @property
    def tuning(self) -> Dict[str, float]:
        """Current tuning knobs (uniform across shards by construction;
        ``retune`` retargets every shard with the same values)."""
        return self.shards[0].tuning

    def retune(self, *, small_frac: Optional[float] = None,
               ghost_frac: Optional[float] = None,
               window_frac: Optional[float] = None,
               steps_per_call: int = 64, complete: bool = True) -> None:
        """Apply one tuning decision (made from AGGREGATED stats — the
        shards all serve slices of the same workload) to every shard via
        each shard's live-resize protocol.  Like ``set_shard_capacities``,
        ``complete=True`` drives all migratable work and leaves shards
        with pinned/DOING-IO strays pending for ``rebalance_step``."""
        with self._mutate_lock:
            for i, s in enumerate(self.shards):
                with self.locks[i]:
                    s.retune(small_frac=small_frac, ghost_frac=ghost_frac,
                             window_frac=window_frac)
                with self._resize_lock:
                    self._resizing.add(i)
            if complete:
                drive_resize(self, steps_per_call)

    # -- whole-service resize (BlockPool compatibility) -----------------------------
    def begin_resize(self, new_capacity: int) -> None:
        """Retarget the TOTAL capacity, split proportionally to current
        shard capacities (so prior rebalancing decisions persist)."""
        if not (self.n_shards * MIN_SHARD_CAP <= new_capacity
                <= self.n_shards * self.shard_max):
            raise ValueError(f"total capacity {new_capacity} out of range")
        with self._mutate_lock:
            weights = [float(s.capacity) for s in self.shards]
            self.capacity = new_capacity
            caps = apportion(weights, new_capacity, MIN_SHARD_CAP,
                             self.shard_max)
            self.set_shard_capacities(caps, complete=False)

    def resize_step(self, n_entries: int = 64) -> bool:
        return self.rebalance_step(n_entries)
