"""Multi-threaded replay harness — the paper's multi-CPU scalability
experiment (§5) against ``ShardedClock2QPlus``.

The trace is cut into contiguous batches; worker ``t`` of ``T`` owns
batches ``t, t+T, t+2T, ...`` (static round-robin: zero coordination on
the hot path, deterministic ownership).  Each worker replays its batches
with ``access_many``, so lock traffic is one acquisition per (batch,
shard) pair.  Reported throughput is wall-clock real: it includes lock
contention, shard imbalance, and Python dispatch — exactly what the
paper's scalability figure measures on real CPUs.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Iterable, List, Optional

import numpy as np

from repro.core.prodcache import ProdClock2QPlus
from repro.obs import EV_SNAPSHOT
from repro.shardcache.sharded import ShardedClock2QPlus


def unsharded_miss_ratio(trace, capacity: int, **kw) -> float:
    """Serial ProdClock2QPlus replay — the baseline the sharded service's
    fidelity is measured against (benchmarks and parity tests share it)."""
    pol = ProdClock2QPlus(capacity, **kw)
    acc = pol.access
    for k in np.asarray(trace).tolist():
        acc(k)
    return pol.misses / max(1, pol.hits + pol.misses)


def lane_miss_ratio(trace, capacity: int, *, policy: str = "clock2q+",
                    universe: Optional[int] = None, **kw) -> float:
    """The JAX-lane counterpart of ``unsharded_miss_ratio``: replay
    through the registered masked engine (``repro.core.engine``) instead
    of the Python service.  Keys must be dense ids in [0, universe).
    Used to cross-check the threaded service against the lane zoo for
    ANY registered policy, not just Clock2Q+."""
    from repro.core.engine import get_engine

    trace = np.asarray(trace)
    if universe is None:
        universe = int(trace.max()) + 1
    eng = get_engine(policy)
    st = eng.init(capacity, int(universe), **kw)
    _, hits = eng.replay(st, np.asarray(trace, np.int32))
    h = int(np.asarray(hits).sum())
    return 1.0 - h / max(1, trace.size)


@dataclasses.dataclass
class ReplayReport:
    n_threads: int
    n_shards: int
    n_requests: int
    seconds: float
    hits: int

    @property
    def throughput(self) -> float:
        """Requests per wall-second."""
        return self.n_requests / max(1e-12, self.seconds)

    @property
    def us_per_access(self) -> float:
        return 1e6 * self.seconds / max(1, self.n_requests)

    @property
    def miss_ratio(self) -> float:
        return 1.0 - self.hits / max(1, self.n_requests)


def replay_threaded(cache: ShardedClock2QPlus, trace: np.ndarray,
                    n_threads: int = 1, batch_size: int = 1024,
                    obs=None) -> ReplayReport:
    """Replay ``trace`` through ``cache`` with ``n_threads`` workers.

    With an ``obs`` sink, each worker observes its per-batch dispatch
    latency into a thread-labeled histogram (per-thread instruments —
    lock-free, merged at snapshot time like per-shard registries)."""
    trace = np.asarray(trace, dtype=np.int64)
    n = trace.shape[0]
    batches = [trace[i:i + batch_size] for i in range(0, n, batch_size)]
    hit_counts = [0] * n_threads
    # per-thread instruments, created BEFORE the workers start (family
    # get-or-create is not thread-safe; binding is, by construction)
    hists = [None] * n_threads
    if obs is not None:
        fam = obs.histogram("replay_batch_seconds", ("thread",),
                            "access_many dispatch latency per batch")
        hists = [fam.labels(str(t)) for t in range(n_threads)]

    def worker(t: int) -> None:
        total = 0
        hist = hists[t]
        for b in range(t, len(batches), n_threads):
            if hist is None:
                total += int(cache.access_many(batches[b]).sum())
            else:
                tb = time.perf_counter()
                total += int(cache.access_many(batches[b]).sum())
                hist.observe(time.perf_counter() - tb)
        hit_counts[t] = total

    t0 = time.perf_counter()
    if n_threads == 1:
        worker(0)
    else:
        threads = [threading.Thread(target=worker, args=(t,))
                   for t in range(n_threads)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
    dt = time.perf_counter() - t0
    return ReplayReport(n_threads=n_threads, n_shards=cache.n_shards,
                        n_requests=n, seconds=dt, hits=sum(hit_counts))


def replay_store(cache: ShardedClock2QPlus, store, *, n_threads: int = 1,
                 batch_size: int = 1024, chunk_size: int = 1 << 20,
                 obs=None) -> ReplayReport:
    """Chunked state-carry replay of an on-disk trace (``TraceStore``,
    ndarray, or any iterable of key chunks) through a sharded cache.

    The cache is stateful, so feeding chunks sequentially IS the
    state-carry; and because ``access_many`` preserves per-shard request
    order regardless of batch boundaries (shards are independent),
    single-threaded streaming is bit-identical to a single-shot
    ``replay_threaded`` of the whole trace, for any chunk_size (asserted
    in tests/test_chunked.py).  With ``n_threads > 1`` the harness's
    relaxed cross-batch ordering applies exactly as in the single-shot
    path: workers race on per-shard order across batches, so hit counts
    can drift by a few per million vs serial — a property of threaded
    replay itself, not of chunking.  Peak memory holds one chunk.

    With an ``obs`` sink, the driver emits one periodic snapshot row per
    chunk — an ``EV_SNAPSHOT`` event (accesses, hits, running miss
    ratio) plus progress gauges — and the per-thread batch-latency
    histograms of ``replay_threaded``, so a long stream leaves a
    scrapeable progress trail instead of one end-of-run number."""
    from repro.traceio.store import iter_chunks

    g_n = g_mr = None
    if obs is not None:
        g_n = obs.gauge("replay_accesses", (),
                        "accesses replayed so far").labels()
        g_mr = obs.gauge("replay_miss_ratio", (),
                         "running miss ratio").labels()
    hits = 0
    n = 0
    seconds = 0.0
    for chunk in iter_chunks(store, chunk_size):
        rep = replay_threaded(cache, chunk, n_threads=n_threads,
                              batch_size=batch_size, obs=obs)
        hits += rep.hits
        n += rep.n_requests
        seconds += rep.seconds
        if obs is not None:
            mr = 1.0 - hits / max(1, n)
            g_n.set(float(n))
            g_mr.set(mr)
            obs.emit(EV_SNAPSHOT, a=n, b=hits, c=mr)
    return ReplayReport(n_threads=n_threads, n_shards=cache.n_shards,
                        n_requests=n, seconds=seconds, hits=hits)


def scalability_sweep(trace: np.ndarray, capacity: int, *,
                      n_shards: int = 8,
                      threads: Iterable[int] = (1, 2, 4, 8),
                      batch_size: int = 1024,
                      cache_kw: Optional[dict] = None) -> List[ReplayReport]:
    """Fresh cache per thread count (equal-work comparison), matching the
    paper's per-core-count runs."""
    out = []
    for t in threads:
        cache = ShardedClock2QPlus(capacity, n_shards=n_shards,
                                   **(cache_kw or {}))
        out.append(replay_threaded(cache, trace, n_threads=t,
                                   batch_size=batch_size))
    return out
