"""Sharded concurrent cache service — the multi-CPU scalability subsystem.

The paper's Clock2Q+ "scales efficiently to multiple CPUs" (§4, §5) by
keeping the hot path short and lock hold times small.  This package is
the repo's counterpart: N hash-partitioned ``ProdClock2QPlus`` shards
behind one facade (``ShardedClock2QPlus``), with

  * ``access_many`` — batched dispatch that groups keys by shard and
    amortizes per-request overhead (the Multi-step-LRU playbook: trade
    per-access global ordering for throughput under parallelism),
  * per-shard locks + a multi-threaded replay harness
    (``repro.shardcache.replay``) that measures real throughput scaling,
  * cross-shard capacity rebalancing built on the live-resize protocol
    (§4.2): hot shards borrow capacity from cold ones without a stop-the-
    world rebuild,
  * aggregated stats/flows across shards.
"""

from repro.shardcache.hashing import shard_of, shard_of_np  # noqa: F401
from repro.shardcache.sharded import ShardedClock2QPlus  # noqa: F401
from repro.shardcache.replay import (  # noqa: F401
    ReplayReport, replay_threaded, scalability_sweep, unsharded_miss_ratio,
)
