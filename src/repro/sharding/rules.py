"""Sharding-rule engine: maps every parameter / activation / cache leaf to
a PartitionSpec, with divisibility fallback (a dim that does not divide the
mesh axis is replicated and the decision is recorded).

Logical policy (DESIGN.md §6):
  * batch dims        -> ("pod", "data") [multi-pod] or ("data",)
  * TP ("model")      -> attention head projections, MLP hidden, expert
                         axis of MoE weights, mamba d_inner, vocab.
  * sequence dim of decode KV caches -> "model" (long caches divide
    across the pod without replicating GQA heads).
  * ZeRO-1: optimizer moments additionally sharded over "data" on the
    first free divisible dim.
  * 1T-class MoE: expert FFN dim additionally sharded over "data"
    (2-D expert sharding) so per-device weights fit HBM.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.config import ModelConfig

# params above this count get expert-FFN FSDP over "data"
FSDP_EXPERT_THRESHOLD = 100_000_000_000


class RuleLog:
    """Records divisibility fallbacks for DESIGN.md / debugging."""

    def __init__(self):
        self.fallbacks: List[str] = []

    def note(self, path: str, dim: int, size: int, axis: str, n: int):
        self.fallbacks.append(
            f"{path} dim{dim}={size} not divisible by {axis}({n}): replicated")


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _fit(mesh: Mesh, path: str, shape: Tuple[int, ...], logical,
         log: Optional[RuleLog]) -> P:
    """Drop axes that do not divide their dim."""
    out = []
    for d, ax in enumerate(logical):
        if ax is None:
            out.append(None)
            continue
        n = _axis_size(mesh, ax)
        if shape[d] % n == 0:
            out.append(ax)
        else:
            if log is not None:
                log.note(path, d, shape[d], str(ax), n)
            out.append(None)
    return P(*out)


def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

def _param_logical(cfg: ModelConfig, path: str, ndim: int,
                   shape: Tuple[int, ...], mesh: Mesh) -> Tuple:
    mp = "model"
    leaf = path.split("/")[-1]
    stacked = path.startswith(("blocks/", "mamba/", "enc/", "dec/"))
    off = 1 if stacked else 0  # leading layer-stack dim

    def L(*spec):
        return (None,) * off + spec

    fsdp_ff = (cfg.family == "moe"
               and cfg.n_params() > FSDP_EXPERT_THRESHOLD)

    if leaf in ("tok",):                       # (V, D)
        return (mp, None)
    if leaf in ("lm_head", "mm_proj"):         # (D, V) / (D, D)
        return (None, mp)
    if leaf in ("w", "b", "_"):                # norms
        return (None,) * ndim
    if leaf in ("wq", "wk", "wv"):             # (D, H*hd)
        nh = cfg.n_kv_heads if leaf in ("wk", "wv") else cfg.n_heads
        n = _axis_size(mesh, mp)
        if nh % n == 0:
            return L(None, mp)
        return L(None, None)                   # replicate (GQA kv < mesh)
    if leaf == "wo":                           # (H*hd, D)
        n = _axis_size(mesh, mp)
        return L(mp, None) if cfg.n_heads % n == 0 else L(None, None)
    if leaf in ("bq", "bk", "bv"):
        return L(None)
    if leaf in ("w_gate", "w_up", "w_down"):
        if ndim - off == 3:                    # MoE experts (E, D, F)/(E, F, D)
            ff_ax = "data" if fsdp_ff else None
            if leaf == "w_down":
                return L(mp, ff_ax, None)
            return L(mp, None, ff_ax)
        if leaf == "w_down":                   # (F, D)
            return L(mp, None)
        return L(None, mp)                     # (D, F)
    if leaf == "router":                       # (D, E)
        return L(None, None)
    # mamba1 / mamba2
    if leaf in ("in_proj", "zx_proj"):         # (D, 2*din)
        return L(None, mp)
    if leaf in ("bc_proj", "dtp", "x_proj"):   # small projections
        return L(None, None) if leaf != "x_proj" else L(mp, None)
    if leaf == "dt_proj":                      # (R, din)
        return L(None, mp)
    if leaf == "conv_w":                       # (K, din)
        return L(None, mp)
    if leaf in ("conv_b", "dt_bias", "Dskip"): # (din,) or (nh,)
        dim = shape[-1]
        n = _axis_size(mesh, mp)
        return L(mp) if dim % n == 0 and dim >= n else L(None)
    if leaf == "A_log":
        if ndim - off == 2:                    # mamba1 (din, N)
            return L(mp, None)
        return L(None)                         # mamba2 (nh,)
    if leaf == "out_proj":                     # (din, D)
        return L(mp, None)
    return (None,) * ndim


def _tree_paths(tree: Any, prefix: str = "") -> List[Tuple[str, Any]]:
    out = []
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.extend(_tree_paths(tree[k], f"{prefix}{k}/"))
    elif hasattr(tree, "_fields"):  # NamedTuple
        for k in tree._fields:
            out.extend(_tree_paths(getattr(tree, k), f"{prefix}{k}/"))
    else:
        out.append((prefix[:-1], tree))
    return out


def param_specs(cfg: ModelConfig, mesh: Mesh, params_shape: Any,
                log: Optional[RuleLog] = None) -> Any:
    """params_shape: pytree of ShapeDtypeStruct (from jax.eval_shape)."""

    def build(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: build(v, f"{prefix}{k}/") for k, v in tree.items()}
        path = prefix[:-1]
        logical = _param_logical(cfg, path, len(tree.shape), tree.shape, mesh)
        return _fit(mesh, path, tree.shape, logical, log)

    return build(params_shape)


def opt_state_specs(cfg: ModelConfig, mesh: Mesh, params_shape: Any,
                    pspecs: Any, log: Optional[RuleLog] = None) -> Any:
    """ZeRO-1: moments get "data" added on the first free divisible dim."""
    n_data = _axis_size(mesh, "data")

    def build(shape_leaf, spec: P):
        spec_t = tuple(spec) + (None,) * (len(shape_leaf.shape) - len(tuple(spec)))
        used = set()
        for ax in spec_t:
            if isinstance(ax, tuple):
                used.update(ax)
            elif ax is not None:
                used.add(ax)
        if "data" in used:  # e.g. 2-D expert sharding already uses it
            return P(*spec_t)
        out = list(spec_t)
        for d, ax in enumerate(spec_t):
            if ax is None and shape_leaf.shape[d] % n_data == 0 \
                    and shape_leaf.shape[d] >= n_data:
                out[d] = "data"
                break
        return P(*out)

    return jax.tree.map(build, params_shape, pspecs)


# ---------------------------------------------------------------------------
# activation / batch / cache rules
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, mesh: Mesh, batch_shapes: Dict[str, tuple],
                log: Optional[RuleLog] = None) -> Dict[str, P]:
    """Shard batch dims over ("pod","data"); everything else replicated."""
    bax = batch_axes(mesh)
    out = {}
    for name, (shape, _) in batch_shapes.items():
        logical = (bax,) + (None,) * (len(shape) - 1)
        out[name] = _fit(mesh, f"batch/{name}", shape, logical, log)
    return out


def cache_specs(cfg: ModelConfig, mesh: Mesh, cache_shape: Any,
                log: Optional[RuleLog] = None) -> Any:
    """KV caches: (L, B, S, H_kv, hd) -> (None, batch, "model", None, None);
    SSM states: shard d_inner / heads over "model"."""
    bax = batch_axes(mesh)

    def build(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: build(v, f"{prefix}{k}/") for k, v in tree.items()}
        if hasattr(tree, "_fields"):
            return type(tree)(*[build(getattr(tree, k), f"{prefix}{k}/")
                                for k in tree._fields])
        path = prefix[:-1]
        shape = tree.shape
        leaf = path.split("/")[-1]
        if leaf in ("bk", "bv") and len(shape) == 5:
            # decode append ring (hillclimb 1b): replicated along S
            logical = (None, bax, None, None, None)
        elif leaf in ("k", "v", "xk", "xv") and len(shape) == 5:
            # shard the sequence dim: (L,B,S,H,hd) or head-major
            # (L,B,H,S,hd) — S is the larger of dims 2/3
            if shape[3] > shape[2]:
                logical = (None, bax, None, "model", None)
            else:
                logical = (None, bax, "model", None, None)
        elif leaf == "conv":                      # (L, B, K-1, din)
            logical = (None, bax, None, "model")
        elif leaf == "ssm":
            if len(shape) == 4:                   # mamba1 (L, B, din, N)
                logical = (None, bax, "model", None)
            else:                                 # mamba2 (L, B, nh, N, P)
                logical = (None, bax, "model", None, None)
        elif leaf == "length" or len(shape) == 0:
            return P()
        else:
            logical = (None,) * len(shape)
        return _fit(mesh, path, shape, logical, log)

    return build(cache_shape)
