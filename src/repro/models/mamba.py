"""Mamba1 (falcon-mamba) and Mamba2/SSD (zamba2 hybrid) blocks.

Chunked formulations keep the (B, S, d_inner, N) state tensors bounded:
full sequences are processed chunk-by-chunk with ``lax.scan`` carrying the
recurrent state across chunks; inside a chunk Mamba1 uses an associative
scan and Mamba2 the quadratic-within-chunk SSD form.  Decode is the O(1)
recurrence.

Simplifications vs the reference implementations (noted in DESIGN.md):
falcon-mamba's extra RMS norms on B/C/dt are folded away; mamba2's short
conv is applied to the x branch only; n_groups = 1.
"""

from __future__ import annotations

from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig


class SSMCache(NamedTuple):
    conv: jnp.ndarray   # (L, B, K-1, d_inner[ +2N for mamba2])
    ssm: jnp.ndarray    # (L, B, d_inner, N) | (L, B, nh, hd, N)


# =============================================================================
# Mamba1
# =============================================================================

def mamba1_params(cfg: ModelConfig, rng) -> Dict:
    D, din, N, R, K = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                       cfg.dt_rank_, cfg.ssm_conv)
    pd = L.pdtype_of(cfg)
    ks = jax.random.split(rng, 6)
    return {
        "ln": L.norm_params(cfg, ks[0]),
        "in_proj": L.dense_init(ks[1], (D, 2 * din), pd),
        "conv_w": L.dense_init(ks[2], (K, din), pd, scale=1.0),
        "conv_b": jnp.zeros((din,), pd),
        "x_proj": L.dense_init(ks[3], (din, R + 2 * N), pd),
        "dt_proj": L.dense_init(ks[4], (R, din), pd),
        "dt_bias": jnp.full((din,), -4.6, pd),  # softplus^-1(0.01)
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, N + 1, dtype=jnp.float32), (din, N))).astype(jnp.float32),
        "Dskip": jnp.ones((din,), pd),
        "out_proj": L.dense_init(ks[5], (din, D), pd),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: jnp.ndarray | None = None) -> jnp.ndarray:
    """Depthwise causal conv; x: (B, S, C), w: (K, C).  ``state``: (B, K-1, C)
    left context (decode), else zero-padded."""
    K = w.shape[0]
    left = state if state is not None else jnp.zeros(
        (x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([left, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    return out + b


def mamba1_full(cfg: ModelConfig, p: Dict, x: jnp.ndarray,
                return_state: bool = False):
    """x: (B, S, D) -> (B, S, D). Chunked selective scan.
    ``return_state``: also return (conv_state, ssm_state) for decode."""
    from repro.models.opt_flags import FLAGS
    B, S, D = x.shape
    din, N, R = cfg.d_inner, cfg.ssm_state, cfg.dt_rank_
    C = FLAGS.mamba_chunk_override or cfg.ssm_chunk
    scan_dt = jnp.bfloat16 if FLAGS.mamba_bf16_scan else jnp.float32
    h0 = jnp.zeros((B, din, N), jnp.float32)

    res = L.rmsnorm(x, p["ln"]["w"]) if cfg.norm == "rmsnorm" else x
    xz = jnp.einsum("bsd,de->bse", res, p["in_proj"])
    xin, z = jnp.split(xz, 2, axis=-1)
    u = jax.nn.silu(_causal_conv(xin, p["conv_w"], p["conv_b"])
                    .astype(jnp.float32)).astype(x.dtype)

    dbc = jnp.einsum("bsi,ie->bse", u, p["x_proj"])
    dt_r, Bc, Cc = jnp.split(dbc, [R, R + N], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dt_r, p["dt_proj"]).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))                       # (B,S,din)
    A = -jnp.exp(p["A_log"])                                      # (din,N)

    if FLAGS.mamba_seq_scan:
        # sequential time recurrence (hillclimb 2.2): one step per token,
        # carry h (B, din, N); residual = the dA/dBu sequences only.
        def step(h, inp):
            u_t, dt_t, b_t, c_t = inp
            dA = jnp.exp(dt_t[..., None] * A)
            h = dA * h + (dt_t * u_t.astype(jnp.float32))[..., None] \
                * b_t.astype(jnp.float32)[:, None, :]
            y = jnp.sum(h * c_t.astype(jnp.float32)[:, None, :], axis=-1)
            return h, y  # keep f32: a bf16 ys buffer makes XLA shadow-
            #              convert the WHOLE stack every step (§Perf 2.2)

        # f32 xs too: bf16 xs make the BACKWARD's stacked cotangent
        # buffers dtype-mismatch and shadow-convert per step
        sw = lambda t: jnp.swapaxes(t.astype(jnp.float32), 0, 1)
        h_last, ys = jax.lax.scan(step, h0, (sw(u), sw(dt), sw(Bc), sw(Cc)))
        y = jnp.swapaxes(ys, 0, 1).astype(x.dtype)
        y = y + u * p["Dskip"].astype(x.dtype)
        y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
        out = x + jnp.einsum("bsi,id->bsd", y, p["out_proj"])
        if return_state:
            K = cfg.ssm_conv
            conv_state = xin[:, S - (K - 1):S] if S >= K - 1 else jnp.pad(
                xin, [(0, 0), (K - 1 - S, 0), (0, 0)])
            return out, (conv_state, h_last)
        return out

    # pad S to a multiple of the chunk size and scan over chunks; padded
    # positions get dt=0 => dA=1, dBu=0 (identity on the carried state)
    pad = (-S) % C
    def padS(t):
        return jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
    up, dtp, Bp, Cp = padS(u), padS(dt), padS(Bc), padS(Cc)
    if pad:
        valid = (jnp.arange(S + pad) < S)[None, :, None]
        dtp = jnp.where(valid, dtp, 0.0)
    nck = (S + pad) // C

    def chunk(h, inp):
        uc, dtc, bc, cc = inp                                # (B,C,...)
        dA = jnp.exp(dtc[..., None] * A).astype(scan_dt)     # (B,C,din,N)
        dBu = ((dtc * uc.astype(jnp.float32))[..., None]
               * bc.astype(jnp.float32)[:, :, None, :]).astype(scan_dt)

        def comb(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, b1 * a2 + b2

        a_cum, b_cum = jax.lax.associative_scan(comb, (dA, dBu), axis=1)
        hs = b_cum + a_cum * h[:, None].astype(scan_dt)      # (B,C,din,N)
        y = jnp.einsum("bcin,bcn->bci", hs, cc.astype(scan_dt))
        return hs[:, -1].astype(jnp.float32), y.astype(x.dtype)

    reshp = lambda t: t.reshape(B, nck, C, *t.shape[2:]).swapaxes(0, 1)
    h_last, ys = jax.lax.scan(chunk, h0, (reshp(up), reshp(dtp), reshp(Bp),
                                          reshp(Cp)))
    y = ys.swapaxes(0, 1).reshape(B, S + pad, din)[:, :S]
    y = y + u * p["Dskip"].astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = x + jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    if return_state:
        K = cfg.ssm_conv
        conv_state = xin[:, S - (K - 1):S] if S >= K - 1 else jnp.pad(
            xin, [(0, 0), (K - 1 - S, 0), (0, 0)])
        # NOTE: with padding the last-chunk carry includes padded zeros'
        # decay only (dt=0 -> dA=1, dBu=0), so h_last is exact.
        return out, (conv_state, h_last)
    return out


def mamba1_decode(cfg: ModelConfig, p: Dict, x: jnp.ndarray,
                  conv_state: jnp.ndarray, ssm_state: jnp.ndarray):
    """x: (B, 1, D); conv_state: (B, K-1, din); ssm_state: (B, din, N)."""
    B = x.shape[0]
    N, R = cfg.ssm_state, cfg.dt_rank_
    res = L.rmsnorm(x, p["ln"]["w"]) if cfg.norm == "rmsnorm" else x
    xz = jnp.einsum("bsd,de->bse", res, p["in_proj"])
    xin, z = jnp.split(xz, 2, axis=-1)
    u = jax.nn.silu(_causal_conv(xin, p["conv_w"], p["conv_b"], conv_state)
                    .astype(jnp.float32)).astype(x.dtype)
    conv_state = jnp.concatenate([conv_state[:, 1:], xin], axis=1)

    dbc = jnp.einsum("bsi,ie->bse", u, p["x_proj"])
    dt_r, Bc, Cc = jnp.split(dbc, [R, R + N], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dt_r, p["dt_proj"]).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))[:, 0]            # (B,din)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt[..., None] * A)                          # (B,din,N)
    dBu = (dt * u[:, 0].astype(jnp.float32))[..., None] \
        * Bc[:, 0].astype(jnp.float32)[:, None, :]
    h = dA * ssm_state + dBu
    y = jnp.einsum("bin,bn->bi", h, Cc[:, 0].astype(jnp.float32))
    y = y.astype(x.dtype)[:, None, :] + u * p["Dskip"].astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return x + jnp.einsum("bsi,id->bsd", y, p["out_proj"]), conv_state, h


# =============================================================================
# Mamba2 (SSD)
# =============================================================================

def mamba2_params(cfg: ModelConfig, rng) -> Dict:
    """The reference fused in_proj (D, 2*din+2N+nh) is decomposed into a
    shard-aligned zx projection plus small B/C/dt projections: identical
    math/params, but the big matmul output splits exactly at the tensor-
    parallel shard boundary (DESIGN.md §6)."""
    D, din, N, K = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    nh = din // cfg.ssm_head_dim
    pd = L.pdtype_of(cfg)
    ks = jax.random.split(rng, 5)
    return {
        "ln": L.norm_params(cfg, ks[0]),
        "zx_proj": L.dense_init(ks[1], (D, 2 * din), pd),
        "bc_proj": L.dense_init(ks[2], (D, 2 * N), pd),
        "dtp": L.dense_init(ks[4], (D, nh), pd),
        "conv_w": L.dense_init(ks[2], (K, din), pd),
        "conv_b": jnp.zeros((din,), pd),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "dt_bias": jnp.full((nh,), -4.6, jnp.float32),
        "Dskip": jnp.ones((nh,), pd),
        "out_proj": L.dense_init(ks[3], (din, D), pd),
    }


def _mamba2_proj(p: Dict, res: jnp.ndarray):
    zx = jnp.einsum("bsd,de->bse", res, p["zx_proj"])
    z, xin = jnp.split(zx, 2, axis=-1)
    bc = jnp.einsum("bsd,de->bse", res, p["bc_proj"])
    Bc, Cc = jnp.split(bc, 2, axis=-1)
    dt_r = jnp.einsum("bsd,de->bse", res, p["dtp"])
    return z, xin, Bc, Cc, dt_r


def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """x: (..., C) -> (..., C, C) lower-tri cumulative sums: out[i,j] =
    sum_{k=j+1..i} x[k] for i >= j, -inf above the diagonal."""
    C = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    i = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    j = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    return jnp.where(i >= j, diff, -jnp.inf)


def mamba2_full(cfg: ModelConfig, p: Dict, x: jnp.ndarray,
                return_state: bool = False):
    B, S, D = x.shape
    din, N, C = cfg.d_inner, cfg.ssm_state, cfg.ssm_chunk
    P = cfg.ssm_head_dim
    nh = din // P

    res = L.rmsnorm(x, p["ln"]["w"]) if cfg.norm == "rmsnorm" else x
    z, xin, Bc, Cc, dt_r = _mamba2_proj(p, res)
    u = jax.nn.silu(_causal_conv(xin, p["conv_w"], p["conv_b"])
                    .astype(jnp.float32)).astype(x.dtype)
    dt = jax.nn.softplus(dt_r.astype(jnp.float32) + p["dt_bias"])  # (B,S,nh)
    A = -jnp.exp(p["A_log"])                                       # (nh,)
    dA = dt * A                                                    # (B,S,nh)

    pad = (-S) % C
    def padS(t):
        return jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
    dtpad, dApad = padS(dt), padS(dA)
    if pad:
        valid = (jnp.arange(S + pad) < S)[None, :, None]
        dtpad = jnp.where(valid, dtpad, 0.0)   # identity on padded steps
        dApad = jnp.where(valid, dApad, 0.0)
    up = padS(u).reshape(B, -1, C, nh, P)
    dtp = dtpad.reshape(B, -1, C, nh)
    dAp = dApad.reshape(B, -1, C, nh)
    Bp = padS(Bc).reshape(B, -1, C, N)
    Cp = padS(Cc).reshape(B, -1, C, N)
    nck = up.shape[1]

    def chunk(h, inp):                    # h: (B, nh, N, P) f32
        uc, dtc, dac, bc, cc = inp        # (B,C,nh,P) (B,C,nh) (B,C,nh) (B,C,N)
        cum = jnp.cumsum(dac, axis=1)                         # (B,C,nh)
        Lmat = jnp.exp(_segsum(dac.swapaxes(1, 2)))           # (B,nh,C,C)
        cb = jnp.einsum("bin,bjn->bij", cc.astype(jnp.float32),
                        bc.astype(jnp.float32))               # (B,C,C)
        du = dtc[..., None] * uc.astype(jnp.float32)          # (B,C,nh,P)
        y_diag = jnp.einsum("bhij,bij,bjhp->bihp", Lmat, cb, du)
        # contribution of the carried-in state
        y_off = jnp.einsum("bin,bhnp,bih->bihp", cc.astype(jnp.float32), h,
                           jnp.exp(cum))
        # new carry
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)          # (B,C,nh)
        h_new = jnp.exp(cum[:, -1])[..., None, None] * h + jnp.einsum(
            "bjn,bjh,bjhp->bhnp", bc.astype(jnp.float32), decay_to_end, du)
        return h_new, (y_diag + y_off).astype(x.dtype)

    h0 = jnp.zeros((B, nh, N, P), jnp.float32)
    sw = lambda t: t.swapaxes(0, 1)
    h_last, ys = jax.lax.scan(chunk, h0,
                              (sw(up), sw(dtp), sw(dAp), sw(Bp), sw(Cp)))
    y = ys.swapaxes(0, 1).reshape(B, S + pad, din)[:, :S]
    y = y + (padS(u).reshape(B, -1, nh, P)[:, :S]
             * p["Dskip"].astype(x.dtype)[None, None, :, None]).reshape(B, S, din)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = x + jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    if return_state:
        K = cfg.ssm_conv
        conv_state = xin[:, S - (K - 1):S] if S >= K - 1 else jnp.pad(
            xin, [(0, 0), (K - 1 - S, 0), (0, 0)])
        return out, (conv_state, h_last)
    return out


def mamba2_decode(cfg: ModelConfig, p: Dict, x: jnp.ndarray,
                  conv_state: jnp.ndarray, ssm_state: jnp.ndarray):
    """x: (B,1,D); conv_state: (B,K-1,din); ssm_state: (B,nh,N,P)."""
    B = x.shape[0]
    din, N, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_head_dim
    nh = din // P
    res = L.rmsnorm(x, p["ln"]["w"]) if cfg.norm == "rmsnorm" else x
    z, xin, Bc, Cc, dt_r = _mamba2_proj(p, res)
    u = jax.nn.silu(_causal_conv(xin, p["conv_w"], p["conv_b"], conv_state)
                    .astype(jnp.float32)).astype(x.dtype)
    conv_state = jnp.concatenate([conv_state[:, 1:], xin], axis=1)
    dt = jax.nn.softplus(dt_r.astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B,nh)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)                                                 # (B,nh)
    uh = u[:, 0].reshape(B, nh, P).astype(jnp.float32)
    h = dA[..., None, None] * ssm_state + jnp.einsum(
        "bn,bh,bhp->bhnp", Bc[:, 0].astype(jnp.float32), dt, uh)
    y = jnp.einsum("bn,bhnp->bhp", Cc[:, 0].astype(jnp.float32), h)
    y = (y + uh * p["Dskip"].astype(jnp.float32)[None, :, None])
    y = y.reshape(B, 1, din).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return x + jnp.einsum("bsi,id->bsd", y, p["out_proj"]), conv_state, h
