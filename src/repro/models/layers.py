"""Shared neural-net building blocks (pure JAX, param dicts, no framework)."""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def pdtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# -- initialisers -------------------------------------------------------------

def dense_init(rng, shape, dtype, scale: float = 1.0):
    fan_in = shape[0] if len(shape) >= 2 else max(1, shape[0])
    std = scale / np.sqrt(fan_in)
    return (jax.random.normal(rng, shape, jnp.float32) * std).astype(dtype)


def embed_init(rng, shape, dtype):
    return (jax.random.normal(rng, shape, jnp.float32) * 0.02).astype(dtype)


# -- norms -----------------------------------------------------------------------

def rmsnorm(x, w, eps: float = 1e-6):
    h = x.astype(jnp.float32)
    h = h * jax.lax.rsqrt(jnp.mean(h * h, axis=-1, keepdims=True) + eps)
    return (h * w.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, w, b, eps: float = 1e-5):
    h = x.astype(jnp.float32)
    mu = jnp.mean(h, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(h - mu), axis=-1, keepdims=True)
    h = (h - mu) * jax.lax.rsqrt(var + eps)
    if w is not None:
        h = h * w.astype(jnp.float32)
    if b is not None:
        h = h + b.astype(jnp.float32)
    return h.astype(x.dtype)


def nonparam_ln(x, eps: float = 1e-5):
    """OLMo's non-parametric LayerNorm (no affine parameters)."""
    return layernorm(x, None, None, eps)


def make_norm(cfg: ModelConfig):
    if cfg.norm == "rmsnorm":
        return lambda x, p: rmsnorm(x, p["w"])
    if cfg.norm == "layernorm":
        return lambda x, p: layernorm(x, p["w"], p["b"])
    if cfg.norm == "nonparam_ln":
        return lambda x, p: nonparam_ln(x)
    raise ValueError(cfg.norm)


def norm_params(cfg: ModelConfig, rng) -> Dict:
    if cfg.norm == "rmsnorm":
        return {"w": jnp.ones((cfg.d_model,), pdtype_of(cfg))}
    if cfg.norm == "layernorm":
        return {"w": jnp.ones((cfg.d_model,), pdtype_of(cfg)),
                "b": jnp.zeros((cfg.d_model,), pdtype_of(cfg))}
    return {"_": jnp.zeros((1,), pdtype_of(cfg))}  # placeholder leaf


# -- rotary embeddings ----------------------------------------------------------

def rope_freqs(hd_rot: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, hd_rot, 2, dtype=np.float64) / hd_rot))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
               frac: float = 1.0) -> jnp.ndarray:
    """x: (B, S, H, hd); positions: (B, S).  Rotates the first
    ``frac * hd`` dims (neox half-split style); the rest pass through
    (partial rotary, as in ChatGLM's 2d-RoPE backbone)."""
    hd = x.shape[-1]
    hd_rot = int(hd * frac)
    hd_rot -= hd_rot % 2
    if hd_rot == 0:
        return x
    freqs = jnp.asarray(rope_freqs(hd_rot, theta), jnp.float32)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (B,S,hd_rot/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    xr = x[..., :hd_rot].astype(jnp.float32)
    x1, x2 = jnp.split(xr, 2, axis=-1)
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                              axis=-1)
    return jnp.concatenate([rotated.astype(x.dtype), x[..., hd_rot:]], axis=-1)


# -- attention ----------------------------------------------------------------------

def repeat_kv(k: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=2)


def _maybe_shard_scores(scores: jnp.ndarray) -> jnp.ndarray:
    """Hillclimb 1a (EXPERIMENTS.md §Perf): keep decode attention scores
    sharded along the KV-sequence axis so GSPMD computes partial softmax
    with small all-reduces instead of all-gathering the cache per layer."""
    from repro.models.opt_flags import FLAGS
    if not FLAGS.decode_shard_scores or scores.shape[2] != 1:
        return scores
    from jax.sharding import PartitionSpec as P
    try:
        return jax.lax.with_sharding_constraint(
            scores, P(None, None, None, FLAGS.decode_seq_axis))
    except (ValueError, RuntimeError):
        return scores  # no mesh context (plain CPU tests)


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
              causal: bool, q_offset=0,
              kv_len: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """q: (B,Sq,H,hd), k/v: (B,Skv,Hkv,hd).  fp32 softmax.

    ``q_offset``: absolute position of q[0] (decode: cache length).
    ``kv_len``: optional valid kv length for masking a padded cache.
    """
    B, Sq, H, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    k = repeat_kv(k, H // Hkv)
    v = repeat_kv(v, H // Hkv)
    scale = 1.0 / np.sqrt(hd)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    scores = _maybe_shard_scores(scores)
    mask = None  # broadcastable against (B, H, Sq, Skv)
    if causal and Sq > 1:
        qpos = jax.lax.broadcasted_iota(jnp.int32, (Sq, Skv), 0) + q_offset
        kpos = jax.lax.broadcasted_iota(jnp.int32, (Sq, Skv), 1)
        mask = (kpos <= qpos)[None, None]
    if kv_len is not None:
        kv_len = jnp.asarray(kv_len)
        kpos = jnp.arange(Skv, dtype=jnp.int32)
        if kv_len.ndim == 0:
            valid = (kpos < kv_len)[None, None, None, :]
        else:  # per-sequence lengths (B,)
            valid = (kpos[None] < kv_len[:, None])[:, None, None, :]
        mask = valid if mask is None else (mask & valid)
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def attention_partial(q, k, v, kv_len=None):
    """Unnormalized attention partial for online-softmax merging:
    returns (o_un (B,Sq,H,hd) f32, m (B,H,Sq) f32, l (B,H,Sq) f32)."""
    B, Sq, H, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    k = repeat_kv(k, H // Hkv)
    v = repeat_kv(v, H // Hkv)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) / np.sqrt(hd)
    s = _maybe_shard_scores(s)
    if kv_len is not None:
        kv_len = jnp.asarray(kv_len)
        kpos = jnp.arange(Skv, dtype=jnp.int32)
        if kv_len.ndim == 0:
            valid = (kpos < kv_len)[None, None, None, :]
        else:
            valid = (kpos[None] < kv_len[:, None])[:, None, None, :]
        s = jnp.where(valid, s, -1e30)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o_un = jnp.einsum("bhqk,bkhd->bqhd", p.astype(q.dtype), v)
    return o_un.astype(jnp.float32), m, l


def attention_partial_hs(q, k_hs, v_hs, kv_len=None):
    """Like attention_partial but with head-major (B,Hkv,S,hd) K/V layout
    (no transpose on read) and grouped-query einsums (no materialized
    repeat_kv) — hillclimb 1 iterations 2+3."""
    B, Sq, H, hd = q.shape
    Hkv, Skv = k_hs.shape[1], k_hs.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Sq, Hkv, G, hd)
    s = jnp.einsum("bqhgd,bhkd->bhgqk", qg, k_hs,
                   preferred_element_type=jnp.float32) / np.sqrt(hd)
    s = s.reshape(B, H, Sq, Skv)
    s = _maybe_shard_scores(s)
    if kv_len is not None:
        kv_len = jnp.asarray(kv_len)
        kpos = jnp.arange(Skv, dtype=jnp.int32)
        if kv_len.ndim == 0:
            valid = (kpos < kv_len)[None, None, None, :]
        else:
            valid = (kpos[None] < kv_len[:, None])[:, None, None, :]
        s = jnp.where(valid, s, -1e30)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    pg = p.reshape(B, Hkv, G, Sq, Skv)
    l = jnp.sum(p, axis=-1)
    o_un = jnp.einsum("bhgqk,bhkd->bqhgd", pg.astype(q.dtype), v_hs)
    return o_un.reshape(B, Sq, H, hd).astype(jnp.float32), m, l


def merge_partials(parts):
    """Merge [(o_un, m, l), ...] online-softmax partials -> (B,Sq,H,hd)."""
    m = parts[0][1]
    for _, mi, _ in parts[1:]:
        m = jnp.maximum(m, mi)
    num = 0.0
    den = 0.0
    for o_un, mi, li in parts:
        a = jnp.exp(mi - m)                       # (B,H,Sq)
        num = num + o_un * a.transpose(0, 2, 1)[..., None]
        den = den + (li * a).transpose(0, 2, 1)[..., None]
    return num / jnp.maximum(den, 1e-30)


# -- MLPs --------------------------------------------------------------------------------

def mlp_apply(cfg: ModelConfig, p: Dict, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.act == "swiglu":
        gate = jnp.einsum("...d,df->...f", x, p["w_gate"])
        up = jnp.einsum("...d,df->...f", x, p["w_up"])
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    else:  # gelu
        h = jnp.einsum("...d,df->...f", x, p["w_up"])
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, p["w_down"])


def mlp_params(cfg: ModelConfig, rng, d_ff: Optional[int] = None) -> Dict:
    d_ff = d_ff or cfg.d_ff
    D, pd = cfg.d_model, pdtype_of(cfg)
    ks = jax.random.split(rng, 3)
    p = {"w_up": dense_init(ks[0], (D, d_ff), pd),
         "w_down": dense_init(ks[1], (d_ff, D), pd)}
    if cfg.act == "swiglu":
        p["w_gate"] = dense_init(ks[2], (D, d_ff), pd)
    return p


# -- attention block params -------------------------------------------------------------------

def attn_params(cfg: ModelConfig, rng) -> Dict:
    D, hd, pd = cfg.d_model, cfg.hd, pdtype_of(cfg)
    ks = jax.random.split(rng, 4)
    p = {"wq": dense_init(ks[0], (D, cfg.n_heads * hd), pd),
         "wk": dense_init(ks[1], (D, cfg.n_kv_heads * hd), pd),
         "wv": dense_init(ks[2], (D, cfg.n_kv_heads * hd), pd),
         "wo": dense_init(ks[3], (cfg.n_heads * hd, D), pd)}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), pd)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), pd)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), pd)
    return p


def qkv_proj(cfg: ModelConfig, p: Dict, x: jnp.ndarray
             ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    B, S, _ = x.shape
    hd = cfg.hd
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    return q, k, v
