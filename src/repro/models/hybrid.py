"""Zamba2-style hybrid stack: Mamba2 layers + ONE shared attention+MLP
block invoked every ``shared_attn_every`` layers (weights reused across
invocations, as in Zamba2; the concat-with-original-embedding trick and
per-invocation LoRA deltas are simplified away — DESIGN.md §4).

Decode state: per-layer Mamba2 (conv, ssm) states + a KV cache per shared-
block invocation (G = n_layers // shared_attn_every caches).
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import mamba as M
from repro.models.config import ModelConfig


class HybridCache(NamedTuple):
    conv: jnp.ndarray    # (L, B, K-1, d_inner)
    ssm: jnp.ndarray     # (L, B, nh, N, P)
    k: jnp.ndarray       # (G, B, S_max, H_kv, hd)
    v: jnp.ndarray
    length: jnp.ndarray  # scalar int32


def n_shared_invocations(cfg: ModelConfig) -> int:
    return max(1, cfg.n_layers // max(1, cfg.shared_attn_every))


def init_params(cfg: ModelConfig, rng) -> Dict:
    k1, k2, k3, k4, k5 = jax.random.split(rng, 5)
    rngs = jax.random.split(k2, cfg.n_layers)
    return {
        "embed": {"tok": L.embed_init(k1, (cfg.vocab, cfg.d_model),
                                      L.pdtype_of(cfg)),
                  "final_norm": L.norm_params(cfg, k5),
                  "lm_head": L.dense_init(k4, (cfg.d_model, cfg.vocab),
                                          L.pdtype_of(cfg))},
        "mamba": jax.vmap(lambda r: M.mamba2_params(cfg, r))(rngs),
        "shared": {"ln1": L.norm_params(cfg, k3),
                   "attn": L.attn_params(cfg, k3),
                   "ln2": L.norm_params(cfg, k3),
                   "mlp": L.mlp_params(cfg, k3)},
    }


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> HybridCache:
    din, N, P, K = (cfg.d_inner, cfg.ssm_state, cfg.ssm_head_dim,
                    cfg.ssm_conv)
    nh = din // P
    G = n_shared_invocations(cfg)
    dt = L.dtype_of(cfg)
    return HybridCache(
        conv=jnp.zeros((cfg.n_layers, batch, K - 1, din), dt),
        ssm=jnp.zeros((cfg.n_layers, batch, nh, N, P), jnp.float32),
        k=jnp.zeros((G, batch, max_len, cfg.n_kv_heads, cfg.hd), dt),
        v=jnp.zeros((G, batch, max_len, cfg.n_kv_heads, cfg.hd), dt),
        length=jnp.int32(0))


def _shared_block_full(cfg: ModelConfig, p: Dict, x, positions):
    norm = L.make_norm(cfg)
    h = norm(x, p["ln1"])
    q, k, v = L.qkv_proj(cfg, p["attn"], h)
    q = L.apply_rope(q, positions, cfg.rope_theta, cfg.rope_frac)
    k = L.apply_rope(k, positions, cfg.rope_theta, cfg.rope_frac)
    o = L.attention(q, k, v, causal=True)
    x = x + jnp.einsum("bqx,xd->bqd", o.reshape(*o.shape[:2], -1),
                       p["attn"]["wo"])
    h = norm(x, p["ln2"])
    return x + L.mlp_apply(cfg, p["mlp"], h), (k, v)


def _shared_block_decode(cfg: ModelConfig, p: Dict, x, pos, kc, vc):
    norm = L.make_norm(cfg)
    B = x.shape[0]
    h = norm(x, p["ln1"])
    q, k, v = L.qkv_proj(cfg, p["attn"], h)
    posb = jnp.full((B, 1), pos, jnp.int32)
    q = L.apply_rope(q, posb, cfg.rope_theta, cfg.rope_frac)
    k = L.apply_rope(k, posb, cfg.rope_theta, cfg.rope_frac)
    kc = jax.lax.dynamic_update_slice(kc, k, (0, pos, 0, 0))
    vc = jax.lax.dynamic_update_slice(vc, v, (0, pos, 0, 0))
    o = L.attention(q, kc, vc, causal=False, kv_len=pos + 1)
    x = x + jnp.einsum("bqx,xd->bqd", o.reshape(B, 1, -1), p["attn"]["wo"])
    h = norm(x, p["ln2"])
    return x + L.mlp_apply(cfg, p["mlp"], h), kc, vc


def _group_slices(params_mamba: Dict, g: int, k: int) -> Dict:
    return jax.tree.map(lambda a: a[g * k:(g + 1) * k], params_mamba)


def forward_full(cfg: ModelConfig, params: Dict, batch: Dict,
                 collect_cache: bool = False, max_len: Optional[int] = None,
                 remat: bool = True):
    tokens = batch["tokens"]
    x = params["embed"]["tok"][tokens].astype(L.dtype_of(cfg))
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    k_every = max(1, cfg.shared_attn_every)
    G = n_shared_invocations(cfg)
    max_len = max_len or S
    kvs = []

    def mamba_body(x, p):
        return M.mamba2_full(cfg, p, x), None

    def mamba_body_state(x, p):
        x, (cs, ss) = M.mamba2_full(cfg, p, x, return_state=True)
        return x, (cs, ss)

    if remat:
        mamba_body = jax.checkpoint(mamba_body)
    states = []
    for g in range(G):
        sl = _group_slices(params["mamba"], g, k_every)
        if collect_cache:
            x, (cs, ss) = jax.lax.scan(mamba_body_state, x, sl)
            states.append((cs, ss))
        else:
            x, _ = jax.lax.scan(mamba_body, x, sl)
        x, (k, v) = _shared_block_full(cfg, params["shared"], x, positions)
        if collect_cache:
            if max_len > S:
                pad = [(0, 0), (0, max_len - S), (0, 0), (0, 0)]
                k, v = jnp.pad(k, pad), jnp.pad(v, pad)
            kvs.append((k, v))

    norm = L.make_norm(cfg)
    x = norm(x, params["embed"]["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["embed"]["lm_head"].astype(x.dtype))
    cache = None
    if collect_cache:
        ks = jnp.stack([k for k, _ in kvs])
        vs = jnp.stack([v for _, v in kvs])
        conv = jnp.concatenate([cs for cs, _ in states])
        ssm = jnp.concatenate([ss for _, ss in states])
        cache = HybridCache(conv=conv, ssm=ssm, k=ks, v=vs,
                            length=jnp.int32(S))
    return logits, cache


def forward_decode(cfg: ModelConfig, params: Dict, tokens: jnp.ndarray,
                   cache: HybridCache):
    x = params["embed"]["tok"][tokens].astype(L.dtype_of(cfg))
    pos = cache.length
    k_every = max(1, cfg.shared_attn_every)
    G = n_shared_invocations(cfg)

    def mamba_body(x, inp):
        p, cs, ss = inp
        x, cs, ss = M.mamba2_decode(cfg, p, x, cs, ss)
        return x, (cs, ss)

    new_conv, new_ssm, new_k, new_v = [], [], [], []
    for g in range(G):
        sl = slice(g * k_every, (g + 1) * k_every)
        x, (cs, ss) = jax.lax.scan(
            mamba_body, x, (_group_slices(params["mamba"], g, k_every),
                            cache.conv[sl], cache.ssm[sl]))
        new_conv.append(cs)
        new_ssm.append(ss)
        x, kc, vc = _shared_block_decode(cfg, params["shared"], x, pos,
                                         cache.k[g], cache.v[g])
        new_k.append(kc)
        new_v.append(vc)

    norm = L.make_norm(cfg)
    x = norm(x, params["embed"]["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["embed"]["lm_head"].astype(x.dtype))
    return logits, HybridCache(
        conv=jnp.concatenate(new_conv), ssm=jnp.concatenate(new_ssm),
        k=jnp.stack(new_k), v=jnp.stack(new_v), length=pos + 1)
