"""Decoder-only transformer (dense / VLM / MoE) with scanned layers.

Three entry points per model family:
  * ``forward_train``  — full-sequence causal forward, returns logits.
  * ``forward_prefill``— like train but also returns the KV cache.
  * ``forward_decode`` — one token with a KV cache (write-at-position).

KV cache layout: k/v as (L, B, S_max, H_kv, hd); sharded (None, "data",
"model", None, None) at scale so a 32k/500k cache divides across the pod
without replicating GQA heads (DESIGN.md §6).
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models.config import ModelConfig


class KVCache(NamedTuple):
    k: jnp.ndarray          # (L, B, S_max, H_kv, hd)
    v: jnp.ndarray
    length: jnp.ndarray     # scalar int32: #valid positions


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  n_layers: Optional[int] = None) -> KVCache:
    nl = cfg.n_layers if n_layers is None else n_layers
    shape = (nl, batch, max_len, cfg.n_kv_heads, cfg.hd)
    z = jnp.zeros(shape, L.dtype_of(cfg))
    return KVCache(z, z, jnp.int32(0))


# -- per-block params -----------------------------------------------------------

def block_params(cfg: ModelConfig, rng) -> Dict:
    ks = jax.random.split(rng, 4)
    p = {"ln1": L.norm_params(cfg, ks[0]),
         "attn": L.attn_params(cfg, ks[1]),
         "ln2": L.norm_params(cfg, ks[2])}
    if cfg.family == "moe":
        p["moe"] = moe_lib.moe_params(cfg, ks[3])
    else:
        p["mlp"] = L.mlp_params(cfg, ks[3])
    return p


def stacked_block_params(cfg: ModelConfig, rng) -> Dict:
    rngs = jax.random.split(rng, cfg.n_layers)
    return jax.vmap(lambda r: block_params(cfg, r))(rngs)


# -- block application -------------------------------------------------------------

def _mix(cfg: ModelConfig, p: Dict, x: jnp.ndarray,
         decode: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """The channel-mixing half (MLP or MoE). Returns (out, aux_loss)."""
    if cfg.family == "moe":
        return moe_lib.moe_apply(cfg, p["moe"], x, decode=decode)
    return L.mlp_apply(cfg, p["mlp"], x), jnp.float32(0.0)


def block_full(cfg: ModelConfig, p: Dict, x: jnp.ndarray,
               positions: jnp.ndarray, causal: bool = True):
    """Full-sequence block. Returns (x, (k, v), aux)."""
    norm = L.make_norm(cfg)
    h = norm(x, p["ln1"])
    q, k, v = L.qkv_proj(cfg, p["attn"], h)
    q = L.apply_rope(q, positions, cfg.rope_theta, cfg.rope_frac)
    k = L.apply_rope(k, positions, cfg.rope_theta, cfg.rope_frac)
    o = L.attention(q, k, v, causal=causal)
    o = jnp.einsum("bqx,xd->bqd", o.reshape(*o.shape[:2], -1), p["attn"]["wo"])
    x = x + o
    h = norm(x, p["ln2"])
    m, aux = _mix(cfg, p, h)
    return x + m, (k, v), aux


def block_decode(cfg: ModelConfig, p: Dict, x: jnp.ndarray,
                 pos: jnp.ndarray, kc: jnp.ndarray, vc: jnp.ndarray):
    """One-token block; kc/vc: (B, S_max, H_kv, hd); pos: scalar cache len."""
    norm = L.make_norm(cfg)
    B = x.shape[0]
    h = norm(x, p["ln1"])
    q, k, v = L.qkv_proj(cfg, p["attn"], h)
    posb = jnp.full((B, 1), pos, jnp.int32)
    q = L.apply_rope(q, posb, cfg.rope_theta, cfg.rope_frac)
    k = L.apply_rope(k, posb, cfg.rope_theta, cfg.rope_frac)
    kc = jax.lax.dynamic_update_slice(kc, k, (0, pos, 0, 0))
    vc = jax.lax.dynamic_update_slice(vc, v, (0, pos, 0, 0))
    o = L.attention(q, kc, vc, causal=False, kv_len=pos + 1)
    o = jnp.einsum("bqx,xd->bqd", o.reshape(B, 1, -1), p["attn"]["wo"])
    x = x + o
    h = norm(x, p["ln2"])
    m, _ = _mix(cfg, p, h, decode=True)
    return x + m, kc, vc


# -- embedding / head -----------------------------------------------------------------

def embed_params(cfg: ModelConfig, rng) -> Dict:
    ks = jax.random.split(rng, 3)
    p = {"tok": L.embed_init(ks[0], (cfg.vocab, cfg.d_model), L.pdtype_of(cfg)),
         "final_norm": L.norm_params(cfg, ks[1])}
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(ks[2], (cfg.d_model, cfg.vocab),
                                    L.pdtype_of(cfg))
    if cfg.frontend == "patch_stub":
        p["mm_proj"] = L.dense_init(ks[2], (cfg.d_model, cfg.d_model),
                                    L.pdtype_of(cfg))
    return p


def embed_tokens(cfg: ModelConfig, p: Dict, tokens: jnp.ndarray) -> jnp.ndarray:
    return p["tok"][tokens].astype(L.dtype_of(cfg))


def lm_logits(cfg: ModelConfig, p: Dict, x: jnp.ndarray) -> jnp.ndarray:
    norm = L.make_norm(cfg)
    x = norm(x, p["final_norm"])
    head = p["tok"].T if cfg.tie_embeddings else p["lm_head"]
    return jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))


def embed_inputs(cfg: ModelConfig, p: Dict, batch: Dict) -> jnp.ndarray:
    """Token embedding, with stub-frontend embeddings prepended for VLM
    (precomputed patch embeddings through a learned projector)."""
    x = embed_tokens(cfg, p, batch["tokens"])
    if cfg.frontend == "patch_stub" and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(L.dtype_of(cfg))
        pe = jnp.einsum("bpd,de->bpe", pe, p["mm_proj"])
        x = jnp.concatenate([pe, x], axis=1)
    return x


# -- model params ------------------------------------------------------------------------

def init_params(cfg: ModelConfig, rng) -> Dict:
    k1, k2 = jax.random.split(rng)
    return {"embed": embed_params(cfg, k1),
            "blocks": stacked_block_params(cfg, k2)}


# -- forward passes ----------------------------------------------------------------------

def forward_train(cfg: ModelConfig, params: Dict, batch: Dict,
                  remat: bool = True):
    """Returns (logits, aux_loss)."""
    x = embed_inputs(cfg, params["embed"], batch)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(carry, p):
        x, aux = carry
        x, _, a = block_full(cfg, p, x, positions)
        return (x, aux + a), None

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), params["blocks"])
    return lm_logits(cfg, params["embed"], x), aux


def forward_prefill(cfg: ModelConfig, params: Dict, batch: Dict,
                    max_len: Optional[int] = None,
                    full_logits: bool = False):
    """Returns (logits, KVCache); logits cover the last position only
    unless ``full_logits`` (used by the serving engine's length-bucketed
    prefill, where the "last real token" is not the last position)."""
    x = embed_inputs(cfg, params["embed"], batch)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    max_len = max_len or S

    def body(x, p):
        x, (k, v), _ = block_full(cfg, p, x, positions)
        if max_len > S:
            pad = [(0, 0), (0, max_len - S), (0, 0), (0, 0)]
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        return x, (k, v)

    x, (ks, vs) = jax.lax.scan(body, x, params["blocks"])
    logits = lm_logits(cfg, params["embed"],
                       x if full_logits else x[:, -1:, :])
    return logits, KVCache(ks, vs, jnp.int32(S))


def forward_decode_paged(cfg: ModelConfig, params: Dict, tokens: jnp.ndarray,
                         kpool: jnp.ndarray, vpool: jnp.ndarray,
                         block_tables: jnp.ndarray, lengths: jnp.ndarray,
                         slot_ids: jnp.ndarray, slot_offs: jnp.ndarray):
    """Paged decode: gather K/V through block tables (vLLM-style).

    kpool/vpool: (L, N, bs, H_kv, hd); block_tables: (B, nb);
    lengths: (B,) current context length; slot_ids/slot_offs: (B,) where
    this step's k/v are written in the pool.  Returns (logits, kpool,
    vpool).  The jnp gather here is the reference semantics of the
    kernels/paged_attention Pallas kernel.
    """
    x = embed_tokens(cfg, params["embed"], tokens)
    B = tokens.shape[0]
    bs = kpool.shape[2]
    norm = L.make_norm(cfg)
    posb = lengths[:, None].astype(jnp.int32)  # (B,1) rope positions

    def body(x, inp):
        p, kp, vp = inp
        h = norm(x, p["ln1"])
        q, k, v = L.qkv_proj(cfg, p["attn"], h)
        q = L.apply_rope(q, posb, cfg.rope_theta, cfg.rope_frac)
        k = L.apply_rope(k, posb, cfg.rope_theta, cfg.rope_frac)
        # write this token's k/v into its pool slot
        kp = kp.at[slot_ids, slot_offs].set(k[:, 0])
        vp = vp.at[slot_ids, slot_offs].set(v[:, 0])
        # gather the sequence's blocks: (B, nb, bs, H, hd) -> (B, S', H, hd)
        kc = kp[block_tables].reshape(B, -1, kp.shape[-2], kp.shape[-1])
        vc = vp[block_tables].reshape(B, -1, vp.shape[-2], vp.shape[-1])
        o = L.attention(q, kc, vc, causal=False, kv_len=lengths + 1)
        o = jnp.einsum("bqx,xd->bqd", o.reshape(B, 1, -1), p["attn"]["wo"])
        x = x + o
        h = norm(x, p["ln2"])
        m, _ = _mix(cfg, p, h, decode=True)
        return x + m, (kp, vp)

    x, (kpool, vpool) = jax.lax.scan(body, x, (params["blocks"], kpool, vpool))
    logits = lm_logits(cfg, params["embed"], x)
    return logits, kpool, vpool


class BufferedKVCache(NamedTuple):
    """Hillclimb 1b/2/3: frozen S-sharded base (head-major layout: no
    transpose on read, grouped-query einsum: no materialized repeat_kv) +
    small replicated append ring.

    Per-step writes hit only the ring (cheap replicated DUS); the sharded
    base is touched by the amortized ``commit_buffer`` every R steps —
    eliminating the per-layer full-shard select/convert that a sharded
    one-token DUS lowers to."""
    k: jnp.ndarray        # (L, B, H_kv, S_max, hd)  -- sharded base
    v: jnp.ndarray
    bk: jnp.ndarray       # (L, B, R, H_kv, hd)      -- replicated ring
    bv: jnp.ndarray
    base_len: jnp.ndarray  # valid positions in base
    buf_len: jnp.ndarray   # valid positions in ring


def init_buffered_cache(cfg: ModelConfig, batch: int, max_len: int,
                        buf_len: int = 256) -> BufferedKVCache:
    dt = L.dtype_of(cfg)
    shape = (cfg.n_layers, batch, cfg.n_kv_heads, max_len, cfg.hd)
    bshape = (cfg.n_layers, batch, buf_len, cfg.n_kv_heads, cfg.hd)
    z = jnp.zeros(shape, dt)
    bz = jnp.zeros(bshape, dt)
    return BufferedKVCache(z, z, bz, bz, jnp.int32(0), jnp.int32(0))


def forward_decode_buffered(cfg: ModelConfig, params: Dict,
                            tokens: jnp.ndarray, cache: BufferedKVCache):
    """One decode token against base+ring (online-softmax merge)."""
    x = embed_tokens(cfg, params["embed"], tokens)
    B = tokens.shape[0]
    pos = cache.base_len + cache.buf_len
    norm = L.make_norm(cfg)

    def body(x, inp):
        p, kc, vc, bk, bv = inp
        h = norm(x, p["ln1"])
        q, k, v = L.qkv_proj(cfg, p["attn"], h)
        posb = jnp.full((B, 1), pos, jnp.int32)
        q = L.apply_rope(q, posb, cfg.rope_theta, cfg.rope_frac)
        k = L.apply_rope(k, posb, cfg.rope_theta, cfg.rope_frac)
        bk = jax.lax.dynamic_update_slice(bk, k, (0, cache.buf_len, 0, 0))
        bv = jax.lax.dynamic_update_slice(bv, v, (0, cache.buf_len, 0, 0))
        p_base = L.attention_partial_hs(q, kc, vc, kv_len=cache.base_len)
        p_buf = L.attention_partial(q, bk, bv, kv_len=cache.buf_len + 1)
        o = L.merge_partials([p_base, p_buf]).astype(x.dtype)
        o = jnp.einsum("bqx,xd->bqd", o.reshape(B, 1, -1), p["attn"]["wo"])
        x = x + o
        h = norm(x, p["ln2"])
        m, _ = _mix(cfg, p, h, decode=True)
        return x + m, (bk, bv)

    x, (bks, bvs) = jax.lax.scan(
        body, x, (params["blocks"], cache.k, cache.v, cache.bk, cache.bv))
    logits = lm_logits(cfg, params["embed"], x)
    return logits, cache._replace(bk=bks, bv=bvs,
                                  buf_len=cache.buf_len + 1)


def commit_buffer(cfg: ModelConfig, cache: BufferedKVCache) -> BufferedKVCache:
    """Amortized ring->base flush (run every R steps); the ring is
    transposed into the base's head-major layout here, once per R steps."""
    bk = cache.bk.transpose(0, 1, 3, 2, 4)  # (L,B,R,H,hd)->(L,B,H,R,hd)
    bv = cache.bv.transpose(0, 1, 3, 2, 4)
    k = jax.lax.dynamic_update_slice(
        cache.k, bk, (0, 0, 0, cache.base_len, 0))
    v = jax.lax.dynamic_update_slice(
        cache.v, bv, (0, 0, 0, cache.base_len, 0))
    return cache._replace(k=k, v=v,
                          base_len=cache.base_len + cache.bk.shape[2],
                          buf_len=jnp.int32(0))


def forward_decode(cfg: ModelConfig, params: Dict, tokens: jnp.ndarray,
                   cache: KVCache):
    """tokens: (B, 1). Returns (logits (B,1,V), updated cache)."""
    x = embed_tokens(cfg, params["embed"], tokens)
    pos = cache.length

    def body(x, inp):
        p, kc, vc = inp
        x, kc, vc = block_decode(cfg, p, x, pos, kc, vc)
        return x, (kc, vc)

    x, (ks, vs) = jax.lax.scan(body, x, (params["blocks"], cache.k, cache.v))
    logits = lm_logits(cfg, params["embed"], x)
    return logits, KVCache(ks, vs, pos + 1)
