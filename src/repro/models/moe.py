"""Mixture-of-Experts layer: top-k routing with capacity-bounded dispatch.

Dispatch uses a scatter into a per-expert buffer of shape (E, C, D) — the
expert axis shards over the mesh "model" axis (expert parallelism); GSPMD
lowers the scatter/gather into all-to-all-style collectives.  For the 1T
config the expert FFN dim additionally shards over "data"
(2-D expert sharding, DESIGN.md §6).

Aux loss: Switch-style load-balance loss (mean fraction × mean router prob
per expert, scaled by E).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig


def _constrain(x, logical):
    """Hillclimb 3 (EXPERIMENTS.md §Perf): pin MoE dispatch shardings so
    GSPMD keeps dispatch buffers expert-sharded and token tensors
    data-sharded instead of all-gathering per layer.  "data_batch" maps
    to ("data",)/(("pod","data")) depending on the mesh axes present."""
    from repro.models.opt_flags import FLAGS
    if not FLAGS.moe_local_dispatch:
        return x
    import jax
    from jax.sharding import PartitionSpec as P

    def resolve(ax):
        if ax != "data_batch":
            return ax
        try:
            mesh = jax.sharding.get_abstract_mesh()
            if mesh is not None and "pod" in mesh.axis_names:
                return ("pod", "data")
        except Exception:  # noqa: BLE001
            pass
        return "data"

    try:
        return jax.lax.with_sharding_constraint(
            x, P(*[resolve(a) for a in logical]))
    except (ValueError, RuntimeError):
        return x  # no mesh (plain CPU tests)


def moe_params(cfg: ModelConfig, rng) -> Dict:
    D, F, E, pd = cfg.d_model, cfg.moe_d_ff, cfg.n_experts, L.pdtype_of(cfg)
    ks = jax.random.split(rng, 5)
    p = {"router": L.dense_init(ks[0], (D, E), jnp.float32),
         "w_gate": L.dense_init(ks[1], (E, D, F), pd),
         "w_up": L.dense_init(ks[2], (E, D, F), pd),
         "w_down": L.dense_init(ks[3], (E, F, D), pd)}
    if cfg.n_shared_experts:
        p["shared"] = L.mlp_params(cfg, ks[4],
                                   d_ff=cfg.moe_d_ff * cfg.n_shared_experts)
    return p


def _capacity(cfg: ModelConfig, n_tokens: int, decode: bool) -> int:
    """Capacity per expert.  Decode uses a higher factor (drops at decode
    hurt generation quality) and is exactly dropless when the batch is
    small enough that C would reach T*K anyway."""
    cf = 4.0 if decode else cfg.capacity_factor
    c = int(n_tokens * cfg.experts_per_tok * cf / cfg.n_experts)
    c = max(4, -(-c // 4) * 4)  # round up to a multiple of 4
    return min(c, n_tokens * cfg.experts_per_tok)


def moe_apply(cfg: ModelConfig, p: Dict, x: jnp.ndarray,
              decode: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (B, S, D), aux_loss (f32 scalar)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.experts_per_tok
    T = B * S
    C = _capacity(cfg, T, decode)
    xf = x.reshape(T, D)

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, eidx = jax.lax.top_k(probs, K)           # (T, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # position of each (token, k) within its expert's capacity buffer:
    # rank = #earlier (token', k') routed to the same expert.
    flat_e = eidx.reshape(-1)                            # (T*K,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (T*K, E)
    ranks = jnp.cumsum(onehot, axis=0) - onehot          # exclusive cumsum
    pos = jnp.take_along_axis(ranks, flat_e[:, None], axis=1)[:, 0]  # (T*K,)
    keep = pos < C

    # scatter tokens into (E, C, D)
    buf = jnp.zeros((E, C, D), x.dtype)
    src = jnp.repeat(xf, K, axis=0)                      # (T*K, D)
    safe_pos = jnp.where(keep, pos, 0)
    src = _constrain(src, ("data_batch", None))
    buf = buf.at[flat_e, safe_pos].add(
        jnp.where(keep[:, None], src, 0), mode="drop")
    buf = _constrain(buf, ("model", None, None))

    # expert FFN on the buffers
    if cfg.act == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
        u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        h = jax.nn.gelu(
            jnp.einsum("ecd,edf->ecf", buf, p["w_up"]).astype(jnp.float32)
        ).astype(x.dtype)
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    out_buf = _constrain(out_buf, ("model", None, None))

    # gather back and combine with gates
    gathered = out_buf[flat_e, safe_pos]                 # (T*K, D)
    gathered = _constrain(gathered, ("data_batch", None))
    gathered = jnp.where(keep[:, None], gathered, 0)
    gates = gate_vals.reshape(-1).astype(x.dtype)
    y = jnp.sum((gathered * gates[:, None]).reshape(T, K, D), axis=1)

    if cfg.n_shared_experts:
        y = y + L.mlp_apply(cfg, p["shared"], xf)

    # Switch load-balance aux loss
    frac = jnp.mean(jax.nn.one_hot(eidx[:, 0], E, dtype=jnp.float32), axis=0)
    pmean = jnp.mean(probs, axis=0)
    aux = jnp.float32(E) * jnp.sum(frac * pmean) * cfg.router_aux_coef

    return y.reshape(B, S, D), aux
