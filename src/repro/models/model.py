"""Unified model API across all families.

``build(cfg)`` returns a ``ModelAPI`` with pure functions:
  init(rng) -> params
  loss(params, batch) -> (scalar loss, metrics dict)
  prefill(params, batch, max_len) -> (logits, cache)
  decode(params, tokens, cache) -> (logits, cache)
  init_cache(batch_size, max_len) -> zeroed cache (fresh-decode dry-run)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.models import encdec, hybrid
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import transformer as T
from repro.models.config import ModelConfig


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray):
    """fp32 CE with ignore_index = -1.  logits (B,S,V), labels (B,S)."""
    lf = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(lf, axis=-1, keepdims=True))
    logz = m[..., 0] + jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1))
    safe = jnp.maximum(labels, 0)
    ll = jnp.take_along_axis(lf, safe[..., None], axis=-1)[..., 0] - logz
    mask = (labels >= 0).astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return -jnp.sum(ll * mask) / denom


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    init: Callable[[Any], Dict]
    loss: Callable[[Dict, Dict], Any]
    prefill: Callable[..., Any]
    decode: Callable[..., Any]
    init_cache: Callable[..., Any]


# ----------------------------------------------------------------------------
# pure-SSM stack (falcon-mamba)
# ----------------------------------------------------------------------------

def _ssm_init(cfg: ModelConfig, rng) -> Dict:
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    rngs = jax.random.split(k2, cfg.n_layers)
    return {"embed": {"tok": L.embed_init(k1, (cfg.vocab, cfg.d_model),
                                          L.pdtype_of(cfg)),
                      "final_norm": L.norm_params(cfg, k3),
                      "lm_head": L.dense_init(k4, (cfg.d_model, cfg.vocab),
                                              L.pdtype_of(cfg))},
            "blocks": jax.vmap(lambda r: M.mamba1_params(cfg, r))(rngs)}


def _ssm_logits(cfg, params, x):
    norm = L.make_norm(cfg)
    x = norm(x, params["embed"]["final_norm"])
    return jnp.einsum("bsd,dv->bsv", x,
                      params["embed"]["lm_head"].astype(x.dtype))


def _ssm_forward_train(cfg, params, batch, remat: bool = True):
    x = params["embed"]["tok"][batch["tokens"]].astype(L.dtype_of(cfg))

    def body(x, p):
        return M.mamba1_full(cfg, p, x), None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["blocks"])
    return _ssm_logits(cfg, params, x), jnp.float32(0.0)


def _ssm_prefill(cfg, params, batch, max_len=None):
    x = params["embed"]["tok"][batch["tokens"]].astype(L.dtype_of(cfg))

    def body(x, p):
        x, (cs, ss) = M.mamba1_full(cfg, p, x, return_state=True)
        return x, (cs, ss)

    x, (convs, ssms) = jax.lax.scan(body, x, params["blocks"])
    logits = _ssm_logits(cfg, params, x[:, -1:])
    cache = M.SSMCache(conv=convs, ssm=ssms)
    return logits, cache


def _ssm_decode(cfg, params, tokens, cache: M.SSMCache):
    x = params["embed"]["tok"][tokens].astype(L.dtype_of(cfg))

    def body(x, inp):
        p, cs, ss = inp
        x, cs, ss = M.mamba1_decode(cfg, p, x, cs, ss)
        return x, (cs, ss)

    x, (convs, ssms) = jax.lax.scan(body, x,
                                    (params["blocks"], cache.conv, cache.ssm))
    return _ssm_logits(cfg, params, x), M.SSMCache(conv=convs, ssm=ssms)


def _ssm_init_cache(cfg, batch: int, max_len: int) -> M.SSMCache:
    return M.SSMCache(
        conv=jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv - 1, cfg.d_inner),
                       L.dtype_of(cfg)),
        ssm=jnp.zeros((cfg.n_layers, batch, cfg.d_inner, cfg.ssm_state),
                      jnp.float32))


# ----------------------------------------------------------------------------
# dispatcher
# ----------------------------------------------------------------------------

def build(cfg: ModelConfig) -> ModelAPI:
    fam = cfg.family

    if fam in ("dense", "vlm", "moe"):
        def loss(params, batch):
            logits, aux = T.forward_train(cfg, params, batch)
            ce = cross_entropy(logits, batch["labels"])
            return ce + aux, {"ce": ce, "aux": aux}

        return ModelAPI(
            cfg=cfg,
            init=lambda rng: T.init_params(cfg, rng),
            loss=loss,
            prefill=lambda params, batch, max_len=None: T.forward_prefill(
                cfg, params, batch, max_len=max_len),
            decode=lambda params, tokens, cache: T.forward_decode(
                cfg, params, tokens, cache),
            init_cache=lambda batch, max_len: T.init_kv_cache(
                cfg, batch, max_len))

    if fam == "ssm":
        def loss(params, batch):
            logits, aux = _ssm_forward_train(cfg, params, batch)
            ce = cross_entropy(logits, batch["labels"])
            return ce + aux, {"ce": ce, "aux": aux}

        return ModelAPI(
            cfg=cfg,
            init=lambda rng: _ssm_init(cfg, rng),
            loss=loss,
            prefill=lambda params, batch, max_len=None: _ssm_prefill(
                cfg, params, batch, max_len),
            decode=lambda params, tokens, cache: _ssm_decode(
                cfg, params, tokens, cache),
            init_cache=lambda batch, max_len: _ssm_init_cache(
                cfg, batch, max_len))

    if fam == "hybrid":
        def loss(params, batch):
            logits, _ = hybrid.forward_full(cfg, params, batch)
            ce = cross_entropy(logits, batch["labels"])
            return ce, {"ce": ce}

        return ModelAPI(
            cfg=cfg,
            init=lambda rng: hybrid.init_params(cfg, rng),
            loss=loss,
            prefill=lambda params, batch, max_len=None: _hybrid_prefill(
                cfg, params, batch, max_len),
            decode=lambda params, tokens, cache: hybrid.forward_decode(
                cfg, params, tokens, cache),
            init_cache=lambda batch, max_len: hybrid.init_cache(
                cfg, batch, max_len))

    if fam == "encdec":
        def loss(params, batch):
            logits, _ = encdec.forward_train(cfg, params, batch)
            ce = cross_entropy(logits, batch["labels"])
            return ce, {"ce": ce}

        def init_cache(batch, max_len):
            enc_len = encdec.enc_len_for(cfg, max_len)
            z = jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads,
                           cfg.hd), L.dtype_of(cfg))
            xz = jnp.zeros((cfg.n_layers, batch, enc_len, cfg.n_kv_heads,
                            cfg.hd), L.dtype_of(cfg))
            return encdec.EncDecCache(z, z, xz, xz, jnp.int32(0))

        return ModelAPI(
            cfg=cfg,
            init=lambda rng: encdec.init_params(cfg, rng),
            loss=loss,
            prefill=lambda params, batch, max_len=None: encdec.forward_prefill(
                cfg, params, batch, max_len),
            decode=lambda params, tokens, cache: encdec.forward_decode(
                cfg, params, tokens, cache),
            init_cache=init_cache)

    raise ValueError(f"unknown family {fam!r}")


def _hybrid_prefill(cfg, params, batch, max_len=None):
    S = batch["tokens"].shape[1]
    logits, cache = hybrid.forward_full(cfg, params, batch,
                                        collect_cache=True,
                                        max_len=max_len or S)
    return logits[:, -1:], cache
