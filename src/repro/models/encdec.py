"""Whisper-style encoder-decoder backbone (conv audio frontend stubbed:
``input_specs`` supplies precomputed frame embeddings).  Sinusoidal
positions on both sides (DESIGN.md notes the learned-decoder-pos
simplification); pre-LN, GELU MLPs, MHA.

Shape convention for the assigned shape grid: ``seq_len`` is the DECODER
length; the encoder runs at ``seq_len // 4`` stub frames (as if 4x
temporally downsampled audio).
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.config import ModelConfig


class EncDecCache(NamedTuple):
    k: jnp.ndarray        # (L_dec, B, S_max, H, hd) decoder self-attn
    v: jnp.ndarray
    xk: jnp.ndarray       # (L_dec, B, S_enc, H, hd) cross-attn (static)
    xv: jnp.ndarray
    length: jnp.ndarray


def enc_len_for(cfg: ModelConfig, dec_len: int) -> int:
    return max(16, dec_len // 4)


def sinusoid(S: int, D: int) -> np.ndarray:
    pos = np.arange(S)[:, None]
    i = np.arange(D // 2)[None, :]
    ang = pos / np.power(10_000.0, 2 * i / D)
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)


def _attn_block_params(cfg: ModelConfig, rng, cross: bool = False) -> Dict:
    ks = jax.random.split(rng, 3)
    p = {"ln": L.norm_params(cfg, ks[0]), "attn": L.attn_params(cfg, ks[1])}
    return p


def _layer_params(cfg: ModelConfig, rng, cross: bool) -> Dict:
    ks = jax.random.split(rng, 4)
    p = {"self": _attn_block_params(cfg, ks[0]),
         "ln_mlp": L.norm_params(cfg, ks[1]),
         "mlp": L.mlp_params(cfg, ks[2])}
    if cross:
        p["cross"] = _attn_block_params(cfg, ks[3], cross=True)
    return p


def init_params(cfg: ModelConfig, rng) -> Dict:
    k1, k2, k3, k4, k5 = jax.random.split(rng, 5)
    enc_rngs = jax.random.split(k2, cfg.n_enc_layers)
    dec_rngs = jax.random.split(k3, cfg.n_layers)
    return {
        "embed": {"tok": L.embed_init(k1, (cfg.vocab, cfg.d_model),
                                      L.pdtype_of(cfg)),
                  "final_norm": L.norm_params(cfg, k5),
                  "enc_final_norm": L.norm_params(cfg, k5)},
        "enc": jax.vmap(lambda r: _layer_params(cfg, r, cross=False))(enc_rngs),
        "dec": jax.vmap(lambda r: _layer_params(cfg, r, cross=True))(dec_rngs),
    }


def _self_attn(cfg, p, x, causal, kc=None, vc=None, pos=None):
    norm = L.make_norm(cfg)
    h = norm(x, p["ln"])
    q, k, v = L.qkv_proj(cfg, p["attn"], h)
    if kc is not None:  # decode
        kc = jax.lax.dynamic_update_slice(kc, k, (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, pos, 0, 0))
        o = L.attention(q, kc, vc, causal=False, kv_len=pos + 1)
    else:
        o = L.attention(q, k, v, causal=causal)
    o = jnp.einsum("bqx,xd->bqd", o.reshape(*o.shape[:2], -1),
                   p["attn"]["wo"])
    return x + o, (k, v), kc, vc


def _cross_attn(cfg, p, x, xk, xv):
    norm = L.make_norm(cfg)
    h = norm(x, p["ln"])
    B, S, _ = h.shape
    q = jnp.einsum("bsd,dh->bsh", h, p["attn"]["wq"]).reshape(
        B, S, cfg.n_heads, cfg.hd)
    o = L.attention(q, xk, xv, causal=False)
    o = jnp.einsum("bqx,xd->bqd", o.reshape(B, S, -1), p["attn"]["wo"])
    return x + o


def _mlp(cfg, p, x):
    norm = L.make_norm(cfg)
    return x + L.mlp_apply(cfg, p["mlp"], norm(x, p["ln_mlp"]))


def encode(cfg: ModelConfig, params: Dict, audio_embeds: jnp.ndarray):
    """audio_embeds: (B, S_enc, D) stub-frontend output."""
    x = audio_embeds.astype(L.dtype_of(cfg))
    x = x + jnp.asarray(sinusoid(x.shape[1], cfg.d_model),
                        L.dtype_of(cfg))[None]

    def body(x, p):
        x, _, _, _ = _self_attn(cfg, p["self"], x, causal=False)
        return _mlp(cfg, p, x), None

    x, _ = jax.lax.scan(body, x, params["enc"])
    norm = L.make_norm(cfg)
    return norm(x, params["embed"]["enc_final_norm"])


def _cross_kv(cfg, p, enc_out):
    B, Se, _ = enc_out.shape
    k = jnp.einsum("bsd,dh->bsh", enc_out, p["attn"]["wk"]).reshape(
        B, Se, cfg.n_kv_heads, cfg.hd)
    v = jnp.einsum("bsd,dh->bsh", enc_out, p["attn"]["wv"]).reshape(
        B, Se, cfg.n_kv_heads, cfg.hd)
    return k, v


def forward_train(cfg: ModelConfig, params: Dict, batch: Dict,
                  remat: bool = True):
    """batch: audio_embeds (B,S_enc,D), tokens (B,S_dec), labels."""
    enc_out = encode(cfg, params, batch["audio_embeds"])
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = params["embed"]["tok"][tokens].astype(L.dtype_of(cfg))
    x = x + jnp.asarray(sinusoid(S, cfg.d_model), L.dtype_of(cfg))[None]

    def body(x, p):
        x, _, _, _ = _self_attn(cfg, p["self"], x, causal=True)
        xk, xv = _cross_kv(cfg, p["cross"], enc_out)
        x = _cross_attn(cfg, p["cross"], x, xk, xv)
        return _mlp(cfg, p, x), None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec"])
    norm = L.make_norm(cfg)
    x = norm(x, params["embed"]["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["embed"]["tok"].T.astype(x.dtype))
    return logits, jnp.float32(0.0)


def forward_prefill(cfg: ModelConfig, params: Dict, batch: Dict,
                    max_len: Optional[int] = None):
    enc_out = encode(cfg, params, batch["audio_embeds"])
    tokens = batch["tokens"]
    B, S = tokens.shape
    max_len = max_len or S
    x = params["embed"]["tok"][tokens].astype(L.dtype_of(cfg))
    x = x + jnp.asarray(sinusoid(S, cfg.d_model), L.dtype_of(cfg))[None]

    def body(x, p):
        x, (k, v), _, _ = _self_attn(cfg, p["self"], x, causal=True)
        xk, xv = _cross_kv(cfg, p["cross"], enc_out)
        x = _cross_attn(cfg, p["cross"], x, xk, xv)
        x = _mlp(cfg, p, x)
        if max_len > S:
            pad = [(0, 0), (0, max_len - S), (0, 0), (0, 0)]
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        return x, (k, v, xk, xv)

    x, (ks, vs, xks, xvs) = jax.lax.scan(body, x, params["dec"])
    norm = L.make_norm(cfg)
    x = norm(x[:, -1:], params["embed"]["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["embed"]["tok"].T.astype(x.dtype))
    return logits, EncDecCache(ks, vs, xks, xvs, jnp.int32(S))


def forward_decode(cfg: ModelConfig, params: Dict, tokens: jnp.ndarray,
                   cache: EncDecCache):
    B = tokens.shape[0]
    pos = cache.length
    x = params["embed"]["tok"][tokens].astype(L.dtype_of(cfg))
    D = cfg.d_model
    # sinusoidal position for the current step
    half = D // 2
    i = jnp.arange(half, dtype=jnp.float32)
    ang = pos.astype(jnp.float32) / jnp.power(10_000.0, 2 * i / D)
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None, :]
    x = x + pe.astype(x.dtype)

    def body(x, inp):
        p, kc, vc, xk, xv = inp
        x, _, kc, vc = _self_attn(cfg, p["self"], x, causal=False,
                                  kc=kc, vc=vc, pos=pos)
        x = _cross_attn(cfg, p["cross"], x, xk, xv)
        return _mlp(cfg, p, x), (kc, vc)

    x, (ks, vs) = jax.lax.scan(body, x, (params["dec"], cache.k, cache.v,
                                         cache.xk, cache.xv))
    norm = L.make_norm(cfg)
    x = norm(x, params["embed"]["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["embed"]["tok"].T.astype(x.dtype))
    return logits, cache._replace(k=ks, v=vs, length=pos + 1)
