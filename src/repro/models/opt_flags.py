"""Beyond-baseline optimization flags (EXPERIMENTS.md §Perf hillclimbs).

The paper-faithful/default lowering is flags-all-off; the dry-run's
``--variant opt`` turns on the per-cell winners.  Module-level so model
code can consult them without threading knobs through every signature.
"""

from __future__ import annotations

import contextlib
import dataclasses


@dataclasses.dataclass
class OptFlags:
    # decode (hillclimb 1): compute attention scores against the
    # S-sharded KV cache locally (partial softmax + small all-reduces)
    # instead of letting GSPMD all-gather the cache per layer.
    decode_shard_scores: bool = False
    decode_seq_axis: str = "model"
    # decode (hillclimb 1b): append new tokens into a small replicated
    # ring buffer; merge base+buffer attention by online softmax; commit
    # to the sharded base cache every R steps (amortized).
    decode_buffered: bool = False
    decode_buffer_len: int = 256
    # mamba (hillclimb 2): run the chunked selective scan in bf16 and
    # with a smaller chunk (lower log-depth traffic).  REFUTED — see
    # EXPERIMENTS.md §Perf iteration 2.1.
    mamba_bf16_scan: bool = False
    mamba_chunk_override: int = 0
    # mamba (hillclimb 2, iteration 2.2): sequential time scan — the
    # linear-recurrence transpose needs only the dA sequence as residual,
    # eliminating the associative scan's log-depth materializations.
    mamba_seq_scan: bool = False
    # moe (hillclimb 3): keep dispatch/combine token-sharded (constrain
    # intermediate shardings) to avoid all-gathering dispatch tensors.
    moe_local_dispatch: bool = False


FLAGS = OptFlags()


@contextlib.contextmanager
def use_flags(**kw):
    global FLAGS
    old = FLAGS
    FLAGS = dataclasses.replace(FLAGS, **kw)
    try:
        yield FLAGS
    finally:
        FLAGS = old
