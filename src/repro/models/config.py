"""Unified model configuration covering every assigned architecture family."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    # -- attention / embedding ------------------------------------------------
    rope_theta: float = 10_000.0
    rope_frac: float = 1.0      # fraction of head_dim rotated (chatglm3: 0.5)
    norm: str = "rmsnorm"       # rmsnorm | layernorm | nonparam_ln
    act: str = "swiglu"         # swiglu | gelu
    qkv_bias: bool = False
    tie_embeddings: bool = False
    # -- MoE --------------------------------------------------------------------
    n_experts: int = 0
    experts_per_tok: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # -- SSM (mamba1/mamba2) ------------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64      # mamba2 only
    ssm_chunk: int = 256        # mamba2 SSD chunk size
    dt_rank: int = 0            # mamba1: 0 -> d_model // 16
    # -- hybrid (zamba2-style shared attention blocks) ------------------------------
    shared_attn_every: int = 0  # one shared attn+mlp block call every k layers
    # -- encoder-decoder (whisper) ---------------------------------------------------
    n_enc_layers: int = 0
    # -- modality frontend stub --------------------------------------------------------
    frontend: str = "none"      # none | patch_stub | audio_stub
    n_patches: int = 576        # vlm: patch positions per example
    # -- numerics -------------------------------------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank_(self) -> int:
        return self.dt_rank or max(1, self.d_model // 16)

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM state or hybrid w/ bounded attn)."""
        return self.family in ("ssm", "hybrid")

    def n_params(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS in the roofline)."""
        D, V, hd = self.d_model, self.vocab, self.hd
        emb = V * D * (1 if self.tie_embeddings else 2)
        per_attn = D * (self.n_heads * hd) * 2 + D * (self.n_kv_heads * hd) * 2
        n = emb
        if self.family in ("dense", "vlm", "moe"):
            mlp_mult = 3 if self.act == "swiglu" else 2
            if self.family == "moe":
                per_mlp = self.n_experts * mlp_mult * D * self.moe_d_ff \
                    + D * self.n_experts \
                    + self.n_shared_experts * mlp_mult * D * self.moe_d_ff
            else:
                per_mlp = mlp_mult * D * self.d_ff
            n += self.n_layers * (per_attn + per_mlp)
        elif self.family == "ssm":
            din, N, R = self.d_inner, self.ssm_state, self.dt_rank_
            per = (D * 2 * din + din * self.ssm_conv + din * (R + 2 * N)
                   + R * din + din * N + din + din * D)
            n += self.n_layers * per
        elif self.family == "hybrid":
            din, N = self.d_inner, self.ssm_state
            nh = din // self.ssm_head_dim
            per = (D * (2 * din + 2 * N + nh) + din * self.ssm_conv
                   + din + din * D)
            n += self.n_layers * per
            mlp_mult = 3 if self.act == "swiglu" else 2
            n += per_attn + mlp_mult * D * self.d_ff  # one shared block
        elif self.family == "encdec":
            mlp_mult = 3 if self.act == "swiglu" else 2
            enc = self.n_enc_layers * (per_attn + mlp_mult * D * self.d_ff)
            dec = self.n_layers * (2 * per_attn + mlp_mult * D * self.d_ff)
            n += enc + dec
        return n

    def n_active_params(self) -> int:
        """Per-token active parameters (MoE: only routed experts count)."""
        if self.family != "moe":
            return self.n_params()
        D = self.d_model
        mlp_mult = 3 if self.act == "swiglu" else 2
        dense_side = self.n_params() - self.n_layers * (
            self.n_experts * mlp_mult * D * self.moe_d_ff)
        active_moe = self.n_layers * (self.experts_per_tok * mlp_mult * D
                                      * self.moe_d_ff)
        return dense_side + active_moe


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (architecture x input-shape) dry-run cell."""
    shape: str           # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode

    @property
    def is_serve(self) -> bool:
        return self.kind in ("prefill", "decode")


SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", 4_096, 256, "train"),
    ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    ShapeCell("decode_32k", 32_768, 128, "decode"),
    ShapeCell("long_500k", 524_288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeCell:
    for s in SHAPES:
        if s.shape == name:
            return s
    raise KeyError(name)


def cell_applicable(cfg: ModelConfig, cell: ShapeCell) -> Optional[str]:
    """Returns a skip-reason string, or None when the cell must run."""
    if cell.shape == "long_500k" and not cfg.sub_quadratic:
        return ("full-attention arch: 500k decode needs sub-quadratic "
                "attention (DESIGN.md §4)")
    return None
