"""Sequence-level paged-KV manager: block tables, prefix sharing, and the
Clock2Q+-backed block pool.

Block keys:
  * full, immutable blocks -> content hash of the token prefix up to the
    block's end: identical prompt prefixes map to the SAME physical block
    (prefix cache).  These are clean once flushed and freely evictable.
  * the mutable tail block of a live sequence -> a unique (seq, idx)
    handle, pinned while the sequence is active and dirty until complete.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.kvcache.pool import BlockPool
from repro.models.config import ModelConfig

_HASH_SPACE = 1 << 48


def _prefix_key(tokens: Sequence[int]) -> int:
    h = 1469598103934665603
    for t in tokens:
        h = ((h ^ (int(t) + 1)) * 1099511628211) % (1 << 64)
    return h % _HASH_SPACE


@dataclasses.dataclass
class SeqState:
    seq_id: int
    tokens: List[int]
    block_keys: List[int]
    slots: List[int]
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    tenant: Optional[str] = None  # serving tenant (pool lookup labels)

    @property
    def length(self) -> int:
        return len(self.tokens) + len(self.out_tokens)


class PagedKVManager:
    def __init__(self, cfg: ModelConfig, pool: BlockPool):
        self.cfg = cfg
        self.pool = pool
        self.bs = pool.bs
        self.seqs: Dict[int, SeqState] = {}
        self._next_handle = _HASH_SPACE  # tail-block handles above hashes

    # -- admission -----------------------------------------------------------
    def admit(self, seq_id: int, tokens: List[int],
              tenant: Optional[str] = None) -> Tuple[SeqState, List[int]]:
        """Allocate blocks for a prompt.  Returns (state, fill_list): the
        indices of blocks whose contents must be computed by prefill
        (prefix-cache hits need no recompute).  ``tenant`` attributes
        every block lookup of this sequence — admission and decode-tail
        — to the owning serving tenant."""
        n_blocks = -(-len(tokens) // self.bs)
        keys, slots, fill = [], [], []
        for b in range(n_blocks):
            end = min((b + 1) * self.bs, len(tokens))
            full = end == (b + 1) * self.bs
            if full:
                key = _prefix_key(tokens[:end])
            else:
                key = self._next_handle
                self._next_handle += 1
            slot, needs_fill = self.pool.lookup(key, pin=True,
                                                tenant=tenant)
            keys.append(key)
            slots.append(slot)
            if needs_fill or not full:
                fill.append(b)
        st = SeqState(seq_id, list(tokens), keys, slots, tenant=tenant)
        self.seqs[seq_id] = st
        return st, fill

    # -- decode append ------------------------------------------------------------
    def slot_for_pos(self, seq_id: int, pos: int) -> Tuple[int, int]:
        """(slot, offset) where the KV of the token at ``pos`` goes;
        allocates a new tail block on a block boundary."""
        st = self.seqs[seq_id]
        while pos // self.bs >= len(st.slots):
            key = self._next_handle
            self._next_handle += 1
            slot, _ = self.pool.lookup(key, pin=True, tenant=st.tenant)
            # contents arrive via write_token in the same step: the block
            # is immediately usable (leaving it DOING-IO would wedge the
            # live-resize drain, §4.2)
            self.pool.policy.io_done(key)
            self.pool.policy.set_dirty(key)
            st.block_keys.append(key)
            st.slots.append(slot)
        return st.slots[pos // self.bs], pos % self.bs

    def block_table(self, seq_id: int, max_blocks: int) -> np.ndarray:
        st = self.seqs[seq_id]
        bt = np.zeros((max_blocks,), np.int32)
        bt[:len(st.slots)] = st.slots
        return bt

    # -- release -------------------------------------------------------------------
    def release(self, seq_id: int) -> None:
        """Sequence finished: unpin all blocks (they stay cached — a
        follow-up request with the same prefix will hit)."""
        st = self.seqs.pop(seq_id)
        for k in st.block_keys:
            self.pool.unpin(k)

    def maintenance(self) -> None:
        self.pool.run_flusher()
