"""Paged KV block pool with Clock2Q+-managed HBM residency.

The block table of a paged KV cache is a metadata structure mapping
logical (sequence, block-index) -> physical HBM block — exactly the
LBN->PBN mapping of the paper (DESIGN.md §2).  The pool is two-tiered:

    HBM  (jnp arrays)  <- Clock2Q+ decides residency (ProdClock2QPlus)
    host (numpy mirror) <- eviction target ("disk"); dirty = HBM-only

Block keys are content hashes for prefix-shared full blocks (identical
prompts share physical blocks) and (seq_id, block_idx) handles for
per-sequence tail blocks.  Correlated references arise naturally: request
admission touches all prefix blocks of a sequence back-to-back.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as obs_mod
from repro.core.prodcache import EMPTY, ProdClock2QPlus, drive_resize
from repro.faults import GhostJournal, HostIO, ShardReplicator, splitmix64
from repro.faults.recovery import failover as _failover
from repro.models.config import ModelConfig
from repro.shardcache import ShardedClock2QPlus


@dataclasses.dataclass
class PoolStats:
    """Point-in-time view over the pool's obs counters (compat shim —
    the ``pool_*_total`` families are the source of truth)."""
    hits: int = 0
    misses: int = 0
    swap_in: int = 0       # host -> HBM copies
    swap_out: int = 0      # HBM -> host copies (dirty evictions)
    drops: int = 0         # clean evictions (host copy already existed)

    @property
    def hit_ratio(self) -> float:
        return self.hits / max(1, self.hits + self.misses)


class BlockPool:
    """Fixed HBM pool of KV blocks + host tier, Clock2Q+ replacement."""

    def __init__(self, cfg: ModelConfig, n_hbm_blocks: int, block_size: int,
                 n_host_blocks: int = 0, dtype=jnp.float32, *,
                 window_frac: float = 0.5, max_hbm_blocks: int = 0,
                 n_shards: int = 0, rebalance_headroom: float = 1.0,
                 autotune=False, faults=None, io_retry=None,
                 journal_every: int = 1024, replicate: bool = False,
                 journal_dir: Optional[str] = None,
                 lag_threshold: int = 4096, replica_poll: int = 256,
                 obs=None):
        self.cfg = cfg
        self.bs = block_size
        self.n_blocks = n_hbm_blocks
        L, H, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
        # n_shards > 1 selects the sharded concurrent policy backend
        # (repro.shardcache); the pool API is identical either way.
        # rebalance_headroom=1.0 keeps the block arrays at the stated HBM
        # budget (cross-shard borrowing then needs max_hbm_blocks slack);
        # >1 preallocates extra blocks per shard for rebalancing.
        tkw = dict(autotune) if isinstance(autotune, dict) else {}
        # queue-fraction candidates need preallocation headroom (extra
        # payload slots, hence extra HBM blocks) so the tuner's choices
        # are realizable instead of silently clamped
        seg_kw = dict(
            max_small_frac=max(tkw.get("small_fracs") or (0.0,)),
            min_small_frac=min(tkw.get("small_fracs") or (1.0,)),
            max_ghost_frac=max(tkw.get("ghost_fracs") or (0.0,)))
        if n_shards > 1:
            self.policy = ShardedClock2QPlus(
                n_hbm_blocks, n_shards=n_shards, track_io=True,
                window_frac=window_frac,
                max_capacity=max(n_hbm_blocks, max_hbm_blocks),
                rebalance_headroom=rebalance_headroom, **seg_kw)
        else:
            self.policy = ProdClock2QPlus(
                n_hbm_blocks, track_io=True, window_frac=window_frac,
                max_capacity=max(n_hbm_blocks, max_hbm_blocks), **seg_kw)
        # the block arrays cover the policy's full payload-handle space
        # (>= n_hbm_blocks when resize headroom / sharding is configured)
        self.kpool = jnp.zeros((L, self.policy.n_slots, block_size, H, hd),
                               dtype)
        self.vpool = jnp.zeros_like(self.kpool)
        self.host: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        self.n_host_blocks = n_host_blocks or 4 * n_hbm_blocks
        # pool-tier telemetry (the policy keeps its own sink; merged by
        # obs_snapshot()).  ``stats`` is a compat view over these.
        self.obs = obs_mod.ObsSink(src="pool") if obs is None else obs
        lookup_fam = self.obs.counter("pool_lookups_total", ("result",),
                                      "block lookups by outcome")
        self._c_hit = lookup_fam.labels("hit")
        self._c_miss = lookup_fam.labels("miss")
        swap_fam = self.obs.counter("pool_swaps_total", ("dir",),
                                    "HBM<->host block copies")
        self._c_swap_in = swap_fam.labels("in")
        self._c_swap_out = swap_fam.labels("out")
        self._c_drop = self.obs.counter(
            "pool_drops_total", (), "clean evictions (host copy "
            "already existed)").labels()
        self._g_host = self.obs.gauge(
            "pool_host_blocks", (), "blocks mirrored in the host "
            "tier").labels()
        self.obs.on_collect(lambda: self._g_host.set(float(len(self.host))))
        # per-tenant lookup attribution (serving threads tenant labels
        # through admissions; None keeps the historical unlabeled keys)
        self._tenant_fam = self.obs.counter(
            "pool_tenant_lookups_total", ("tenant", "result"),
            "block lookups attributed to serving tenants")
        # hardened host IO (repro.faults).  faults=None keeps the
        # historical direct swap path with zero instrumentation; passing
        # a plan (NullPlan in production) routes every host-block copy
        # through HostIO — retries/backoff/deadlines, a circuit breaker
        # that sheds to read-through under sustained failure, torn-write
        # quarantine, and (on a sharded policy) a GhostJournal captured
        # every ``journal_every`` lookups so SHARD_LOSS faults trigger
        # automatic failover.
        self._io: Optional[HostIO] = None
        self._journal: Optional[GhostJournal] = None
        self._corrupt: set = set()
        self._lookups = 0
        self.journal_every = journal_every
        if faults is not None:
            self._io = HostIO(plan=faults, retry=io_retry, obs=self.obs)
            self._c_torn = self.obs.counter(
                "pool_torn_writes_total", (), "swap-outs persisted torn "
                "(PARTIAL_WRITE) and quarantined").labels()
            self._c_corrupt = self.obs.counter(
                "pool_corrupt_dropped_total", (), "quarantined host "
                "copies dropped at swap-in (read repair: refill from "
                "origin)").labels()
            self._c_lost = self.obs.counter(
                "pool_lost_writes_total", (), "dirty evictions whose "
                "swap-out failed — content refills from origin").labels()
            g_deg = self.obs.gauge(
                "pool_degraded", (), "1 while the breaker has shed host "
                "IO (read-through mode)").labels()
            self.obs.on_collect(
                lambda: g_deg.set(1.0 if self._io.degraded else 0.0))
            if hasattr(self.policy, "shards"):
                self._journal = GhostJournal(self.policy)
        # hot-standby replication (repro.faults.replica): a write-ahead
        # delta journal per shard plus a bounded-staleness standby that
        # tails it, polled from the lookup path every ``replica_poll``
        # lookups.  On shard loss, failover_shard() promotes the standby
        # (exact state, no synthetic re-accesses) while its lag is
        # within ``lag_threshold``; past it, the ghost rewarm above is
        # the fallback.  journal_dir=None replicates in memory.
        self._replicator: Optional[ShardReplicator] = None
        self.replica_poll = replica_poll
        if replicate:
            if not hasattr(self.policy, "shards"):
                raise ValueError("replicate= needs a sharded policy "
                                 "(n_shards > 1)")
            self._replicator = ShardReplicator(
                self.policy, journal_dir, lag_threshold=lag_threshold,
                clock=self._io.clock if self._io is not None else None,
                obs=self.obs)
        # autotune=True (defaults) or a dict of OnlineTuner kwargs: the
        # tuner observes the block-key stream through lookup() and
        # retargets the policy's window / queue fractions online via the
        # live-resize protocol.  Retuning never changes the preallocated
        # payload-handle space, so the block arrays above stay valid.
        self.tuner = None
        if autotune:
            from repro.tuning import OnlineTuner
            tkw.setdefault("retune_every", max(1024, 32 * n_hbm_blocks))
            self.tuner = OnlineTuner(self.policy, obs=self.obs, **tkw)

    @property
    def stats(self) -> PoolStats:
        """The historical stats surface, derived from the obs counters."""
        return PoolStats(hits=self._c_hit.value, misses=self._c_miss.value,
                         swap_in=self._c_swap_in.value,
                         swap_out=self._c_swap_out.value,
                         drops=self._c_drop.value)

    def obs_snapshot(self) -> "obs_mod.Snapshot":
        """Merged pool + replacement-policy (+ tuner, which shares the
        pool's sink) telemetry."""
        pol_snap = self.policy.obs_snapshot() \
            if hasattr(self.policy, "obs_snapshot") \
            else self.policy.obs.snapshot()
        return obs_mod.merge([self.obs.snapshot(), pol_snap])

    # -- residency ------------------------------------------------------------
    def lookup(self, key: int, pin: bool = True,
               tenant: Optional[str] = None) -> Tuple[int, bool]:
        """Returns (hbm_slot, needs_fill).  On miss, a slot is allocated
        (evicting per Clock2Q+); if the key has a host copy it is swapped
        in; otherwise the caller must fill the block (needs_fill=True).
        A failed/shed/quarantined swap-in degrades to read-through: the
        caller refills from the origin exactly as for a cold miss.
        ``tenant`` additionally attributes the lookup to a serving
        tenant (``pool_tenant_lookups_total{tenant,result}``)."""
        if self._io is not None or self._replicator is not None:
            self._lookups += 1
            if self._io is not None and self._io.pending_shard_loss:
                self._drain_shard_loss()
            if self._journal is not None and \
                    self._lookups % self.journal_every == 0:
                self._journal.capture(self.policy)
            if self._replicator is not None and \
                    self._lookups % self.replica_poll == 0:
                self._replicator.poll()
        if self.tuner is not None:
            self.tuner.observe(key)
        r = self.policy.access(key, pin=pin)
        if tenant is not None:
            self._tenant_fam.labels(
                tenant, "hit" if r.hit else "miss").value += 1
        if r.hit:
            self._c_hit.value += 1
            return r.block, False
        self._c_miss.value += 1
        if r.evicted_key != EMPTY:
            self._on_evict(r.evicted_key, r.evicted_block)
        if key in self.host and self._swap_in(key, r.block):
            self.policy.io_done(key)
            return r.block, False
        # brand-new block (or unreadable host copy): contents will be
        # written by prefill/decode
        return r.block, True

    def _on_evict(self, key: int, slot: int) -> None:
        """HBM eviction: dirty blocks (no host copy) are swapped out.
        A failed swap-out loses the content (the next access refills from
        origin); a torn one (PARTIAL_WRITE) is quarantined for read
        repair at the next swap-in."""
        if key in self.host:
            self._c_drop.value += 1
            return
        if len(self.host) >= self.n_host_blocks:
            return
        if self._io is None:
            self._copy_out(key, slot)
            self._c_swap_out.value += 1
            return
        res = self._io.run("swap_out", key,
                           lambda: self._copy_out(key, slot))
        if not res.ok:
            self._c_lost.value += 1
            return
        if res.corrupt:
            self._corrupt.add(key)
            self._c_torn.value += 1
        self._c_swap_out.value += 1

    def _copy_out(self, key: int, slot: int) -> None:
        self.host[key] = (np.asarray(self.kpool[:, slot]),
                          np.asarray(self.vpool[:, slot]))

    def _copy_in(self, key: int, slot: int) -> None:
        k, v = self.host[key]
        self.kpool = self.kpool.at[:, slot].set(jnp.asarray(k))
        self.vpool = self.vpool.at[:, slot].set(jnp.asarray(v))

    def _swap_in(self, key: int, slot: int) -> bool:
        """Host -> HBM copy through the hardened path.  False = the copy
        did not happen (IO gave up, breaker shed, or the host copy was
        quarantined) — the caller serves the miss read-through."""
        if self._io is None:
            self._copy_in(key, slot)
            self._c_swap_in.value += 1
            return True
        if key in self._corrupt:
            # read repair: the torn copy is detected here (the digest-
            # mismatch path) and dropped; the block refills from origin
            del self.host[key]
            self._corrupt.discard(key)
            self._c_corrupt.value += 1
            return False
        res = self._io.run("swap_in", key, lambda: self._copy_in(key, slot))
        if not res.ok:
            return False
        self._c_swap_in.value += 1
        return True

    def write_block(self, slot: int, k: jnp.ndarray, v: jnp.ndarray,
                    key: Optional[int] = None) -> None:
        """k/v: (L, block_size, H, hd) — fill a block after prefill."""
        self.kpool = self.kpool.at[:, slot].set(k)
        self.vpool = self.vpool.at[:, slot].set(v)
        if key is not None:
            self.policy.io_done(key)
            self.policy.set_dirty(key)  # HBM-only content until flushed

    def write_token(self, slot: int, offset: int, k: jnp.ndarray,
                    v: jnp.ndarray) -> None:
        """k/v: (L, H, hd) — append one decoded token into a block."""
        self.kpool = self.kpool.at[:, slot, offset].set(k)
        self.vpool = self.vpool.at[:, slot, offset].set(v)

    def unpin(self, key: int) -> None:
        self.policy.unpin(key)

    def flush(self, key: int) -> None:
        """Mirror a dirty block to host (background flusher).  Under the
        hardened path a failed mirror leaves the block dirty, so the
        watermark flusher naturally retries it; a torn mirror is
        quarantined like any other swap-out."""
        slot = self.policy.slot_of(key)
        if slot == EMPTY:
            return
        if key not in self.host and len(self.host) < self.n_host_blocks:
            if self._io is not None:
                res = self._io.run("swap_out", key,
                                   lambda: self._copy_out(key, slot))
                if not res.ok:
                    return  # still dirty: retried by the next flusher pass
                if res.corrupt:
                    self._corrupt.add(key)
                    self._c_torn.value += 1
            else:
                self._copy_out(key, slot)
            self._c_swap_out.value += 1
        self.policy.clean(key)

    def run_flusher(self, max_blocks: int = 4) -> int:
        """Watermark flusher (paper §4.1.3): mirror oldest dirty blocks."""
        dirty = self.policy.dirty_keys()[:max_blocks]
        for k in dirty:
            self.flush(k)
        return len(dirty)

    # -- backpressure (serving scheduler) -----------------------------------------
    def pinned_count(self) -> int:
        """Resident blocks currently pinned (unevictable) — the hard
        part of occupancy: unpinned blocks are reclaimable by Clock2Q+
        on demand, pinned ones are held by live sequences."""
        if hasattr(self.policy, "shards"):
            return sum(int((s.pin > 0).sum()) for s in self.policy.shards)
        return int((self.policy.pin > 0).sum())

    def free_fraction(self) -> float:
        """Fraction of the HBM budget not pinned — the scheduler's
        free-block watermark signal (1.0 = nothing held)."""
        return 1.0 - self.pinned_count() / max(1, self.n_blocks)

    def io_clock(self):
        """The virtual tick clock the serving scheduler should run on:
        the hardened host-IO path's clock when fault injection is armed
        (so IO backoff time and scheduler time share one axis), a fresh
        one otherwise."""
        from repro.faults.io import Clock
        return self._io.clock if self._io is not None else Clock()

    # -- faults / failover (repro.faults) -----------------------------------------
    @property
    def degraded(self) -> bool:
        """True while host IO is shed (read-through mode).  Always False
        on the uninstrumented path."""
        return self._io is not None and self._io.degraded

    def replication_lag(self, sid: int) -> int:
        """Standby lag for shard ``sid`` in journal records (0 when
        replication is off)."""
        return self._replicator.lag(sid) if self._replicator else 0

    def failover_shard(self, sid: int) -> Tuple[int, int]:
        """Lose shard ``sid`` and rebuild it.

        With replication armed (``replicate=True``) and the standby's
        lag within threshold, the standby is *promoted*: the journal
        tail is replayed past its applied LSN, its exact replacement
        state is loaded into the fresh shard, and only payloads refill
        — no synthetic re-accesses (``repro.faults.replica``).  A
        too-stale standby (or no replication) falls back to the ghost-
        journal rewarm (``repro.faults.recovery.failover``), after
        which the shard's journal is reattached at the next epoch so
        replication resumes.  Either way, readmitted keys whose
        payloads survive in the host tier are refilled directly (the
        recovery scan reads local copies, not the faulted swap path);
        the rest end up in the ghost ring and refill from origin on
        their next touch.  Returns (residents, ghosts) for rewarm,
        (refilled, demoted) for promotion.
        """
        if self._journal is None and self._replicator is None:
            raise RuntimeError("failover needs faults= (or replicate=) "
                               "and a sharded policy (n_shards > 1)")
        base = sid * self.policy.stride

        def fill(key):
            if key not in self.host or key in self._corrupt:
                return None
            return lambda local: self._copy_in(key, base + local)

        rep = self._replicator
        if rep is not None and rep.should_promote(sid):
            res = rep.promote(sid, fill=fill)
            return (res.refilled, res.demoted)
        if self._journal is None:
            raise RuntimeError("standby for shard %d is %d records "
                               "stale (threshold %d) and no ghost "
                               "journal is armed (faults=)"
                               % (sid, rep.lag(sid), rep.lag_threshold))
        out = _failover(self.policy, sid, self._journal, fill=fill)
        if rep is not None:
            rep.reattach(sid)  # resume journaling the rewarmed shard
        return out

    def _drain_shard_loss(self) -> None:
        """Apply SHARD_LOSS faults the plan injected on the IO stream.
        ``shard=-1`` specs pick the victim by hashing the op sequence the
        fault fired at (deterministic per seed)."""
        pending, self._io.pending_shard_loss = \
            self._io.pending_shard_loss, []
        if self._journal is None:
            return  # unsharded policy: nothing to lose a shard from
        n = self.policy.n_shards
        for f in pending:
            sid = f.shard if f.shard >= 0 else \
                splitmix64(self._io.plan.seed ^ f.op_seq) % n
            self.failover_shard(sid)

    # -- what-if analysis --------------------------------------------------------
    def estimate_mrc(self, capacities=None, *, rate_shift: int = 4,
                     window_fracs=None) -> Dict[int, float]:
        """Sampled MRC estimate of the recent block-key stream at
        alternative HBM budgets — what-if input for ``resize()``.
        Requires ``autotune=`` (the tuner's ring buffer is the key
        history); simulated by the registered lane engine for the live
        policy (``policy.engine_policy``), so the estimates describe the
        exact replacement machine this pool runs.  Returns
        {capacity: est. miss ratio} (NaN when the sample is empty)."""
        from repro.tuning import profiler

        if self.tuner is None:
            raise RuntimeError(
                "estimate_mrc needs autotune= — the OnlineTuner's access "
                "ring buffer is the key history it profiles")
        caps = [int(c) for c in
                (capacities or (max(1, self.n_blocks // 2), self.n_blocks,
                                2 * self.n_blocks))]
        live = self.tuner._live_config()
        wfs = tuple(window_fracs) if window_fracs else (live.window_frac,)
        configs = [dataclasses.replace(live, capacity=c, window_frac=wf)
                   for c in caps for wf in wfs]
        trace = self.tuner.recent()
        if trace.size == 0:
            return {c: float("nan") for c in caps}
        est = profiler.estimate_sweep(trace, configs, rate_shift)
        # best window per capacity: the pool would retune after a resize
        per_cap = est.reshape(len(caps), len(wfs))
        out = {c: float(np.nanmin(per_cap[i])) for i, c in enumerate(caps)}
        # what-if MRC as a gauge family: the last estimate at each
        # alternative HBM budget stays scrapeable between calls
        fam = self.obs.gauge("pool_est_miss_ratio", ("capacity",),
                             "sampled-MRC estimate at alternative HBM "
                             "budgets (last estimate_mrc call)")
        for c, mr in out.items():
            fam.labels(str(c)).set(mr)
        return out

    # -- elastic resize (paper §4.2 -> HBM budget changes) -----------------------
    def resize(self, new_n_blocks: int, steps_per_call: int = 64) -> None:
        """Retarget the HBM budget and drive all *migratable* work to
        completion.  Blocks pinned or DOING-IO beyond a shrink boundary
        cannot be drained until released — those are left pending (later
        ``resize_step``/``resize`` calls finish them) instead of spinning:
        the unpin/io_done may be waiting on this very thread."""
        self.policy.begin_resize(new_n_blocks)
        drive_resize(self.policy, steps_per_call)
