"""train_step / serve_step factories.

``make_train_step`` builds a pure (state, batch) -> (state, metrics) step:
grad-accumulation microbatches via lax.scan (XLA overlaps per-microbatch
reduce-scatters with the next microbatch's compute), global-norm clipping,
AdamW.  ``make_prefill_step`` / ``make_decode_step`` build the serving
steps lowered by the dry-run.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import ModelAPI
from repro.training import optim


@dataclasses.dataclass(frozen=True)
class RunConfig:
    microbatches: int = 1
    adamw: optim.AdamWConfig = dataclasses.field(
        default_factory=optim.AdamWConfig)


class TrainState(NamedTuple):
    params: Any
    opt: optim.OptState


def init_train_state(api: ModelAPI, rng, oc: optim.AdamWConfig) -> TrainState:
    params = api.init(rng)
    return TrainState(params=params, opt=optim.init_opt_state(params, oc))


def abstract_train_state(api: ModelAPI, oc: optim.AdamWConfig):
    """Shape-only TrainState (no allocation) for dry-run lowering."""
    return jax.eval_shape(
        lambda r: init_train_state(api, r, oc), jax.random.PRNGKey(0))


def make_train_step(api: ModelAPI, rc: RunConfig):
    oc = rc.adamw

    def loss_fn(params, batch):
        loss, metrics = api.loss(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        if rc.microbatches > 1:
            def reshape(x):
                b = x.shape[0]
                mb = rc.microbatches
                return x.reshape(mb, b // mb, *x.shape[1:])

            micro = jax.tree.map(reshape, batch)

            def accum(carry, mb):
                (l, g) = carry
                (li, _), gi = grad_fn(state.params, mb)
                return (l + li, jax.tree.map(jnp.add, g, gi)), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (loss, grads), _ = jax.lax.scan(
                accum, (jnp.float32(0.0), zero_g), micro)
            inv = 1.0 / rc.microbatches
            loss = loss * inv
            grads = jax.tree.map(lambda g: g * inv, grads)
        else:
            (loss, _), grads = grad_fn(state.params, batch)

        new_params, new_opt, om = optim.adamw_update(
            state.params, grads, state.opt, oc)
        metrics = {"loss": loss, **om}
        return TrainState(new_params, new_opt), metrics

    return step


def make_prefill_step(api: ModelAPI, max_len: Optional[int] = None):
    def step(params, batch):
        return api.prefill(params, batch, max_len=max_len)
    return step


def make_decode_step(api: ModelAPI):
    def step(params, tokens, cache):
        return api.decode(params, tokens, cache)
    return step
