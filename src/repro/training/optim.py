"""AdamW with pytree state (ZeRO-1 sharding is applied by the caller via
out_shardings on the moments), global-norm gradient clipping, and optional
int8 stochastic-rounding gradient compression for cross-pod reduction
(beyond-paper distributed-optimization trick; measured in §Perf)."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    moment_dtype: str = "float32"   # bf16 moments for 1T-class models


class OptState(NamedTuple):
    mu: Any
    nu: Any
    step: jnp.ndarray


def init_opt_state(params: Any, oc: AdamWConfig) -> OptState:
    mdt = jnp.dtype(oc.moment_dtype)
    z = jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params)
    z2 = jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params)
    return OptState(mu=z, nu=z2, step=jnp.int32(0))


def _schedule(oc: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(1.0, (step + 1) / max(1, oc.warmup_steps))
    return oc.lr * warm


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads: Any, clip: float) -> Tuple[Any, jnp.ndarray]:
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, clip / (gn + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


def adamw_update(params: Any, grads: Any, state: OptState,
                 oc: AdamWConfig) -> Tuple[Any, OptState, Dict]:
    grads, gn = clip_by_global_norm(grads, oc.clip_norm)
    step = state.step + 1
    lr = _schedule(oc, state.step)
    b1, b2 = oc.b1, oc.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(oc.moment_dtype)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + gf * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + jnp.square(gf) * (1 - b2)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + oc.eps) + oc.weight_decay \
            * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m32.astype(mdt), v32.astype(mdt))

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    outs = [upd(p, g, m, v) for p, g, m, v in
            zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in outs])
    new_m = tdef.unflatten([o[1] for o in outs])
    new_v = tdef.unflatten([o[2] for o in outs])
    return new_p, OptState(new_m, new_v, step), {"grad_norm": gn, "lr": lr}


# -- gradient compression (beyond-paper §Perf experiment) --------------------

def compress_int8(g: jnp.ndarray, rng: jnp.ndarray):
    """Per-tensor symmetric int8 quantization with stochastic rounding."""
    scale = jnp.maximum(jnp.max(jnp.abs(g.astype(jnp.float32))), 1e-12) / 127.0
    x = g.astype(jnp.float32) / scale
    noise = jax.random.uniform(rng, g.shape) - 0.5
    q = jnp.clip(jnp.round(x + noise), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray,
                    dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)
