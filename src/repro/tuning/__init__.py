"""Batched MRC sweeps + online autotuning — the tuning subsystem.

The paper argues Clock2Q+ "is both easy to tune and implement" and shows
it with an offline window sweep (fig13).  This package turns that story
into a runtime capability:

  * ``sweep`` — a vmap-batched sweep engine on the capacity-masked
    policy core (``repro.core.engine``): a full tuning grid (capacities
    x correlation windows x small/ghost fractions x policies) simulated
    in one jitted ``lax.scan`` per policy family, each lane bit-for-bit
    equal to the serial ``core.jax_engine`` replay at that
    configuration — they call the SAME registered step function.
  * ``profiler`` — spatially-sampled mini-simulation (hash-sample the
    key space to ~1/64 of the stream, scale capacities by the rate) so
    MRC estimation is cheap enough to run continuously.
  * ``tuner`` — ``OnlineTuner``: periodically re-profiles the recent
    access window and retargets a live ``ProdClock2QPlus`` /
    ``ShardedClock2QPlus`` through the ``retune`` runtime setter (built
    on the live-resize protocol, §4.2 — no pause, exact lookups
    mid-migration).  Opt in from ``kvcache.pool.BlockPool`` / the
    serving engine with ``autotune=``.
"""

from repro.tuning.sweep import (  # noqa: F401
    SweepConfig, grid_init, lane_hits, make_grid, mrc_grid,
    relabel, serial_sweep_hits, sweep_grid, sweep_hits,
)
from repro.tuning.profiler import (  # noqa: F401
    estimate_mrc, estimate_sweep, estimate_sweep_store,
    estimate_sweep_stream, sample_mask, sample_stream, sample_trace,
)
from repro.tuning.tuner import OnlineTuner, TuneDecision  # noqa: F401
