"""Batched miss-ratio-curve (MRC) sweep engine.

Simulates a full tuning grid — capacities x correlation-window sizes x
small/ghost-fraction variants x policies — in per-policy jitted
``lax.scan`` calls with the grid as vmap lanes, replacing serial
per-configuration replays (the fig13 path) with a handful of device
calls (one per policy family in the grid; a single-policy grid is ONE
call, as before).

The masked state machinery lives in ``repro.core.engine``: every lane's
queue arrays are padded to the grid-wide maxima while the LOGICAL sizes
live in the state as per-lane scalars, and the ONE shared step function
per policy wraps its cursors modulo the logical sizes.  Each lane is
bit-for-bit the simulation ``core.jax_engine`` would run at that exact
configuration — the step functions are literally the same objects
(asserted in tests/test_tuning.py and tests/test_conformance.py).

This module only depends on the ``core.engine`` API — the grid state
layout and masked steps are not duplicated here.

Keys are relabelled to a dense ``[0, n_unique)`` id space host-side
(cache replacement is label-invariant), so the engine accepts raw 64-bit
keys (e.g. content hashes from the KV block pool) and the per-lane
location tables stay small.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.engine import (  # noqa: F401  (SweepConfig re-exported here)
    SweepConfig, get_engine, grid_hit_arrays, grid_hit_counts, grid_init,
)
from repro.core.engine import lane_hits  # noqa: F401  (conformance hook)


def make_grid(capacities: Sequence[int],
              window_fracs: Sequence[float] = (0.5,),
              small_fracs: Sequence[float] = (0.1,),
              ghost_fracs: Sequence[float] = (0.5,),
              skip_limit: int = 0,
              policy: str = "clock2q+", **kw) -> List[SweepConfig]:
    """Cartesian tuning grid, capacity-major (matches np.reshape order).
    Extra kwargs (e.g. ``bits``) are applied to every config."""
    return [SweepConfig(int(c), float(wf), float(sf), float(gf), skip_limit,
                        policy=policy, **kw)
            for c in capacities for wf in window_fracs
            for sf in small_fracs for gf in ghost_fracs]


def relabel(trace: np.ndarray) -> Tuple[np.ndarray, int]:
    """Dense relabelling: raw (possibly 64-bit) keys -> [0, n_unique).
    (Shared implementation: ``repro.traceio.formats.relabel``.)"""
    from repro.traceio.formats import relabel as _relabel

    return _relabel(trace)


def sweep_hits(trace: np.ndarray, configs: Sequence[SweepConfig],
               pad_pow2: bool = False) -> np.ndarray:
    """Exact per-config hit counts for ``trace`` over the whole grid.
    Result is aligned with ``configs``.  Mixed-policy grids are
    partitioned by ``config.policy`` (vmap lanes must share a state
    pytree); each partition is one jitted call.

    The location tables are sized to the next power of two above the
    relabelled universe: ids beyond ``n_unique`` are never accessed, so
    results are unchanged, but repeated sweeps (the OnlineTuner's
    steady state) hit the jit cache instead of recompiling for every
    new unique-key count.  ``pad_pow2`` additionally pads the trace to a
    power-of-two length with no-op sentinels (same jit-cache motive, at
    up-to-2x step cost — worth it only for repeated small sweeps)."""
    if len(configs) == 0:
        raise ValueError("empty sweep grid")
    tr, universe = relabel(trace)
    universe = 1 << max(1, universe - 1).bit_length()
    if pad_pow2:
        n = 1 << max(1, tr.size - 1).bit_length()
        tr = np.concatenate([tr, np.full(n - tr.size, -1, np.int32)])
    tr = jnp.asarray(tr)
    out = np.empty(len(configs), dtype=np.int64)
    by_policy: dict = {}
    for i, c in enumerate(configs):
        by_policy.setdefault(c.policy, []).append(i)
    for policy, idx in by_policy.items():
        states = grid_init([configs[i] for i in idx], universe)
        out[idx] = np.asarray(grid_hit_counts(policy, states, tr))
    return out


def sweep_grid(trace: np.ndarray, configs: Sequence[SweepConfig],
               pad_pow2: bool = False) -> np.ndarray:
    """Miss ratio per grid configuration (aligned with ``configs``)."""
    hits = sweep_hits(trace, configs, pad_pow2)
    return 1.0 - hits / max(1, len(trace))


def surface_shape(n_grid: int, capacities: Sequence[int],
                  window_fracs: Sequence[float]) -> List[int]:
    """Result shape of a ``make_grid`` sweep viewed as an MRC surface:
    (capacities, window_fracs[, small x ghost variants]) — the single
    place that encodes make_grid's capacity-major ordering."""
    shape = [len(capacities), len(window_fracs)]
    extra = n_grid // (shape[0] * shape[1])
    if extra > 1:
        shape.append(extra)
    return shape


def mrc_grid(trace: np.ndarray, capacities: Sequence[int],
             window_fracs: Sequence[float] = (0.5,),
             **kw) -> np.ndarray:
    """MRC surface of shape (len(capacities), len(window_fracs), ...),
    capacity-major like ``make_grid``."""
    grid = make_grid(capacities, window_fracs, **kw)
    mrs = sweep_grid(trace, grid)
    return mrs.reshape(surface_shape(len(grid), capacities, window_fracs))


def serial_sweep_hits(trace: np.ndarray,
                      configs: Sequence[SweepConfig]) -> np.ndarray:
    """The replaced path: one ``jax_engine`` replay per configuration.
    Kept as the parity/"before" reference for tests and benchmarks."""
    from repro.core import jax_engine as je

    tr, universe = relabel(trace)
    out = np.empty(len(configs), dtype=np.int64)
    for i, c in enumerate(configs):
        eng = get_engine(c.policy)
        kw = {k: getattr(c, k) for k in eng.knobs}
        h, _ = je.replay_np(c.policy, tr, c.capacity, universe=universe,
                            **kw)
        out[i] = h
    return out
