"""Batched miss-ratio-curve (MRC) sweep engine.

Simulates a full tuning grid — capacities x correlation-window sizes x
small/ghost-fraction variants — in ONE jitted ``lax.scan`` with the grid
as vmap lanes, replacing serial per-configuration replays (the fig13
path) with a single device call.

vmap lanes must share array shapes, but grid configurations differ in
segment sizes.  The trick is the *capacity-masked* state: every lane's
queue arrays are padded to the grid-wide maxima while the LOGICAL sizes
(``scap``/``mcap``/``gcap``) live in the state as per-lane scalars, and
the step function wraps its cursors modulo the logical sizes.  Padded
slots start EMPTY and no cursor ever reaches them, so each lane is
bit-for-bit the simulation ``core.jax_engine.c2qp_init/step`` would run
at that exact configuration — asserted in tests/test_tuning.py.

Keys are relabelled to a dense ``[0, n_unique)`` id space host-side
(cache replacement is label-invariant), so the engine accepts raw 64-bit
keys (e.g. content hashes from the KV block pool) and the per-lane
location tables stay small.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.jax_engine import (
    EMPTY, W_GHOST, W_MAIN, W_NONE, W_SMALL, c2qp_sizes,
)


@dataclasses.dataclass(frozen=True)
class SweepConfig:
    """One grid point: a full Clock2Q+ parameterization."""
    capacity: int
    window_frac: float = 0.5
    small_frac: float = 0.1
    ghost_frac: float = 0.5
    skip_limit: int = 0

    def sizes(self) -> Tuple[int, int, int, int]:
        return c2qp_sizes(self.capacity, self.small_frac, self.ghost_frac,
                          self.window_frac)


def make_grid(capacities: Sequence[int],
              window_fracs: Sequence[float] = (0.5,),
              small_fracs: Sequence[float] = (0.1,),
              ghost_fracs: Sequence[float] = (0.5,),
              skip_limit: int = 0) -> List[SweepConfig]:
    """Cartesian tuning grid, capacity-major (matches np.reshape order)."""
    return [SweepConfig(int(c), float(wf), float(sf), float(gf), skip_limit)
            for c in capacities for wf in window_fracs
            for sf in small_fracs for gf in ghost_fracs]


def grid_init(configs: Sequence[SweepConfig], universe: int) -> Dict:
    """Batched masked state: leading axis = len(configs); queue arrays
    padded to the grid maxima, logical sizes as per-lane scalars."""
    n = len(configs)
    if n == 0:
        raise ValueError("empty sweep grid")
    sizes = np.asarray([c.sizes() for c in configs], dtype=np.int32)
    S, M, G = (int(sizes[:, i].max()) for i in range(3))
    return dict(
        skey=jnp.full((n, S), EMPTY), sref=jnp.zeros((n, S), jnp.bool_),
        sseq=jnp.zeros((n, S), jnp.int32), spos=jnp.zeros((n,), jnp.int32),
        seqctr=jnp.zeros((n,), jnp.int32),
        mkey=jnp.full((n, M), EMPTY), mref=jnp.zeros((n, M), jnp.bool_),
        hand=jnp.zeros((n,), jnp.int32),
        gkey=jnp.full((n, G), EMPTY), gpos=jnp.zeros((n,), jnp.int32),
        loc_w=jnp.zeros((n, universe), jnp.int8),
        loc_s=jnp.zeros((n, universe), jnp.int32),
        scap=jnp.asarray(sizes[:, 0]), mcap=jnp.asarray(sizes[:, 1]),
        gcap=jnp.asarray(sizes[:, 2]), window=jnp.asarray(sizes[:, 3]),
        skip_limit=jnp.asarray([c.skip_limit for c in configs], jnp.int32),
    )


# -- the masked step (jax_engine.c2qp_step with logical sizes from state) ------
#
# Two deliberate departures from ``jax_engine.c2qp_step``'s structure, both
# semantics-preserving (asserted bit-for-bit in tests/test_tuning.py) and
# both essential for grid throughput under vmap:
#
#   1. No lax.switch/cond.  Batched lanes diverge, so a switch executes
#      every branch and SELECTS whole state arrays — copying each lane's
#      (universe,)-sized location tables several times per request.  The
#      four cases are mutually exclusive per lane, so the step is written
#      as straight-line code with masked single-slot scatters (a False
#      mask rewrites the current value — a no-op).
#   2. No lax.while_loop for the clock sweep.  Lanes would advance in
#      lock-step.  The sweep is deterministic, so the victim is computed
#      in closed form: with cyclic distance ``d(slot) = (slot - hand)
#      mod mcap`` and ``skippable = occupied & ref``, the hand stops at
#      ``vd = min(first non-skippable d, skip_limit)`` (a full fruitless
#      lap clears every ref and takes the hand slot, ``vd = mcap``),
#      clearing the refs of exactly the ``d < vd`` slots it walked over.

def _mset(arr: jnp.ndarray, i, val, mask) -> jnp.ndarray:
    """Masked single-slot scatter: ``arr[i] = val`` where ``mask``, else
    unchanged (the False branch rewrites ``arr[i]`` to itself, so a
    garbage/negative ``i`` under a False mask is harmless)."""
    return arr.at[i].set(jnp.where(mask, val, arr[i]))


def grid_step(st: Dict, key: jnp.ndarray) -> Tuple[Dict, jnp.ndarray]:
    # key < 0 is a padding sentinel: every case mask goes False, so the
    # step is a no-op and the (non-)hit never counts.  Lets callers pad
    # traces to a bucketed length and reuse the compiled sweep.
    active = key >= 0
    key = jnp.maximum(key, 0)
    where = st["loc_w"][key]
    slot = st["loc_s"][key]
    is_small = active & (where == W_SMALL)
    is_main = active & (where == W_MAIN)
    is_ghost = active & (where == W_GHOST)
    is_none = active & (where == W_NONE)
    hit = is_small | is_main

    # -- hits: ref-bit updates (small obeys the correlation window) -----------
    age_ok = (st["seqctr"] - st["sseq"][slot]) >= st["window"]
    sref = _mset(st["sref"], slot, st["sref"][slot] | age_ok, is_small)
    mref = _mset(st["mref"], slot, True, is_main)

    # -- ghost hit: leave the ghost ring, then insert into main ---------------
    gkey = _mset(st["gkey"], slot, EMPTY, is_ghost)
    loc_w = _mset(st["loc_w"], key, W_NONE, is_ghost)
    loc_s = st["loc_s"]

    # -- miss: displace the small-FIFO cursor slot ----------------------------
    spos = st["spos"]
    displaced = st["skey"][spos]
    disp = is_none & (displaced >= 0)
    disp_promote = disp & sref[spos]
    disp_demote = disp & ~sref[spos]
    loc_w = _mset(loc_w, displaced, W_NONE, disp)

    # demote path: ghost-push the displaced key
    g = st["gpos"]
    gold = gkey[g]
    loc_w = _mset(loc_w, gold, W_NONE, disp_demote & (gold >= 0))
    gkey = _mset(gkey, g, displaced, disp_demote)
    loc_w = _mset(loc_w, displaced, W_GHOST, disp_demote)
    loc_s = _mset(loc_s, displaced, g, disp_demote)
    gpos = jnp.where(disp_demote, (g + 1) % st["gcap"], g)

    # -- main insert (ghost hit or promoted displacee): closed-form clock -----
    do_ins = is_ghost | disp_promote
    ins_key = jnp.where(is_ghost, key, displaced)
    M = st["mkey"].shape[-1]  # physical (padded) ring size — static
    mcap, hand = st["mcap"], st["hand"]
    idx = jnp.arange(M)
    valid = idx < mcap
    d = jnp.where(valid, (idx - hand) % mcap, M + 1)
    skippable = (st["mkey"] >= 0) & mref
    k = jnp.min(jnp.where(valid & ~skippable, d, M + 1))
    k = jnp.minimum(k, mcap)  # no non-skippable slot: full lap
    vd = jnp.where(st["skip_limit"] > 0,
                   jnp.minimum(k, st["skip_limit"]), k)
    ms = (hand + vd) % mcap
    mref = jnp.where(do_ins, mref & ~(valid & (d < vd)), mref)
    victim = st["mkey"][ms]
    loc_w = _mset(loc_w, victim, W_NONE, do_ins & (victim >= 0))
    loc_w = _mset(loc_w, ins_key, W_MAIN, do_ins)
    loc_s = _mset(loc_s, ins_key, ms, do_ins)
    mkey = _mset(st["mkey"], ms, ins_key, do_ins)
    mref = _mset(mref, ms, False, do_ins)
    hand = jnp.where(do_ins, (ms + 1) % mcap, hand)

    # -- miss: the new key enters the small FIFO ------------------------------
    skey = _mset(st["skey"], spos, key, is_none)
    sref = _mset(sref, spos, False, is_none)
    sseq = _mset(st["sseq"], spos, st["seqctr"], is_none)
    loc_w = _mset(loc_w, key, W_SMALL, is_none)
    loc_s = _mset(loc_s, key, spos, is_none)
    spos = jnp.where(is_none, (spos + 1) % st["scap"], spos)
    seqctr = jnp.where(is_none, st["seqctr"] + 1, st["seqctr"])

    st = dict(st, skey=skey, sref=sref, sseq=sseq, spos=spos, seqctr=seqctr,
              mkey=mkey, mref=mref, hand=hand, gkey=gkey, gpos=gpos,
              loc_w=loc_w, loc_s=loc_s)
    return st, hit


@jax.jit
def _sweep_hits(states: Dict, trace: jnp.ndarray) -> jnp.ndarray:
    """All lanes x the whole trace in one compiled call; per-lane hit
    counts (the full hit arrays are reduced on-device, so long traces
    never materialize a lanes x T matrix on the host)."""

    def lane(st):
        st, hits = jax.lax.scan(grid_step, st, trace)
        return jnp.sum(hits.astype(jnp.int32))

    return jax.vmap(lane)(states)


@jax.jit
def _lane_hit_arrays(states: Dict, trace: jnp.ndarray) -> jnp.ndarray:
    def lane(st):
        _, hits = jax.lax.scan(grid_step, st, trace)
        return hits

    return jax.vmap(lane)(states)


def lane_hits(trace: np.ndarray, config: SweepConfig,
              universe: int | None = None) -> np.ndarray:
    """Per-request bool hit array for ONE grid configuration — the
    conformance hook: lets tests/test_conformance.py compare the sweep
    engine hit-for-hit against the other four Clock2Q+ implementations
    (``sweep_hits`` only exposes per-lane counts).  ``trace`` must already
    be dense int ids in [0, universe)."""
    trace = np.asarray(trace)
    if universe is None:
        universe = int(trace.max()) + 1
    states = grid_init([config], int(universe))
    hits = _lane_hit_arrays(states, jnp.asarray(trace, jnp.int32))
    return np.asarray(hits)[0].astype(bool)


def relabel(trace: np.ndarray) -> Tuple[np.ndarray, int]:
    """Dense relabelling: raw (possibly 64-bit) keys -> [0, n_unique).
    (Shared implementation: ``repro.traceio.formats.relabel``.)"""
    from repro.traceio.formats import relabel as _relabel

    return _relabel(trace)


def sweep_hits(trace: np.ndarray, configs: Sequence[SweepConfig],
               pad_pow2: bool = False) -> np.ndarray:
    """Exact per-config hit counts for ``trace`` over the whole grid, in
    one jitted call.  Result is aligned with ``configs``.

    The location tables are sized to the next power of two above the
    relabelled universe: ids beyond ``n_unique`` are never accessed, so
    results are unchanged, but repeated sweeps (the OnlineTuner's
    steady state) hit the jit cache instead of recompiling for every
    new unique-key count.  ``pad_pow2`` additionally pads the trace to a
    power-of-two length with no-op sentinels (same jit-cache motive, at
    up-to-2x step cost — worth it only for repeated small sweeps)."""
    tr, universe = relabel(trace)
    universe = 1 << max(1, universe - 1).bit_length()
    if pad_pow2:
        n = 1 << max(1, tr.size - 1).bit_length()
        tr = np.concatenate([tr, np.full(n - tr.size, -1, np.int32)])
    states = grid_init(configs, universe)
    return np.asarray(_sweep_hits(states, jnp.asarray(tr)))


def sweep_grid(trace: np.ndarray, configs: Sequence[SweepConfig],
               pad_pow2: bool = False) -> np.ndarray:
    """Miss ratio per grid configuration (aligned with ``configs``)."""
    hits = sweep_hits(trace, configs, pad_pow2)
    return 1.0 - hits / max(1, len(trace))


def surface_shape(n_grid: int, capacities: Sequence[int],
                  window_fracs: Sequence[float]) -> List[int]:
    """Result shape of a ``make_grid`` sweep viewed as an MRC surface:
    (capacities, window_fracs[, small x ghost variants]) — the single
    place that encodes make_grid's capacity-major ordering."""
    shape = [len(capacities), len(window_fracs)]
    extra = n_grid // (shape[0] * shape[1])
    if extra > 1:
        shape.append(extra)
    return shape


def mrc_grid(trace: np.ndarray, capacities: Sequence[int],
             window_fracs: Sequence[float] = (0.5,),
             **kw) -> np.ndarray:
    """MRC surface of shape (len(capacities), len(window_fracs), ...),
    capacity-major like ``make_grid``."""
    grid = make_grid(capacities, window_fracs, **kw)
    mrs = sweep_grid(trace, grid)
    return mrs.reshape(surface_shape(len(grid), capacities, window_fracs))


def serial_sweep_hits(trace: np.ndarray,
                      configs: Sequence[SweepConfig]) -> np.ndarray:
    """The replaced path: one ``jax_engine`` replay per configuration.
    Kept as the parity/"before" reference for tests and benchmarks."""
    from repro.core import jax_engine as je

    tr, universe = relabel(trace)
    out = np.empty(len(configs), dtype=np.int64)
    for i, c in enumerate(configs):
        h, _ = je.replay_np("clock2q+", tr, c.capacity, universe=universe,
                            small_frac=c.small_frac, ghost_frac=c.ghost_frac,
                            window_frac=c.window_frac,
                            skip_limit=c.skip_limit)
        out[i] = h
    return out
