"""OnlineTuner — closes the loop from profiling to a live cache.

The paper tunes Clock2Q+ offline (fig13's window sweep); production
workloads drift, so the knobs must track the workload online.  The tuner
keeps a ring buffer of the most recent accesses, periodically profiles
that window with the spatially-sampled batched sweep (a full candidate
grid in one jitted call on ~1/2**rate_shift of the stream), and — when a
candidate configuration beats the live one by at least ``min_gain`` miss
ratio — retargets the live cache through the ``retune`` runtime setter,
which moves segment boundaries via the live-resize protocol (no pause,
lookups stay exact mid-migration).

Works against ``ProdClock2QPlus``, ``ShardedClock2QPlus`` (one decision
from aggregated traffic, applied to every shard) and any cache exposing
the same small surface: ``capacity``, ``tuning``, ``retune``, and an
``engine_policy`` attribute naming its registered lane engine (e.g.
``core.engine.host.EngineCache`` running s3fifo).  The candidate grid
only spans the knobs that engine actually reads — for a knob-free
policy like clock the grid collapses to the live point and the tuner
simply never fires.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro import obs as obs_mod
from repro.core.engine import _FRAC_KNOBS, get_engine
from repro.obs import EV_RETUNE
from repro.core.prodcache import drive_resize
from repro.tuning import profiler
from repro.tuning.sweep import SweepConfig, sweep_grid

DEFAULT_WINDOW_FRACS = (0.1, 0.3, 0.5, 1.0)


@dataclasses.dataclass
class TuneDecision:
    """One profiling round: the candidate grid, estimates, and outcome."""
    at_access: int
    configs: List[SweepConfig]
    est_miss_ratios: np.ndarray
    n_sampled: int
    rate_shift: int
    chosen: SweepConfig
    applied: bool


class OnlineTuner:
    """Periodic sampled re-profiling + live retargeting of a cache."""

    def __init__(self, cache, *, policy: Optional[str] = None,
                 window_fracs: Sequence[float] = DEFAULT_WINDOW_FRACS,
                 small_fracs: Optional[Sequence[float]] = None,
                 ghost_fracs: Optional[Sequence[float]] = None,
                 retune_every: int = 50_000, history: int = 0,
                 rate_shift: int = 6, min_samples: int = 1024,
                 min_scaled_cap: int = 64, min_gain: float = 0.005,
                 confirm_rounds: int = 2, drive_steps: int = 256,
                 max_decisions: int = 256, obs=None):
        self.cache = cache
        # which lane engine simulates this cache: explicit policy= wins,
        # else the cache declares it (engine_policy), else clock2q+
        self.policy = policy or getattr(cache, "engine_policy", "clock2q+")
        self.engine = get_engine(self.policy)
        self.window_fracs = tuple(window_fracs)
        # None = hold the cache's current fraction (window-only tuning);
        # pass explicit candidates to tune the queue fractions too.
        self.small_fracs = tuple(small_fracs) if small_fracs else None
        self.ghost_fracs = tuple(ghost_fracs) if ghost_fracs else None
        self.retune_every = retune_every
        self.history = history or retune_every
        self.rate_shift = rate_shift
        self.min_samples = min_samples
        # Sampling must not scale the mini-cache below this: the window
        # candidates are fractions of the scaled SMALL FIFO, and a
        # too-small mini-cache rounds them all to the same 0-2 slots —
        # the whole dimension being tuned disappears from the estimate.
        self.min_scaled_cap = min_scaled_cap
        self.min_gain = min_gain
        # debounce: a challenger must win this many CONSECUTIVE rounds
        # before it is applied (sampled estimates are noisy; one flip
        # must not whipsaw a live cache)
        self.confirm_rounds = confirm_rounds
        self.drive_steps = drive_steps
        self._buf = np.empty(self.history, dtype=np.int64)
        self._pos = 0
        self._streak: tuple = (None, 0)  # (challenger, consecutive wins)
        self.n_observed = 0
        # bounded: a long-lived service profiles forever, and each
        # decision retains its candidate grid + estimate arrays
        self.decisions: collections.deque = collections.deque(
            maxlen=max_decisions)
        # telemetry: profiling rounds / applied retunes as counters, the
        # sampled-MRC estimate of every grid point as a gauge family
        # (the profiler's what-if surface, scrapeable per round), and an
        # EV_RETUNE event per applied decision
        self.obs = obs_mod.ObsSink(src="tuner") if obs is None else obs
        self._c_rounds = self.obs.counter(
            "tuner_rounds_total", (), "profiling rounds run").labels()
        self._c_retunes = self.obs.counter(
            "tuner_retunes_total", (), "retunes applied to the live "
            "cache").labels()
        self._g_est = self.obs.gauge(
            "tuner_est_miss_ratio",
            ("window_frac", "small_frac", "ghost_frac"),
            "sampled-MRC estimate per candidate config (last round)")
        self._g_live = self.obs.gauge(
            "tuner_live_est_miss_ratio", (), "sampled-MRC estimate of "
            "the live config (last round)").labels()

    # -- observation -----------------------------------------------------------
    def observe(self, key: int) -> Optional[TuneDecision]:
        """Record one access; runs a profiling round every
        ``retune_every`` accesses.  Returns the decision when one ran."""
        self._buf[self._pos] = key
        self._pos = (self._pos + 1) % self.history
        self.n_observed += 1
        if self.n_observed % self.retune_every == 0:
            return self.retune_now()
        return None

    def observe_many(self, keys) -> List[TuneDecision]:
        """Batched ``observe`` (profiling rounds still fire on schedule,
        at batch granularity)."""
        keys = np.asarray(keys, dtype=np.int64)
        out = []
        before = self.n_observed
        for lo in range(0, keys.size,
                        max(1, self.retune_every)):
            chunk = keys[lo:lo + self.retune_every]
            n = chunk.size
            if n >= self.history:
                self._buf[:] = chunk[-self.history:]
                self._pos = 0
            else:
                end = self._pos + n
                if end <= self.history:
                    self._buf[self._pos:end] = chunk
                else:
                    cut = self.history - self._pos
                    self._buf[self._pos:] = chunk[:cut]
                    self._buf[:end - self.history] = chunk[cut:]
                self._pos = end % self.history
            self.n_observed += n
            if self.n_observed // self.retune_every \
                    > before // self.retune_every:
                d = self.retune_now()
                if d is not None:
                    out.append(d)
                before = self.n_observed
        return out

    def recent(self) -> np.ndarray:
        """The buffered access window, oldest first."""
        n = min(self.n_observed, self.history)
        if n < self.history:
            return self._buf[:self._pos].copy()
        return np.concatenate([self._buf[self._pos:], self._buf[:self._pos]])

    # -- the profiling + retargeting round --------------------------------------
    def _realizable(self, sf: float, gf: float) -> bool:
        """A fraction candidate is only worth estimating if the cache's
        preallocation can realize it — ``set_capacity`` clamps to the
        construction-time maxima (give ``max_small_frac``/
        ``min_small_frac``/``max_ghost_frac`` headroom to widen the
        search space).  A small fraction must fit the small maximum AND
        leave a main that fits the main maximum: a clamped segment would
        silently shrink the effective capacity, so the estimate (made at
        the unclamped shape) would not describe the applied cache.
        Caches without preallocation clamps (no ``max_small`` — e.g. an
        ``EngineCache`` that re-inits on retune) realize everything."""
        shards = getattr(self.cache, "shards", None) or [self.cache]
        for s in shards:
            if not hasattr(s, "max_small"):
                continue
            sc = max(1, int(round(s.capacity * sf)))
            if sc > s.max_small or s.capacity - sc > s.max_main:
                return False
            if int(round(s.capacity * gf)) > s.max_ghost:
                return False
        return True

    def _live_skip_limit(self) -> int:
        """The cache's clock skip limit, translated to the SweepConfig
        convention — every estimate must simulate the policy the cache
        actually runs.  ProdClock2QPlus uses None for unlimited and
        forces AFTER the skip counter reaches the limit, so its 0 and 1
        both allow exactly one ref-clearing skip; SweepConfig uses 0 for
        unlimited, hence None -> 0 and n -> max(1, n).  A cache already
        speaking the lane convention says so via ``lane_skip_limit``."""
        shards = getattr(self.cache, "shards", None) or [self.cache]
        lane = getattr(shards[0], "lane_skip_limit", None)
        if lane is not None:
            return int(lane)
        sk = getattr(shards[0], "skip_limit", None)
        return 0 if sk is None else max(1, int(sk))

    def _live_config(self) -> SweepConfig:
        """The configuration the cache runs right now, as a grid point.
        Starts from the engine's own base config (preset defaults for
        fields the cache does not report) and overlays the cache's
        current fraction knobs."""
        base = self.engine.config(self.cache.capacity,
                                  skip_limit=self._live_skip_limit())
        cur = self.cache.tuning
        fracs = {k: float(cur[k]) for k in _FRAC_KNOBS
                 if k in self.engine.knobs and cur.get(k) is not None}
        return dataclasses.replace(base, **fracs)

    def candidate_grid(self) -> List[SweepConfig]:
        """Current-capacity grid over the candidate knobs (candidates the
        preallocation cannot realize are dropped), with the LIVE
        configuration always included (so the gain comparison is against
        the cache as it runs today).  Dimensions the engine does not
        read (``engine.knobs``) collapse to the live value — a
        knob-free policy yields just the live point."""
        live = self._live_config()
        knobs = self.engine.knobs
        wfs = self.window_fracs if "window_frac" in knobs \
            else (live.window_frac,)
        sfs = (self.small_fracs or (live.small_frac,)) \
            if "small_frac" in knobs else (live.small_frac,)
        gfs = (self.ghost_fracs or (live.ghost_frac,)) \
            if "ghost_frac" in knobs else (live.ghost_frac,)
        grid = [dataclasses.replace(live, window_frac=float(wf),
                                    small_frac=float(sf),
                                    ghost_frac=float(gf))
                for wf in wfs for sf in sfs for gf in gfs
                if self._realizable(sf, gf)]
        if live not in grid:
            grid.append(live)
        return grid

    def retune_now(self) -> Optional[TuneDecision]:
        """Profile the recent window and retarget the cache if a
        candidate wins by ``min_gain``.

        Adaptive sampling rate: the shift is bounded by (a) the cache
        capacity, so the scaled mini-cache keeps window resolution
        (``min_scaled_cap``), and (b) the sample count, backing off
        toward exact (shift 0) mini-simulation when the hash sample of
        the window is too thin.  The sample always spans the WHOLE
        buffered window — spatial sampling preserves each surviving
        key's full access sequence, and cutting the horizon instead
        would hide exactly the long-run evictions being tuned for.  The
        sweep itself runs padded to a power-of-two length so
        steady-state rounds reuse the compiled grid."""
        recent = self.recent()
        if recent.size == 0:
            return None
        # rate bounded by capacity (window resolution) and sample count
        cap_bound = max(0, (self.cache.capacity
                            // max(1, self.min_scaled_cap)).bit_length() - 1)
        shift = min(self.rate_shift, cap_bound)
        sampled = profiler.sample_trace(recent, shift)
        while shift > 0 and sampled.size < self.min_samples:
            shift -= 1
            sampled = profiler.sample_trace(recent, shift)
        if sampled.size == 0:
            return None
        grid = self.candidate_grid()
        est = sweep_grid(sampled, profiler.scaled_configs(grid, shift),
                         pad_pow2=True)
        n_sampled = int(sampled.size)
        live = self._live_config()
        live_mr = est[grid.index(live)]
        best_i = int(np.nanargmin(est))
        chosen = grid[best_i]
        self._c_rounds.value += 1
        for cfg, e in zip(grid, est):
            self._g_est.labels(f"{cfg.window_frac:g}",
                               f"{cfg.small_frac:g}",
                               f"{cfg.ghost_frac:g}").set(float(e))
        self._g_live.set(float(live_mr))
        wins = (chosen != live
                and live_mr - est[best_i] >= self.min_gain)
        if wins:
            prev, streak = self._streak
            streak = streak + 1 if chosen == prev else 1
            self._streak = (chosen, streak)
        else:
            self._streak = (None, 0)
        applied = wins and self._streak[1] >= self.confirm_rounds
        if applied:
            self._streak = (None, 0)
            self.cache.retune(**{k: getattr(chosen, k) for k in _FRAC_KNOBS
                                 if k in self.engine.knobs})
            if hasattr(self.cache, "resize_step"):
                drive_resize(self.cache, self.drive_steps)
            self._c_retunes.value += 1
            # window fracs as per-mille ints (event a/b are int64),
            # estimated gain in c
            self.obs.emit(EV_RETUNE,
                          a=int(round(1000 * live.window_frac)),
                          b=int(round(1000 * chosen.window_frac)),
                          c=float(live_mr - est[best_i]))
        d = TuneDecision(self.n_observed, grid, est, n_sampled, shift,
                         chosen, applied)
        self.decisions.append(d)
        return d
