"""Spatially-sampled mini-simulation MRC profiler (the SHARDS idea).

Exact MRC estimation replays every request; continuous online profiling
can't afford that.  Instead, sample the KEY SPACE with a hash: a key is
in the sample iff ``mix64(key) mod 2**rate_shift == 0``, so roughly a
``1/2**rate_shift`` fraction of the stream survives — and, crucially,
every surviving key keeps its FULL access sequence (spatial sampling
preserves per-key temporal patterns, unlike request subsampling).  The
sampled stream is then simulated at capacities scaled by the sampling
rate; the resulting miss ratios estimate the full-trace miss ratios at
the original capacities.

The mix is a splitmix64 finalizer — deliberately distinct from both the
shard-selection hash (``shardcache.hashing``) and the bucket hash
(``ProdClock2QPlus._h``) so the sample is uncorrelated with shard or
bucket placement.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.tuning.sweep import (
    SweepConfig, make_grid, surface_shape, sweep_grid,
)

_MASK64 = np.uint64(0xFFFFFFFFFFFFFFFF)


def mix64(keys: np.ndarray) -> np.ndarray:
    """Vectorized splitmix64 finalizer (uint64 in/out)."""
    z = np.asarray(keys).astype(np.uint64)
    z = (z + np.uint64(0x9E3779B97F4A7C15)) & _MASK64
    z = ((z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)) & _MASK64
    z = ((z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)) & _MASK64
    return z ^ (z >> np.uint64(31))


def sample_mask(keys: np.ndarray, rate_shift: int = 6) -> np.ndarray:
    """True for keys in the spatial sample (~``2**-rate_shift`` of the
    key space; ``rate_shift=0`` keeps everything)."""
    if rate_shift <= 0:
        return np.ones(np.asarray(keys).shape, dtype=bool)
    return (mix64(keys) & np.uint64((1 << rate_shift) - 1)) == 0


def sample_trace(trace: np.ndarray, rate_shift: int = 6) -> np.ndarray:
    """The subsequence of ``trace`` whose keys fall in the sample, in
    request order (~1/64 of the stream at the default shift)."""
    trace = np.asarray(trace)
    return trace[sample_mask(trace, rate_shift)]


def scale_capacity(capacity: int, rate_shift: int, floor: int = 4) -> int:
    """Cache size for the mini-simulation: capacity x sampling rate."""
    return max(floor, int(round(capacity / (1 << rate_shift))))


def scaled_configs(configs: Sequence[SweepConfig],
                   rate_shift: int) -> list:
    # replace() keeps every other knob — including policy and bits — so
    # the profiler works for any registered lane policy, not just
    # clock2q+
    return [dataclasses.replace(
        c, capacity=scale_capacity(c.capacity, rate_shift))
        for c in configs]


def estimate_sweep(trace: np.ndarray, configs: Sequence[SweepConfig],
                   rate_shift: int = 6) -> np.ndarray:
    """Estimated full-trace miss ratio for each (full-scale) config, from
    one batched mini-simulation of the sampled stream."""
    sampled = sample_trace(trace, rate_shift)
    if sampled.size == 0:
        return np.full(len(configs), np.nan)
    return sweep_grid(sampled, scaled_configs(configs, rate_shift))


def sample_stream(chunks, rate_shift: int = 6) -> np.ndarray:
    """Spatial sample of a CHUNKED stream: the mask is a pure per-key
    function, so sampling each chunk and concatenating is bit-identical
    to ``sample_trace`` of the concatenated trace — this is what makes
    SHARDS-style profiling streamable.  The returned sample (~1/2**shift
    of the stream) is the only thing held in memory."""
    parts = [c[sample_mask(c, rate_shift)]
             for c in (np.asarray(c) for c in chunks)]
    if not parts:
        return np.empty(0, dtype=np.int64)
    return np.concatenate(parts)


def estimate_sweep_stream(chunks, configs: Sequence[SweepConfig],
                          rate_shift: int = 6) -> np.ndarray:
    """``estimate_sweep`` over a chunk iterable (e.g. ``TraceStore.
    chunks()``): bounded memory in the trace length, bit-identical to
    the whole-trace estimate (asserted in tests/test_chunked.py)."""
    sampled = sample_stream(chunks, rate_shift)
    if sampled.size == 0:
        return np.full(len(configs), np.nan)
    return sweep_grid(sampled, scaled_configs(configs, rate_shift))


def estimate_sweep_store(store, configs: Sequence[SweepConfig],
                         rate_shift: int = 6,
                         chunk_size: int = 1 << 20) -> np.ndarray:
    """Sampled sweep straight off an on-disk trace (TraceStore/ndarray)."""
    from repro.traceio.store import iter_chunks

    return estimate_sweep_stream(iter_chunks(store, chunk_size), configs,
                                 rate_shift)


def estimate_mrc(trace: np.ndarray, capacities: Sequence[int],
                 window_fracs: Sequence[float] = (0.5,),
                 rate_shift: int = 6, **kw) -> np.ndarray:
    """Sampled MRC estimate, shaped like ``sweep.mrc_grid``'s output."""
    grid = make_grid(capacities, window_fracs, **kw)
    est = estimate_sweep(trace, grid, rate_shift)
    return est.reshape(surface_shape(len(grid), capacities, window_fracs))
